# Developer entrypoints.  `make verify` is the tier-1 gate (ROADMAP.md).
PY := python
export PYTHONPATH := src

.PHONY: verify test fast quickstart bench bench-check docs-check coverage

verify:
	$(PY) -m pytest -x -q

test:
	$(PY) -m pytest -q --continue-on-collection-errors

fast:
	$(PY) -m pytest -q -m "not slow"

quickstart:
	$(PY) examples/quickstart.py

# CI-sized benchmark sweep; transport_bench also writes BENCH_transport.json
bench:
	$(PY) -m benchmarks.run --fast

# Perf-regression gate: fresh full-size bench runs vs committed
# BENCH_*.json baselines, with per-metric tolerances (benchmarks/check.py)
bench-check:
	$(PY) -m benchmarks.run --check

# Executable-documentation gate: runs every fenced python snippet in
# docs/*.md + README.md + listed module docstrings + the examples
docs-check:
	$(PY) tools/docs_check.py

# Line-coverage gate for core/psi.py + federation/ (fails below
# REPRO_COVERAGE_MIN, default 93%; REPRO_COVERAGE_GATE=0 to bypass —
# baseline in docs/BENCHMARKS.md).  Uses pytest-cov when installed, a
# scoped stdlib tracer otherwise.
coverage:
	$(PY) tools/coverage_report.py
