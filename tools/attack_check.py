#!/usr/bin/env python
"""Privacy-attack smoke gate (CI `privacy-attack` job).

Runs the transcript-attack harness (tests/attacks/harness.py) against
real captured wire traffic with each defense off and on, prints the
leakage table, and exits nonzero unless EVERY defense makes its
attacker strictly worse off:

  * model inversion (held-out R^2) and dcor leakage must drop under
    ``cut_noise_std`` and under ``aggregation="masked_sum"``;
  * the norm attack's label-inference AUC must drop under
    ``grad_noise_std`` and both ``grad_norm_mode`` settings;
  * PSI membership inference (scientist-side, against resolved-round
    transcripts) must lose advantage under ``resolve(mode="hidden")``
    vs the plaintext-intersection modes (WIRE_PROTOCOL invariant 12).

Usage:  PYTHONPATH=src:tests python tools/attack_check.py [--steps N]
        (``--psi-only`` runs just the PSI membership check — the other
        attacks need a full split fit and dominate the runtime)
"""
from __future__ import annotations

import argparse
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, os.path.join(ROOT, "tests"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--psi-only", action="store_true",
                    help="run only the PSI membership-inference check")
    args = ap.parse_args()

    from attacks import harness as H

    failures = []

    def check(label, attacker, baseline, defended):
        gap = baseline - defended
        ok = gap > 0
        print(f"{attacker:22s} {label:12s} baseline={baseline:+.4f} "
              f"defended={defended:+.4f} gap={gap:+.4f} "
              f"{'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append((attacker, label))

    # PSI membership inference: the hidden-mode keep-mask must strictly
    # reduce the scientist-side attacker's advantage over the plaintext
    # intersection (it stays > 0 — padding hides identity, not the
    # every-member-is-kept property; see ARCHITECTURE threat model)
    check("hidden_mode", "psi_membership",
          H.psi_membership_advantage("noinv"),
          H.psi_membership_advantage("hidden"))

    if not args.psi_only:
        kw = dict(steps=args.steps, n=args.n)
        base = H.capture_transcript(**kw)
        runs = {
            "cut_noise": H.capture_transcript(cut_noise_std=2.0, **kw),
            "masked_sum": H.capture_transcript(aggregation="masked_sum",
                                               **kw),
            "grad_noise": H.capture_transcript(grad_noise_std=0.05,
                                               **kw),
            "grad_unit": H.capture_transcript(grad_norm_mode="unit",
                                              **kw),
            "grad_sign": H.capture_transcript(grad_norm_mode="sign",
                                              **kw),
        }
        owners = sorted(base.cuts)
        for defense in ("cut_noise", "masked_sum"):
            for owner in owners:
                check(defense, f"inversion_r2[{owner}]",
                      H.inversion_r2(base, owner),
                      H.inversion_r2(runs[defense], owner))
                check(defense, f"dcor[{owner}]",
                      H.dcor_leakage(base, owner),
                      H.dcor_leakage(runs[defense], owner))
        for defense in ("grad_noise", "grad_unit", "grad_sign"):
            check(defense, "norm_auc",
                  H.norm_attack_auc(base),
                  H.norm_attack_auc(runs[defense]))

    if failures:
        print(f"\n{len(failures)} defense(s) failed to reduce leakage")
        return 1
    print("\nall defenses strictly reduce attacker leakage")
    return 0


if __name__ == "__main__":
    sys.exit(main())
