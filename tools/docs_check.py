#!/usr/bin/env python
"""Executable-documentation gate (``make docs-check``).

Documentation rots silently unless it runs.  This tool extracts and
executes, as real subprocesses with ``PYTHONPATH=src``:

  1. every fenced ```python block in ``docs/*.md`` and ``README.md``
     (skip one by putting ``<!-- docs-check: skip -->`` on the line
     directly above the fence — for deliberately illustrative fragments);
  2. every fenced ```python block inside the module docstrings listed in
     ``DOCSTRING_MODULES`` (e.g. the ``federation/session.py`` header
     example);
  3. the example scripts in ``EXAMPLES`` (with fast flags where the
     script supports them).

Each snippet must be self-contained: it runs in its own interpreter from
the repo root.  Failures print the captured output and fail the gate
(exit 1) — CI runs this next to the tier-1 tests.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_GLOBS = ("docs", "README.md")
DOCSTRING_MODULES = ("src/repro/federation/session.py",)
EXAMPLES = (
    ("examples/psi_demo.py", ()),
    ("examples/multihead_scaling.py", ("--fast",)),
    ("examples/serve_split.py",
     ("--ctx", "32", "--new", "4", "--batch", "2", "--n-batches", "2",
      "--continuous", "--sessions", "2", "--transport", "queue")),
    ("examples/privacy_defense.py", ("--fast",)),
)
SKIP_MARK = "<!-- docs-check: skip -->"
TIMEOUT_S = 1200

FENCE_RE = re.compile(r"^```python\s*$")


def fenced_blocks(text: str):
    """Yield (start_line, code) for each ```python fence, honoring the
    skip marker on the line directly above the fence."""
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if FENCE_RE.match(lines[i]):
            prev = ""
            for j in range(i - 1, -1, -1):
                if lines[j].strip():
                    prev = lines[j].strip()
                    break
            body = []
            i += 1
            start = i + 1
            while i < len(lines) and lines[i].rstrip() != "```":
                body.append(lines[i])
                i += 1
            if prev != SKIP_MARK:
                yield start, "\n".join(body) + "\n"
        i += 1


def collect():
    """-> [(label, code-or-None, argv-or-None)] — code snippets carry
    source text; examples carry an argv to run directly."""
    jobs = []
    md_files = []
    for entry in DOC_GLOBS:
        path = os.path.join(ROOT, entry)
        if os.path.isdir(path):
            md_files += sorted(
                os.path.join(path, f) for f in os.listdir(path)
                if f.endswith(".md"))
        elif os.path.exists(path):
            md_files.append(path)
    for md in md_files:
        with open(md) as f:
            text = f.read()
        for line, code in fenced_blocks(text):
            rel = os.path.relpath(md, ROOT)
            jobs.append((f"{rel}:{line}", code, None))
    for mod in DOCSTRING_MODULES:
        with open(os.path.join(ROOT, mod)) as f:
            doc = ast.get_docstring(ast.parse(f.read())) or ""
        for line, code in fenced_blocks(doc):
            jobs.append((f"{mod}:docstring:{line}", code, None))
    for script, extra in EXAMPLES:
        jobs.append((f"{script} {' '.join(extra)}".strip(), None,
                     [os.path.join(ROOT, script), *extra]))
    return jobs


def run_one(label, code, argv) -> bool:
    env = dict(os.environ)
    # src for repro.*, the repo root for benchmarks.* / tools.*
    path = os.path.join(ROOT, "src") + os.pathsep + ROOT
    env["PYTHONPATH"] = (path + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else path)
    if argv is None:
        with tempfile.NamedTemporaryFile(
                "w", suffix=".py", delete=False) as f:
            f.write(code)
            tmp = f.name
        # launch via a ``-c`` + exec shim: snippets are unguarded (no
        # ``if __name__ == "__main__"``), and multiprocessing *spawn*
        # children re-execute the parent's main-module file — which
        # would re-run the whole snippet recursively.  Under ``-c`` the
        # real ``sys.modules['__main__']`` has no ``__file__`` (runpy
        # would temporarily install the snippet there, so it is no
        # help), spawn ships no main module, and process-backend
        # snippets fork out cleanly.
        shim = ("import sys; p = sys.argv[1]; "
                "exec(compile(open(p).read(), p, 'exec'), "
                "{'__name__': '__main__', '__file__': p})")
        cmd = [sys.executable, "-c", shim, tmp]
    else:
        tmp = None
        cmd = [sys.executable, *argv]
    try:
        proc = subprocess.run(cmd, cwd=ROOT, env=env, text=True,
                              capture_output=True, timeout=TIMEOUT_S)
    finally:
        if tmp:
            os.unlink(tmp)
    ok = proc.returncode == 0
    print(f"docs-check {'PASS' if ok else 'FAIL'} {label}")
    if not ok:
        sys.stdout.write(proc.stdout[-4000:])
        sys.stderr.write(proc.stderr[-4000:])
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="list snippets without running them")
    ap.add_argument("--only", default=None,
                    help="substring filter on snippet labels")
    args = ap.parse_args(argv)
    jobs = collect()
    if args.only:
        jobs = [j for j in jobs if args.only in j[0]]
    if args.list:
        for label, code, argv_ in jobs:
            kind = "example" if argv_ else f"{len(code.splitlines())} lines"
            print(f"{label} ({kind})")
        return 0
    if not jobs:
        print("docs-check: no snippets found", file=sys.stderr)
        return 1
    failures = sum(not run_one(*j) for j in jobs)
    print(f"docs-check: {len(jobs) - failures}/{len(jobs)} snippets pass")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
