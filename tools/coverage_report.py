#!/usr/bin/env python
"""Line-coverage report for the PSI + federation stack (``make coverage``).

Scope: ``src/repro/core/psi.py`` and ``src/repro/federation/*.py`` — the
modules the wire-native resolution work (ISSUE 5) touches — exercised by
the protocol-focused test files in ``DEFAULT_TESTS``.

Two engines, same report shape:

  * **pytest-cov** (preferred; in ``requirements-dev.txt``, so CI has
    it): delegates to ``pytest --cov`` with the scoped targets.
  * **stdlib fallback** — offline images without pytest-cov get a
    ``sys.settrace``/``threading.settrace`` tracer restricted to the
    target files (line events fire only inside target frames, so the
    rest of the suite runs near full speed).  Executable-line
    denominators come from walking each module's compiled code objects
    (``co_lines``), i.e. exactly the lines the tracer could ever hit.

The report is a **gate**: total coverage below ``REPRO_COVERAGE_MIN``
(default 93, in percent) fails the run.  Set ``REPRO_COVERAGE_GATE=0``
to drop back to informational mode (the escape hatch for exploratory
branches); the committed baseline lives in ``docs/BENCHMARKS.md``.
Slow-marked tests run too — the process-backend fit tests are what
exercise the parent-side worker-lifecycle branches in ``session.py``.

    PYTHONPATH=src python tools/coverage_report.py [test paths...]
"""
from __future__ import annotations

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: minimum total line coverage (percent) unless REPRO_COVERAGE_GATE=0
COVERAGE_MIN = float(os.environ.get("REPRO_COVERAGE_MIN", "93"))
GATED = os.environ.get("REPRO_COVERAGE_GATE", "1") != "0"

TARGET_FILES = ("src/repro/core/psi.py",)
TARGET_DIRS = ("src/repro/federation",)

#: the protocol/federation-focused slice of the suite (the full tier-1
#: run would cover the same targets more slowly; kernels/model tests
#: don't touch them)
DEFAULT_TESTS = (
    "tests/test_psi.py",
    "tests/test_psi_parallel.py",
    "tests/test_psi_transport.py",
    "tests/test_resolution.py",
    "tests/test_transport.py",
    "tests/test_federation.py",
    "tests/test_process_transport.py",
    "tests/test_serving.py",
    "tests/test_recovery.py",
)


def target_files():
    out = [os.path.join(ROOT, f) for f in TARGET_FILES]
    for d in TARGET_DIRS:
        full = os.path.join(ROOT, d)
        out += sorted(os.path.join(full, f) for f in os.listdir(full)
                      if f.endswith(".py"))
    return [os.path.realpath(f) for f in out]


def _have_pytest_cov() -> bool:
    try:
        import pytest_cov  # noqa: F401
        return True
    except ImportError:
        return False


def run_pytest_cov(tests) -> int:
    cmd = [sys.executable, "-m", "pytest", "-q", *tests,
           "--cov=repro.core.psi", "--cov=repro.federation",
           "--cov-report=term"]
    if GATED:
        cmd.append(f"--cov-fail-under={COVERAGE_MIN}")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.call(cmd, cwd=ROOT, env=env)


# ---------------------------------------------------------------------------
# stdlib fallback tracer
# ---------------------------------------------------------------------------


def executable_lines(path: str) -> set:
    """All line numbers the tracer could report for ``path``: walk the
    compiled module's code objects recursively and collect co_lines."""
    with open(path) as f:
        code = compile(f.read(), path, "exec")
    lines: set = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, _, ln in co.co_lines() if ln is not None)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_code"))
    return lines


def run_fallback(tests) -> int:
    import threading

    import pytest

    targets = set(target_files())
    hits = {t: set() for t in targets}
    # co_filename is whatever path the import used; resolve lazily and
    # memoize so the global trace hook stays cheap
    resolved: dict = {}

    def resolve(fn):
        try:
            return resolved[fn]
        except KeyError:
            real = os.path.realpath(fn)
            out = real if real in targets else None
            resolved[fn] = out
            return out

    def local_trace(frame, event, arg):
        if event == "line":
            tgt = resolve(frame.f_code.co_filename)
            if tgt is not None:
                hits[tgt].add(frame.f_lineno)
        return local_trace

    def global_trace(frame, event, arg):
        if resolve(frame.f_code.co_filename) is not None:
            return local_trace
        return None

    threading.settrace(global_trace)
    sys.settrace(global_trace)
    try:
        rc = pytest.main(["-q", *tests])
    finally:
        sys.settrace(None)
        threading.settrace(None)

    print("\n--- line coverage (stdlib tracer; pytest-cov absent) ---")
    print(f"{'file':<44} {'lines':>6} {'hit':>6} {'cover':>7}")
    tot_lines = tot_hit = 0
    for t in sorted(targets):
        exe = executable_lines(t)
        hit = hits[t] & exe
        tot_lines += len(exe)
        tot_hit += len(hit)
        rel = os.path.relpath(t, ROOT)
        pct = 100.0 * len(hit) / max(len(exe), 1)
        print(f"{rel:<44} {len(exe):>6} {len(hit):>6} {pct:>6.1f}%")
    pct = 100.0 * tot_hit / max(tot_lines, 1)
    print(f"{'TOTAL':<44} {tot_lines:>6} {tot_hit:>6} {pct:>6.1f}%")
    if int(rc):
        return int(rc)
    if GATED and pct < COVERAGE_MIN:
        print(f"FAIL coverage gate: total {pct:.1f}% < "
              f"REPRO_COVERAGE_MIN={COVERAGE_MIN:g}% "
              f"(set REPRO_COVERAGE_GATE=0 to bypass)")
        return 1
    return 0


def main(argv=None) -> int:
    tests = list(argv if argv is not None else sys.argv[1:]) \
        or list(DEFAULT_TESTS)
    if _have_pytest_cov():
        return run_pytest_cov(tests)
    return run_fallback(tests)


if __name__ == "__main__":
    raise SystemExit(main())
