"""The bench regression gate's comparator (benchmarks/check.py): the
tolerance model that lets `make bench-check` track BENCH_*.json perf
baselines without flaking on a noisy box."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check import TRACKED, check, compare  # noqa: E402


BASE = {
    "config": {"n": 1500},
    "joint_step_ms": 2.0,
    "split_pipelined_step_ms": 20.0,
    "pipeline_speedup": 2.2,
    "pipelined_microbatches": 1,
    "pipeline_sweep": {"8.0": {"1": 20.0}},
    "compression": {
        "int8": {"cut_payload_bytes_per_step": 17408,
                 "val_accuracy": 0.45,
                 "compression_ratio": 3.76}},
}


def test_within_tolerance_passes():
    fresh = json.loads(json.dumps(BASE))
    fresh["joint_step_ms"] = 4.5            # 2.25x — noisy-box ratio ok
    fresh["compression"]["int8"]["val_accuracy"] = 0.41
    assert compare(BASE, fresh) == []


def test_timing_regression_fails():
    fresh = json.loads(json.dumps(BASE))
    fresh["split_pipelined_step_ms"] = 60.0  # 3x — compile in hot loop
    fails = compare(BASE, fresh)
    assert len(fails) == 1 and "split_pipelined_step_ms" in fails[0]


def test_byte_counts_are_exact():
    fresh = json.loads(json.dumps(BASE))
    fresh["compression"]["int8"]["cut_payload_bytes_per_step"] += 4
    assert any("cut_payload_bytes_per_step" in f
               for f in compare(BASE, fresh))


def test_missing_metric_fails_and_skips_are_skipped():
    fresh = json.loads(json.dumps(BASE))
    del fresh["pipeline_speedup"]
    fresh["pipelined_microbatches"] = 4      # platform pick: ignored
    fresh["config"] = {"n": 9}               # config subtree: ignored
    fresh["pipeline_sweep"] = {}             # sweep subtree: ignored
    fails = compare(BASE, fresh)
    assert len(fails) == 1 and "pipeline_speedup" in fails[0]


def test_check_gates_on_committed_baselines(tmp_path):
    """End-to-end on synthetic files: PASS when fresh matches, count
    failures when a tracked metric regresses or a file is missing."""
    repo, fresh = tmp_path / "repo", tmp_path / "fresh"
    repo.mkdir(), fresh.mkdir()
    fname = next(iter(TRACKED))
    (repo / fname).write_text(json.dumps(BASE))
    (fresh / fname).write_text(json.dumps(BASE))
    assert check(str(repo), str(fresh)) == 0
    bad = json.loads(json.dumps(BASE))
    bad["split_pipelined_step_ms"] = 500.0
    (fresh / fname).write_text(json.dumps(bad))
    assert check(str(repo), str(fresh)) == 1


def test_committed_baselines_parse_against_themselves():
    """The real committed BENCH files pass their own gate (sanity that
    the tolerance rules cover every key they contain)."""
    root = os.path.join(os.path.dirname(__file__), "..")
    for fname in TRACKED:
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            pytest.skip(f"{fname} not committed")
        with open(path) as f:
            d = json.load(f)
        assert compare(d, d, fname) == []
