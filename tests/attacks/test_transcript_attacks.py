"""Transcript attacks vs defenses: every defense must make the
attacker strictly worse off, measured on tap-captured wire traffic of
real training runs (never on synthetic tensors).

Leakage numbers in the asserts come with a lot of margin — the
harness's probe runs show baseline inversion R^2 ~ 0.5 vs ~ -0.5
defended, and norm-attack AUC 0.99 vs ~ 0.5 defended.  dcor carries a
large small-sample bias at B=64 in high dimension (floor ~ 0.83), so
its assertions are strictly relative.
"""
import pytest

from . import harness as H

# one capture per defense configuration, shared across tests
_T: dict = {}


def _tr(name, **kw):
    if name not in _T:
        _T[name] = H.capture_transcript(**kw)
    return _T[name]


def _base():
    return _tr("base")


# ---------------------------------------------------------------------------
# forward leg: model inversion + dcor vs cut defenses
# ---------------------------------------------------------------------------


def test_inversion_reconstructs_undefended_cuts():
    """The attack is real: with no defense the ridge decoder
    reconstructs held-out raw rows well above chance from the wire."""
    tr = _base()
    for owner in sorted(tr.cuts):
        assert H.inversion_r2(tr, owner) > 0.3


def test_cut_noise_blunts_inversion_and_dcor():
    base, noisy = _base(), _tr("cut_noise", cut_noise_std=2.0)
    for owner in sorted(base.cuts):
        r2_b, r2_d = (H.inversion_r2(base, owner),
                      H.inversion_r2(noisy, owner))
        assert r2_d < r2_b - 0.3 and r2_d < 0.05
        assert H.dcor_leakage(noisy, owner) \
            < H.dcor_leakage(base, owner) - 0.05


def test_masked_sum_blunts_forward_leakage_to_the_noise_floor():
    """Ring-coded frames are uniform: inversion collapses below zero
    R^2 (worse than predicting the mean) and dcor falls to the
    independent-batch floor."""
    base, masked = _base(), _tr("masked", aggregation="masked_sum")
    for owner in sorted(base.cuts):
        assert H.inversion_r2(masked, owner) < 0.0
        assert H.dcor_leakage(masked, owner) \
            < H.dcor_leakage(base, owner) - 0.05


# ---------------------------------------------------------------------------
# backward leg: norm-based label inference vs gradient defenses
# ---------------------------------------------------------------------------


def test_norm_attack_reads_labels_from_undefended_gradients():
    """The Li et al. attack is real: rare-class labels are nearly fully
    recoverable from per-example cut-gradient norms."""
    assert H.norm_attack_auc(_base()) > 0.9


@pytest.mark.parametrize("defense,kw", [
    ("grad_noise", dict(grad_noise_std=0.05)),
    ("grad_unit", dict(grad_norm_mode="unit")),
    ("grad_sign", dict(grad_norm_mode="sign")),
])
def test_each_gradient_defense_blunts_the_norm_attack(defense, kw):
    auc_b = H.norm_attack_auc(_base())
    auc_d = H.norm_attack_auc(_tr(defense, **kw))
    assert auc_d < auc_b - 0.25
    assert auc_d < 0.65


def test_unit_norm_defense_leaves_zero_norm_bits():
    """norm_mode="unit" is the strongest on its own axis: every shipped
    per-example norm is identical, so the attack's AUC is chance up to
    ties."""
    auc = H.norm_attack_auc(_tr("grad_unit", grad_norm_mode="unit"))
    assert auc == pytest.approx(0.5, abs=0.05)


# ---------------------------------------------------------------------------
# transcript sanity: the harness captures what it claims
# ---------------------------------------------------------------------------


def test_transcript_shapes_and_ground_truth_alignment():
    tr = _base()
    assert len(tr.batches) == 6                    # steady steps
    assert set(tr.cuts) == set(tr.features)
    for owner, frames in tr.cuts.items():
        assert len(frames) == 6
        for t, z in frames:
            assert z.shape[0] == len(tr.batches[t])
    assert set(tr.labels.tolist()) <= {0, 1}       # binarized
    assert 0.02 < tr.labels.mean() < 0.3           # rare positives


# ---------------------------------------------------------------------------
# PSI membership inference: hidden mode blunts the scientist-side attack
# ---------------------------------------------------------------------------


def test_hidden_mode_blunts_membership_inference():
    """ISSUE 10: against plaintext-intersection modes the resolved-ID
    list IS a perfect membership oracle; under mode="hidden" the padded
    keep-mask drags the advantage down (decoy false positives), though
    every true member is still kept (documented residual leak)."""
    adv_plain = H.psi_membership_advantage("noinv")
    adv_hidden = H.psi_membership_advantage("hidden")
    assert adv_plain == 1.0
    assert adv_hidden < adv_plain - 0.5
    assert adv_hidden >= 0.0
