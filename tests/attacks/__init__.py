"""Transcript attacks: real adversaries run against tap-captured wire
traffic of real training runs, asserting each defense strictly reduces
the attacker's leakage (see docs/ARCHITECTURE.md, threat model)."""
