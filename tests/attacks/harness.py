"""Transcript-attack harness: capture the full wire transcript of a
real split fit, then attack it the way an honest-but-curious scientist
(or a wire eavesdropper) would.

The attacker model per attack:

* **Model inversion** (`inversion_r2`): the adversary observes an
  owner's cut-activation frames and holds a leaked auxiliary subset of
  that owner's raw rows (half the captured examples).  It fits a ridge
  decoder cut -> raw on the leaked rows and reconstructs the REST.
  Score: held-out R^2 (1 = perfect reconstruction, <= 0 = noise).
* **Distance-correlation leakage** (`dcor_leakage`): no auxiliary data
  at all — the adversary measures statistical dependence between the
  raw batch and the frames on the wire (Szekely dcor, the NoPeek
  metric).  Needs the raw rows only to *score* the leak.
* **Norm-based label inference** (`norm_attack_auc`, Li et al. 2021):
  the adversary observes the cut-gradient frames the scientist ships
  back and predicts the (rare) binary label from per-example gradient
  norms.  Score: AUC (0.5 = chance, 1 = full leak).

Labels are binarized ("is the rare class") so the norm attack faces
the imbalanced setting it exploits in practice.
"""
import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core.privacy import distance_correlation, label_inference_auc
from repro.core.resolution import VerticalDataset
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties, transport
from repro.federation.transport import _unpack, get_codec


@dataclass
class Transcript:
    """Everything a wire observer saw, plus the ground truth needed to
    *score* an attack (never fed to the attacker's fit)."""
    cuts: Dict[str, List[Tuple[int, np.ndarray]]] = field(
        default_factory=dict)       # owner -> [(step, (B, k) float)]
    grads: Dict[str, List[Tuple[int, np.ndarray]]] = field(
        default_factory=dict)       # owner -> [(step, (B, k) float)]
    batches: Dict[int, np.ndarray] = field(default_factory=dict)
    features: Dict[str, np.ndarray] = field(default_factory=dict)
    labels: Optional[np.ndarray] = None
    aggregation: Optional[str] = None


def capture_transcript(*, aggregation=None, cut_noise_std=0.0,
                       grad_noise_std=0.0, grad_norm_mode="none",
                       n=256, steps=6, batch_size=64, seed=0,
                       rare_class=0, compression=None) -> Transcript:
    """Run a real sum-combine split fit on the queue backend with the
    given defenses and capture every serialized frame."""
    captured = []
    orig = transport.channel_pair

    def tapped(a, b, **kw):
        kw["tap"] = lambda msg, blob: captured.append(
            (msg.sender, msg.receiver, msg.kind, msg.seq, blob))
        return orig(a, b, **kw)

    transport.channel_pair = tapped
    try:
        sci_ds, owner_ds = make_vertical_mnist_parties(n, seed=seed,
                                                       keep_frac=0.9)
        # binarize: the rare class (~10% of rows) is the positive —
        # the imbalanced setting the norm attack exploits
        sci_ds = VerticalDataset(
            sci_ds.ids,
            (np.asarray(sci_ds.data) == rare_class).astype(np.int32))
        s = VerticalSession(*feature_parties(sci_ds, owner_ds))
        s.resolve(group="modp512")
        s.build(dataclasses.replace(MNIST_CFG, split=dataclasses.replace(
            MNIST_CFG.split, combine="sum",
            cut_noise_std=cut_noise_std, grad_noise_std=grad_noise_std,
            grad_norm_mode=grad_norm_mode)))
        s.fit(steps=steps, batch_size=batch_size, verbose=False,
              mode="split", backend="queue", aggregation=aggregation,
              compression=compression)
    finally:
        transport.channel_pair = orig

    tr = Transcript(aggregation=aggregation)
    codec = get_codec(compression)
    for sender, receiver, kind, seq, blob in captured:
        payload = _unpack(blob)
        if kind == "head_fwd":
            # the same indices go to every owner; seq == step (M=1)
            tr.batches[seq] = np.asarray(payload["idx"], np.int32)
        elif kind == "cut_activations":
            if "mq" in payload:
                # best-effort float view of the ring element — all an
                # eavesdropper can do with a masked frame
                z = (payload["mq"].view(np.int32).astype(np.float32)
                     * np.float32(2.0 ** -16))
            else:
                z = np.asarray(codec.decode(payload), np.float32)
            tr.cuts.setdefault(sender, []).append((seq, z))
        elif kind == "cut_gradients":
            tr.grads.setdefault(receiver, []).append(
                (seq, np.asarray(codec.decode(payload), np.float32)))
    for o in s.owners:
        tr.features[o.name] = np.asarray(o._features, np.float32)
    tr.labels = np.asarray(s.scientist.labels)
    return tr


def _stacked(tr: Transcript, owner: str):
    """(X raw rows, Z wire frames, y labels) stacked over steady steps."""
    xs, zs, ys = [], [], []
    for t, z in sorted(tr.cuts[owner]):
        idx = tr.batches[t]
        xs.append(tr.features[owner][idx])
        zs.append(np.asarray(z, np.float32))
        ys.append(tr.labels[idx])
    return (np.concatenate(xs), np.concatenate(zs),
            np.concatenate(ys))


def inversion_r2(tr: Transcript, owner: str, *, ridge=1e-2,
                 train_frac=0.5) -> float:
    """Ridge-decoder model inversion with a leaked auxiliary subset."""
    X, Z, _ = _stacked(tr, owner)
    # standardize the wire view so masked uint32 scales don't blow up
    Z = (Z - Z.mean(0)) / np.maximum(Z.std(0), 1e-6)
    Z = np.concatenate([Z, np.ones((len(Z), 1), np.float32)], 1)
    n_tr = int(len(Z) * train_frac)
    Ztr, Xtr, Zte, Xte = Z[:n_tr], X[:n_tr], Z[n_tr:], X[n_tr:]
    A = (Ztr.T @ Ztr).astype(np.float64) + ridge * np.eye(Z.shape[1])
    W = np.linalg.solve(A, (Ztr.T @ Xtr).astype(np.float64))
    err = Xte - Zte @ W
    sse = float(np.sum(err ** 2))
    sst = float(np.sum((Xte - Xtr.mean(0)) ** 2))
    return 1.0 - sse / max(sst, 1e-12)


def dcor_leakage(tr: Transcript, owner: str) -> float:
    """Mean per-step distance correlation between the raw batch and the
    frame on the wire."""
    vals = []
    for t, z in sorted(tr.cuts[owner]):
        x = tr.features[owner][tr.batches[t]]
        vals.append(float(distance_correlation(x, np.asarray(z))))
    return float(np.mean(vals))


def norm_attack_auc(tr: Transcript, owner: Optional[str] = None) -> float:
    """Li et al. norm attack on the captured cut-gradient frames."""
    key = owner if owner is not None else sorted(tr.grads)[0]
    norms, labels = [], []
    for t, g in sorted(tr.grads[key]):
        idx = tr.batches[t]
        norms.append(np.linalg.norm(
            np.asarray(g).reshape(len(idx), -1), axis=1))
        labels.append(tr.labels[idx])
    return label_inference_auc(np.concatenate(norms),
                               np.concatenate(labels))


def psi_membership_advantage(mode: str, *, n=40, members=5,
                             group="modp512", chunk_size=16) -> float:
    """Membership-inference advantage (TPR - FPR) of a scientist-side
    attacker against one resolved PSI round over the queue backend.

    The adversary holds the client's view of the transcript and, for
    each candidate ID it submitted, predicts "in the owner's set" from
    the round's output: under ``noinv``/``bloom`` the resolved IDs are
    the raw intersection, so the attack is perfect (advantage 1.0);
    under ``mode="hidden"`` the client sees only the padded keep-set of
    its own row positions — every true member is kept, but so are
    deterministic decoys, so the false-positive rate rises with the
    padding and the advantage drops strictly below the plaintext modes.
    """
    import threading

    from repro.core.psi import PSIClient, PSIServer
    from repro.federation.psi_transport import (PSIServerEndpoint,
                                                wire_psi_round)

    ids = [f"user-{i}" for i in range(n)]
    truth = set(ids[:members])
    sv_items = sorted(truth) + [f"other-{i}" for i in range(n - members)]
    client = PSIClient(ids, group, mode=mode)
    server = PSIServer(sv_items, group=group)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint("owner0", server, ep_s)
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        inter, _ = wire_psi_round(client, ep_c, worker=worker,
                                  chunk_size=chunk_size)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    if mode == "hidden":
        flagged = {ids[i] for i in inter}   # keep positions incl. decoys
    else:
        flagged = set(inter)                # the raw matched IDs
    tpr = len(flagged & truth) / max(len(truth), 1)
    fpr = len(flagged - truth) / max(n - len(truth), 1)
    return tpr - fpr
