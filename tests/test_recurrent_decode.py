"""Serving-path correctness: prefill(S) + decode(1) must equal the full
forward over S+1 tokens — for every stateful block family (KV-cache
attention, Mamba2 SSD state, mLSTM matrix memory, sLSTM scalar memory).
This is the strongest single invariant of the inference engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import SplitModel

B = 2


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma2-9b", "zamba2-2.7b",
                                  "xlstm-125m", "mixtral-8x7b",
                                  "deepseek-moe-16b", "nemotron-4-15b"])
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, reduced=True).replace(
        compute_dtype="float32", remat=False)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P = cfg.split.n_owners
    S = 32                       # context length (divisible by P)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)

    # Reference: full forward over all S+1 tokens, read logits at the last
    # position of owner0's slice-extended stream.  The decode path routes
    # the new token through owner 0's head at local position S_p, so the
    # comparable full forward is over owner slices where owner 0 holds one
    # extra token.  Build it explicitly:
    S_p = S // P
    owner_tokens = toks[:, :S].reshape(B, P, S_p).transpose(1, 0, 2)
    new_tok = toks[:, S:S + 1]

    # full forward where owner0's slice has the extra token appended:
    ext = np.concatenate(
        [np.concatenate([owner_tokens[0], new_tok], axis=1)[None],
         np.pad(owner_tokens[1:], ((0, 0), (0, 0), (0, 1)))], axis=0)

    def full_logits():
        cut, _, _ = model.heads_forward(params["heads"], jnp.asarray(ext))
        # owner 0's cut activation at the new token's position:
        z = cut[0][:, S_p:S_p + 1]
        # trunk over [combined context, new token] — mirror decode layout
        ctx_cut, _, _ = model.heads_forward(params["heads"],
                                            jnp.asarray(owner_tokens))
        z_ctx = model.combine(ctx_cut)
        z_all = jnp.concatenate([z_ctx, z], axis=1)
        logits, _, _ = model.trunk_forward(params["trunk"], z_all)
        return logits[:, -1]

    ref = full_logits()

    caches = model.cache_init(B, S, n_new=4)
    _, caches = model.prefill(params, {"owner_tokens":
                                       jnp.asarray(owner_tokens)}, caches)
    got, _ = model.decode_step(params, caches, jnp.asarray(new_tok), S, S_p)

    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)


def test_whisper_decode_matches_full_forward():
    cfg = get_config("whisper-tiny", reduced=True).replace(
        compute_dtype="float32", remat=False)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 32
    half = S // 2
    rng = np.random.default_rng(1)
    frames = rng.normal(size=(B, half, cfg.d_frontend)).astype(np.float32)
    dec = rng.integers(0, cfg.vocab, (B, half + 1)).astype(np.int32)

    logits_full, _ = model.forward(
        params, {"frames": jnp.asarray(frames),
                 "tokens": jnp.asarray(dec)})
    ref = logits_full[:, -1]

    caches = model.cache_init(B, S, n_new=4)
    _, caches = model.prefill(
        params, {"frames": jnp.asarray(frames),
                 "tokens": jnp.asarray(dec[:, :half])}, caches)
    got, _ = model.decode_step(params, caches,
                               jnp.asarray(dec[:, half:half + 1]), half, 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
