"""The supervised federation runtime: programmable fault injection
(``federation.faults``), heartbeat liveness + restart budgeting
(``federation.supervisor``), CRC frame integrity, and crash recovery
with bit-identical resume (``fit(supervise=True)``).

The chaos matrix at the bottom is the tentpole's acceptance gate: for
every wire backend x fault kind, a mid-run owner failure must recover
to the *bitwise* fault-free final params.
"""
import json
import threading
import time
import warnings

import numpy as np
import pytest

from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.data import make_vertical_mnist_parties
from repro.federation import (VerticalSession, faults, feature_parties,
                              transport)
from repro.federation.session import _join_or_warn, leak_stats
from repro.federation.supervisor import OwnerFailure, Supervisor
from repro.federation.transport import FrameCorrupt

# ---------------------------------------------------------------------------
# fault plans: the env grammar and the injector semantics
# ---------------------------------------------------------------------------


def test_fault_plan_legacy_round_trip():
    """A one-fault legacy plan serializes byte-identically to the PR 6
    single-shot hook string, and multi-party comma specs round-trip."""
    plan = faults.FaultPlan([faults.Fault("owner0", "crash", "head_fwd")])
    assert plan.to_env() == "owner0:crash_fwd"
    assert faults.FaultPlan.from_env("owner0:crash_fwd") == plan

    multi = faults.FaultPlan([
        faults.Fault("owner0", "crash", "head_fwd"),
        faults.Fault("owner1", "wedge", "psi_blind_chunk"),
    ])
    env = multi.to_env()
    assert env == "owner0:crash_fwd,owner1:wedge_psi"
    assert faults.FaultPlan.from_env(env) == multi


def test_fault_plan_json_round_trip():
    """Plans outside the legacy grammar ride the same env var as json."""
    plan = faults.FaultPlan([
        faults.Fault("owner0", "corrupt_frame", "cut_activations",
                     occurrence=3, gen=0),
        faults.Fault("owner1", "delay", "head_fwd", step=2, delay_s=0.1),
    ])
    env = plan.to_env()
    assert env.startswith("json:")
    json.loads(env[5:])                       # well-formed
    assert faults.FaultPlan.from_env(env) == plan


def test_fault_plan_unknown_legacy_tokens_are_inert():
    plan = faults.FaultPlan.from_env("owner0:nonsense, ,owner1:crash_fwd")
    assert [f.party for f in plan] == ["owner1"]


def test_injector_occurrence_step_and_generation():
    plan = faults.FaultPlan([
        faults.Fault("o", "crash", "k", occurrence=1),        # 2nd match
        faults.Fault("o", "crash", "k2", occurrence=None, step=7),
        faults.Fault("o", "wedge", "k3", gen=1),
    ])
    inj = faults.FaultInjector(plan, "o", generation=0)
    assert inj.actor_fault("k", 0) is None        # occurrence 0: no fire
    assert inj.actor_fault("k", 5) == "crash"     # occurrence 1: fires
    assert inj.actor_fault("k2", 3) is None       # wrong step
    assert inj.actor_fault("k2", 7) == "crash"    # pinned step
    assert inj.actor_fault("k2", 7) == "crash"    # occurrence=None: every
    assert inj.actor_fault("k3", 0) is None       # gen-1 fault, gen-0 view
    inj1 = faults.FaultInjector(plan, "o", generation=1)
    assert inj1.actor_fault("k3", 0) == "wedge"
    assert inj1.actor_fault("k", 5) is None       # gen-0 faults filtered
    other = faults.FaultInjector(plan, "someone-else")
    assert other.actor_fault("k", 5) is None      # party-scoped


def test_corrupt_frame_fault_surfaces_as_crc_failure():
    """An armed corrupt_frame fault flips payload bytes *after* the CRC
    is stamped, so the receiver's integrity check attributes it."""
    plan = faults.FaultPlan([faults.Fault(
        "owner0", "corrupt_frame", "cut_activations", occurrence=0)])
    sci, own = transport.channel_pair("scientist", "owner0",
                                      backend="queue")
    faults.arm_endpoint(own, "owner0", plan=plan)
    own.send("cut_activations", {"x": np.arange(4, dtype=np.float32)},
             seq=0)
    with pytest.raises(FrameCorrupt) as ei:
        sci.recv_kind("cut_activations", timeout=5.0)
    assert ei.value.kind == "cut_activations"
    assert ei.value.sender == "owner0"
    # clean traffic still flows afterwards
    own.send("cut_activations", {"x": np.arange(4, dtype=np.float32)},
             seq=1)
    assert sci.recv_kind("cut_activations", timeout=5.0).seq == 1


def test_corrupt_marker_routed_to_consumer_kind():
    """A corrupt frame of kind A must not blow up a concurrent
    ``recv_kind(B)`` consumer — it is stashed and re-raised for A's
    consumer (then cleared by ``flush_pending``)."""
    plan = faults.FaultPlan([faults.Fault(
        "owner0", "corrupt_frame", "cut_activations", occurrence=0)])
    sci, own = transport.channel_pair("scientist", "owner0",
                                      backend="queue")
    faults.arm_endpoint(own, "owner0", plan=plan)
    own.send("cut_activations", {"x": np.zeros(2, np.float32)}, seq=0)
    own.send("step_done", {}, seq=0)
    assert sci.recv_kind("step_done", timeout=5.0).seq == 0
    with pytest.raises(FrameCorrupt):
        sci.recv_kind("cut_activations", timeout=5.0)
    sci.flush_pending()


# ---------------------------------------------------------------------------
# supervisor: heartbeats, suspicion, restart budget
# ---------------------------------------------------------------------------


def _echo_actor(ep, stop):
    while not stop.is_set():
        try:
            m = ep.recv_kind("heartbeat", timeout=0.05)
        except Exception:
            continue
        ep.send("heartbeat_ack", {}, seq=m.seq)


def test_supervisor_heartbeats_and_wedge_suspicion():
    sci, own = transport.channel_pair("scientist", "owner0",
                                      backend="queue")
    stop = threading.Event()
    th = threading.Thread(target=_echo_actor, args=(own, stop),
                          daemon=True)
    th.start()
    sup = Supervisor(heartbeat_s=0.02, miss_limit=3)
    sup.attach("owner0", sci, None)
    sup.start()
    try:
        time.sleep(0.3)
        assert sup.stats["heartbeats_sent"] >= 3
        assert sup.stats["heartbeat_acks"] >= 1
        assert "owner0" not in sup.failed
        stop.set()                       # wedge: actor stops answering
        deadline = time.monotonic() + 5.0
        while "owner0" not in sup.failed and time.monotonic() < deadline:
            time.sleep(0.02)
        assert "owner0" in sup.failed
        assert "unresponsive" in str(sup.failed["owner0"])
    finally:
        sup.stop()
        stop.set()
        th.join(timeout=5.0)


def test_supervisor_restart_budget_and_backoff():
    sup = Supervisor(max_restarts=2, backoff_base_s=0.01,
                     backoff_cap_s=0.02)
    sup.failed["o"] = RuntimeError("boom")
    d0 = sup.plan_restart("o")
    assert "o" not in sup.failed         # re-adopted
    assert sup.restarts("o") == 1
    d1 = sup.plan_restart("o")
    assert d0 == pytest.approx(0.01) and d1 == pytest.approx(0.02)
    with pytest.raises(RuntimeError, match="restart budget exhausted"):
        sup.plan_restart("o")


def test_join_or_warn_flags_leaked_thread():
    """A thread that outlives its join window is a *loud* leak: a
    RuntimeWarning plus a ``leak_stats`` bump, never a silent hang."""
    ev = threading.Event()
    th = threading.Thread(target=ev.wait, daemon=True, name="wedged")
    th.start()
    before = leak_stats["leaked_threads"]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert _join_or_warn(th, 0.05, "test") is False
    assert leak_stats["leaked_threads"] == before + 1
    assert any("leaked" in str(x.message) for x in w)
    ev.set()
    th.join(timeout=5.0)
    ok = threading.Thread(target=lambda: None)
    ok.start()
    assert _join_or_warn(ok, 5.0, "test") is True


# ---------------------------------------------------------------------------
# the chaos matrix: supervised fit recovers bit-identically
# ---------------------------------------------------------------------------

_STEPS = 6
_REF: dict = {}       # backend -> (param leaves, losses) fault-free


def _split_fit(backend, env=None, *, timeout=15.0, retries=0,
               supervise=True):
    if env:
        with pytest.MonkeyPatch.context() as mp_:
            mp_.setenv(faults.CHAOS_ENV, env)
            return _split_fit_inner(backend, timeout, retries, supervise)
    return _split_fit_inner(backend, timeout, retries, supervise)


def _split_fit_inner(backend, timeout, retries, supervise):
    sci, owners = make_vertical_mnist_parties(300, seed=0, keep_frac=0.9)
    s = VerticalSession(*feature_parties(sci, owners))
    s.resolve(group="modp512", retries=retries)
    s.build(MNIST_CFG)
    h = s.fit(steps=_STEPS, batch_size=64, verbose=False, mode="split",
              backend=backend, supervise=supervise, timeout=timeout)
    import jax
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(s.params)]
    losses = [r["loss"] for r in h["train"]]
    return s, leaves, losses


def _reference(backend):
    if backend not in _REF:
        _, leaves, losses = _split_fit(backend)
        _REF[backend] = (leaves, losses)
    return _REF[backend]


_FIT_FAULTS = {
    "crash_fwd": faults.Fault("owner0", "crash", "head_fwd",
                              occurrence=None, step=3),
    "wedge_fwd": faults.Fault("owner0", "wedge", "head_fwd",
                              occurrence=None, step=3),
    "corrupt_frame": faults.Fault("owner0", "corrupt_frame",
                                  "cut_activations", occurrence=4),
}


@pytest.mark.parametrize("backend", ["queue", "process"])
@pytest.mark.parametrize("fault", sorted(_FIT_FAULTS))
def test_chaos_matrix_fit_recovers_bit_identically(backend, fault):
    ref_leaves, ref_losses = _reference(backend)
    env = faults.FaultPlan([_FIT_FAULTS[fault]]).to_env()
    timeout = 3.0 if fault == "wedge_fwd" else 15.0
    s, leaves, losses = _split_fit(backend, env, timeout=timeout)
    assert s.recovery_events, "fault never fired / never recovered"
    ev = s.recovery_events[-1]
    assert ev["party"] == "owner0"
    assert ev["action"] == ("rollback" if fault == "corrupt_frame"
                            else "respawn")
    assert losses == ref_losses
    assert len(leaves) == len(ref_leaves)
    for a, b in zip(leaves, ref_leaves):
        np.testing.assert_array_equal(a, b)
    assert s.transport_stats["recoveries"] == len(s.recovery_events)
    sup_stats = s.transport_stats["supervisor"]
    assert sup_stats is not None and sup_stats["heartbeats_sent"] >= 0


_MASKED_REF: dict = {}


def _masked_fit(backend, env=None, *, timeout=15.0):
    """Supervised masked-sum split fit (sum-combine config), optionally
    under a chaos plan."""
    import dataclasses

    import jax

    def inner():
        sci, owners = make_vertical_mnist_parties(300, seed=0,
                                                  keep_frac=0.9)
        s = VerticalSession(*feature_parties(sci, owners))
        s.resolve(group="modp512")
        s.build(dataclasses.replace(MNIST_CFG, split=dataclasses.replace(
            MNIST_CFG.split, combine="sum")))
        h = s.fit(steps=_STEPS, batch_size=64, verbose=False,
                  mode="split", backend=backend, supervise=True,
                  aggregation="masked_sum", timeout=timeout)
        leaves = [np.asarray(x)
                  for x in jax.tree_util.tree_leaves(s.params)]
        return s, leaves, [r["loss"] for r in h["train"]]

    if env:
        with pytest.MonkeyPatch.context() as mp_:
            mp_.setenv(faults.CHAOS_ENV, env)
            return inner()
    return inner()


@pytest.mark.parametrize("backend", ["queue", "process"])
def test_chaos_masked_sum_recovers_bit_identically(backend):
    """A mid-run owner crash during a masked-sum fit must recover to
    the bitwise fault-free result: the respawned owner (generation 1)
    re-derives the same steady-state masks (tags are generation-
    agnostic) so replayed frames still cancel against the survivor."""
    if backend not in _MASKED_REF:
        _, leaves, losses = _masked_fit(backend)
        _MASKED_REF[backend] = (leaves, losses)
    ref_leaves, ref_losses = _MASKED_REF[backend]
    env = faults.FaultPlan([faults.Fault(
        "owner0", "crash", "head_fwd", occurrence=None, step=3)]).to_env()
    s, leaves, losses = _masked_fit(backend, env)
    assert s.recovery_events, "fault never fired / never recovered"
    assert s.recovery_events[-1]["action"] == "respawn"
    assert losses == ref_losses
    for a, b in zip(leaves, ref_leaves):
        np.testing.assert_array_equal(a, b)
    assert s.transport_stats["aggregation"] == "masked_sum"


@pytest.mark.parametrize("backend", ["queue", "process"])
def test_chaos_matrix_psi_crash_retries(backend):
    """crash_psi: the owner's PSI worker dies on the first blind chunk;
    ``resolve(retries=1)`` respawns it at generation 1 (where the gen-0
    fault is inert) and the intersection matches the fault-free run."""
    clean = VerticalSession(*feature_parties(
        *make_vertical_mnist_parties(200, seed=0, keep_frac=0.8)))
    clean.resolve(group="modp512")

    env = "owner0:crash_psi"            # legacy single-shot grammar
    with pytest.MonkeyPatch.context() as mp_:
        mp_.setenv(faults.CHAOS_ENV, env)
        s = VerticalSession(*feature_parties(
            *make_vertical_mnist_parties(200, seed=0, keep_frac=0.8)))
        with pytest.raises(RuntimeError):
            s.resolve(group="modp512", backend=backend,
                      timeout=60.0)              # no retries: surfaces
        s2 = VerticalSession(*feature_parties(
            *make_vertical_mnist_parties(200, seed=0, keep_frac=0.8)))
        s2.resolve(group="modp512", backend=backend, retries=1,
                   timeout=60.0)
    assert any(e["action"] == "psi_retry" for e in s2.recovery_events)
    assert s2.scientist.ids == clean.scientist.ids


def test_supervise_requires_wire_backend():
    sci, owners = make_vertical_mnist_parties(60, seed=0)
    s = VerticalSession(*feature_parties(sci, owners))
    s.resolve(group="modp512")
    s.build(MNIST_CFG)
    with pytest.raises(ValueError, match="supervise"):
        s.fit(steps=1, batch_size=16, verbose=False, mode="split",
              backend="direct", supervise=True)
    with pytest.raises(ValueError, match="supervise"):
        s.fit(steps=1, batch_size=16, verbose=False, supervise=True)


def test_restart_budget_exhaustion_surfaces():
    """A party that keeps crashing (occurrence=None, gen=None — every
    generation) burns the restart budget and fails loudly."""
    env = faults.FaultPlan([faults.Fault(
        "owner0", "crash", "head_fwd", occurrence=None, step=3,
        gen=None)]).to_env()
    with pytest.MonkeyPatch.context() as mp_:
        mp_.setenv(faults.CHAOS_ENV, env)
        sci, owners = make_vertical_mnist_parties(300, seed=0,
                                                  keep_frac=0.9)
        s = VerticalSession(*feature_parties(sci, owners))
        s.resolve(group="modp512")
        s.build(MNIST_CFG)
        with pytest.raises(RuntimeError,
                           match="restart budget exhausted"):
            s.fit(steps=_STEPS, batch_size=64, verbose=False,
                  mode="split", backend="queue", supervise=True,
                  timeout=15.0, max_restarts=1)


# ---------------------------------------------------------------------------
# checkpoint -> restore round-trip (recovery across process lifetimes)
# ---------------------------------------------------------------------------


def test_checkpoint_restore_resume_round_trip(tmp_path):
    sci, owners = make_vertical_mnist_parties(300, seed=0, keep_frac=0.9)
    donor = VerticalSession(*feature_parties(sci, owners))
    donor.resolve(group="modp512")
    donor.build(MNIST_CFG)
    donor.fit(steps=6, batch_size=64, eval_frac=0.2, verbose=False,
              mode="split", backend="queue")
    step_dir = donor.checkpoint(str(tmp_path), step=6)
    donor_eval = donor.evaluate()

    sci2, owners2 = make_vertical_mnist_parties(300, seed=0,
                                                keep_frac=0.9)
    resumed = VerticalSession(*feature_parties(sci2, owners2))
    resumed.resolve(group="modp512")
    resumed.build(MNIST_CFG)
    resumed.restore(step_dir)
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(resumed.params),
                    jax.tree_util.tree_leaves(donor.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    h = resumed.fit(steps=2, batch_size=64, eval_frac=0.2, verbose=False,
                    mode="split", backend="queue")
    # loss continuity: training picks up from the restored point, not a
    # re-init cliff — the first resumed step's loss sits near the
    # donor's last, and the restored params evaluate like the donor's
    first_resumed = h["train"][0]["loss"]
    assert first_resumed == pytest.approx(
        donor.history["train"][-1]["loss"], rel=0.35)
    resumed_eval = resumed.evaluate()
    assert set(resumed_eval) == set(donor_eval)


def test_restore_requires_built():
    sci, owners = make_vertical_mnist_parties(60, seed=0)
    s = VerticalSession(*feature_parties(sci, owners))
    with pytest.raises(RuntimeError):
        s.restore("/nonexistent")


@pytest.mark.parametrize("backend", ["queue", "process"])
def test_psi_retry_wire_accounting_and_cache_hygiene(backend):
    """ISSUE 10 regression: a crashed PSI attempt must not (a) fold its
    bytes into ``per_party_wire`` — only the verified attempt is
    measured — or (b) leave the failed generation's entries in any
    blind/response cache: the post-retry repeat resolve is still the
    O(hello) cached fast path and stays exact."""
    def build():
        return VerticalSession(*feature_parties(
            *make_vertical_mnist_parties(200, seed=0, keep_frac=0.8)))

    clean = build()
    st_clean = clean.resolve(group="modp512", backend=backend,
                             timeout=60.0)

    with pytest.MonkeyPatch.context() as mp_:
        mp_.setenv(faults.CHAOS_ENV, "owner0:crash_psi")
        s = build()
        st = s.resolve(group="modp512", backend=backend, retries=1,
                       timeout=60.0)
    assert any(e["action"] == "psi_retry" for e in s.recovery_events)
    assert s.scientist.ids == clean.scientist.ids
    # (a) per-party totals equal the fault-free run's: the crashed
    # generation's traffic is not double-counted into the retry's
    for name, wire in st["per_party_wire"].items():
        ref = st_clean["per_party_wire"][name]
        assert wire["sent_wire_bytes"] == ref["sent_wire_bytes"]
        assert wire["recv_wire_bytes"] == ref["recv_wire_bytes"]
        assert wire["messages"] == ref["messages"]
    # (b) cache hygiene: with chaos disarmed, the next resolve rides the
    # caches the *verified* attempt wrote — no re-upload, no stale tags
    st2 = s.resolve(group="modp512", backend=backend, timeout=60.0)
    for r in st2["rounds"]:
        assert r["upload_skipped"] and r["server_leg_skipped"]
        assert r["upload_wire_bytes"] == 0
    assert s.scientist.ids == clean.scientist.ids
