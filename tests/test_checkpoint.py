"""Per-party checkpointing: roundtrips and VFL isolation (one file per
party, owners never serialize each other's segments)."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt


def _params():
    key = jax.random.PRNGKey(0)
    heads = {"w": jax.random.normal(key, (2, 8, 4)),
             "blocks": [{"s": jnp.ones((2, 3))}, {"s": jnp.zeros((2, 3))}]}
    trunk = {"w": jax.random.normal(key, (8, 10)), "b": jnp.zeros((10,))}
    return {"heads": heads, "trunk": trunk}


def test_save_restore_roundtrip(tmp_path):
    p = _params()
    path = os.path.join(tmp_path, "tree.npz")
    ckpt.save(path, p)
    r = ckpt.restore(path)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), b)
    assert isinstance(r["heads"]["blocks"], list)


def test_split_checkpoint_per_party(tmp_path):
    p = _params()
    d = ckpt.save_split(str(tmp_path), p, step=7)
    files = sorted(os.listdir(d))
    assert files == ["owner0.npz", "owner1.npz", "trunk.npz"]
    r = ckpt.restore_split(d)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_owner_file_contains_only_own_segment(tmp_path):
    p = _params()
    d = ckpt.save_split(str(tmp_path), p, step=0)
    o0 = ckpt.restore(os.path.join(d, "owner0.npz"))
    np.testing.assert_array_equal(o0["w"], np.asarray(p["heads"]["w"][0]))
    # owner 0's file must NOT contain owner 1's weights
    assert not np.array_equal(o0["w"], np.asarray(p["heads"]["w"][1]))
