"""Optimizer substrate: reference-implementation equivalence + transforms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adam, apply_updates, chain, clip_by_global_norm,
                         constant, multi_segment, sgd, warmup_cosine)


def _tree():
    return {"a": jnp.ones((3, 2)), "b": jnp.full((4,), 2.0)}


def test_sgd_matches_formula():
    opt = sgd(0.1)
    p = _tree()
    g = jax.tree.map(jnp.ones_like, p)
    u, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(u["a"], -0.1 * np.ones((3, 2)), rtol=1e-6)


def test_adam_matches_numpy_reference():
    lr, b1, b2, eps = 1e-2, 0.9, 0.999, 1e-8
    opt = adam(lr, b1, b2, eps)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    state = opt.init(p)
    m = v = np.zeros(3)
    w = np.array([1.0, -2.0, 3.0])
    rng = np.random.default_rng(0)
    for t in range(1, 6):
        g = rng.normal(size=3).astype(np.float32)
        u, state = opt.update({"w": jnp.asarray(g)}, state, p, t - 1)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        ref = -lr * (m / (1 - b1 ** t)) / (np.sqrt(v / (1 - b2 ** t)) + eps)
        np.testing.assert_allclose(np.asarray(u["w"]), ref,
                                   rtol=2e-4, atol=1e-7)
        p = apply_updates(p, u)
        w = w + ref
    np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-3)


def test_clip_by_global_norm():
    opt = chain(clip_by_global_norm(1.0), sgd(1.0))
    p = _tree()
    g = jax.tree.map(lambda x: 100.0 * jnp.ones_like(x), p)
    u, _ = opt.update(g, opt.init(p), p, 0)
    gnorm = np.sqrt(sum(np.sum(np.square(np.asarray(x)))
                        for x in jax.tree.leaves(u)))
    np.testing.assert_allclose(gnorm, 1.0, rtol=1e-5)


def test_small_grads_not_clipped():
    opt = clip_by_global_norm(1e9)
    p = _tree()
    g = jax.tree.map(jnp.ones_like, p)
    u, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(u["a"], g["a"], rtol=1e-6)


def test_multi_segment_independent_updates():
    opt = multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})
    p = {"heads": {"w": jnp.ones(3)}, "trunk": {"w": jnp.ones(3)}}
    g = jax.tree.map(jnp.ones_like, p)
    u, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(u["heads"]["w"], -0.01 * np.ones(3), rtol=1e-6)
    np.testing.assert_allclose(u["trunk"]["w"], -0.1 * np.ones(3), rtol=1e-6)


def test_warmup_cosine_schedule():
    s = warmup_cosine(1.0, 10, 110, final_frac=0.1)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(10)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(s(110)), 0.1, rtol=1e-4)
    assert float(s(5)) == pytest.approx(0.5)


def test_adam_weight_decay():
    opt = adam(0.1, weight_decay=0.1)
    p = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}
    u, _ = opt.update(g, opt.init(p), p, 0)
    np.testing.assert_allclose(np.asarray(u["w"]), [-0.1 * 0.1 * 10.0],
                               rtol=1e-5)
