"""True split execution: the transport layer (channels, wire format,
latency model), cut-payload codecs + the Pallas quantize kernel, the
pipelined/sequential split schedules' gradient equivalence against the
joint autodiff oracle, measured-vs-analytic traffic reconciliation, and
transport-backed serving."""
import time

import jax
import numpy as np
import pytest

from repro.testing.hypo import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core.splitnn import cut_layer_traffic
from repro.core.vertical import (partition_features, partition_sequence,
                                 unpartition)
from repro.data import make_token_dataset, make_vertical_mnist_parties
from repro.federation import (VerticalSession, feature_parties,
                              sequence_parties, transport)
from repro.federation.transport import _pack, _unpack

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# wire format and channels
# ---------------------------------------------------------------------------


def test_wire_format_round_trips_exactly():
    import ml_dtypes
    payload = {
        "f32": RNG.normal(size=(7, 33)).astype(np.float32),
        "i8": RNG.integers(-127, 127, (5, 4, 3)).astype(np.int8),
        "idx": np.arange(11, dtype=np.int32),
        "scalar": np.float32(3.5),
        # LM cut activations are bfloat16 — the wire format must carry
        # ml_dtypes extension types (dtype.name, not dtype.str)
        "bf16": RNG.normal(size=(4, 8)).astype(ml_dtypes.bfloat16),
    }
    back = _unpack(_pack(payload))
    assert set(back) == set(payload)
    for k in payload:
        assert back[k].dtype == np.asarray(payload[k]).dtype
        np.testing.assert_array_equal(
            back[k].astype(np.float32), payload[k].astype(np.float32))


@pytest.mark.parametrize("backend", ["queue", "direct"])
def test_channel_accounting_and_fifo(backend):
    a, b = transport.channel_pair("sci", "owner", backend=backend)
    x = RNG.normal(size=(16, 8)).astype(np.float32)
    a.send("head_fwd", {"idx": np.arange(4, dtype=np.int32)}, seq=0)
    a.send("cut_gradients", {"g": x}, seq=0)
    m0 = b.recv()
    m1 = b.recv()
    assert (m0.kind, m1.kind) == ("head_fwd", "cut_gradients")  # FIFO
    np.testing.assert_array_equal(m1.payload["g"], x)
    # measured bytes: the payload count is exactly the array buffers
    assert m1.payload_bytes == x.nbytes
    if backend == "queue":
        assert m1.wire_bytes > m1.payload_bytes        # + headers
    else:
        assert m1.wire_bytes == m1.payload_bytes
    st_ = a.sent_stats
    assert st_["messages"] == 2
    assert st_["by_kind"]["cut_gradients"]["payload_bytes"] == x.nbytes


def test_recv_kind_stashes_out_of_order_messages():
    a, b = transport.channel_pair("sci", "owner", backend="direct")
    a.send("cut_activations", {"x": np.zeros(3, np.float32)}, seq=7)
    a.send("barrier_ack", {}, seq=-1)
    ack = b.recv_kind("barrier_ack")           # skips past the cut message
    assert ack.seq == -1
    cut = b.recv_kind("cut_activations")       # stashed, not lost
    assert cut.seq == 7


def test_queue_latency_delays_delivery():
    a, b = transport.channel_pair("sci", "owner", backend="queue",
                                  latency_s=0.05)
    t0 = time.monotonic()
    a.send("head_fwd", {"idx": np.arange(2)}, seq=0)
    b.recv()
    assert time.monotonic() - t0 >= 0.045


def test_bandwidth_models_transit_time():
    # 40 KB at 1 MB/s ~= 40 ms of transit
    a, b = transport.channel_pair("sci", "owner", backend="queue",
                                  bandwidth_bps=1e6)
    t0 = time.monotonic()
    a.send("cut_activations",
           {"x": np.zeros((100, 100), np.float32)}, seq=0)
    b.recv()
    assert time.monotonic() - t0 >= 0.03


# ---------------------------------------------------------------------------
# codecs and the Pallas quantize kernel
# ---------------------------------------------------------------------------


def test_codec_round_trips_and_ratios():
    x = RNG.normal(size=(64, 64)).astype(np.float32)
    none = transport.get_codec(None)
    np.testing.assert_array_equal(none.decode(none.encode(x)), x)

    fp16 = transport.get_codec("fp16")
    enc = fp16.encode(x)
    assert sum(a.nbytes for a in enc.values()) == x.nbytes // 2
    assert np.abs(fp16.decode(enc) - x).max() < 2e-3

    int8 = transport.get_codec("int8")
    enc = int8.encode(x)
    nbytes = sum(a.nbytes for a in enc.values())
    assert x.nbytes / nbytes >= 3.0                 # >=3x smaller payload
    # per-row scale bounds the dequantization error
    row_max = np.abs(x).max(-1, keepdims=True)
    assert (np.abs(int8.decode(enc) - x) <= row_max / 127.0 + 1e-7).all()

    with pytest.raises(ValueError, match="unknown compression"):
        transport.get_codec("zstd")


def test_quantize_kernel_matches_ref():
    from repro.kernels.quantize import quantize_int8, quantize_int8_ref
    for shape in ((8, 64), (130, 64), (1, 128)):    # incl. padded grids
        x = RNG.normal(size=shape).astype(np.float32) * 3.0
        q, s = quantize_int8(x, interpret=True)
        qr, sr = quantize_int8_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                                   rtol=1e-6)
        assert np.asarray(q).dtype == np.int8


# ---------------------------------------------------------------------------
# uneven vertical partitions (core/vertical.py)
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(1, 7), min_size=1, max_size=5),
       st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_uneven_partition_round_trips(sizes, seed):
    rng = np.random.default_rng(seed)
    width = sum(sizes)
    x = rng.normal(size=(5, width)).astype(np.float32)
    slices = partition_features(x, sizes)
    assert [s.shape[-1] for s in slices] == list(sizes)
    np.testing.assert_array_equal(unpartition(slices), x)
    t = rng.integers(0, 100, size=(3, width))
    tslices = partition_sequence(t, sizes)
    assert [s.shape[1] for s in tslices] == list(sizes)
    np.testing.assert_array_equal(unpartition(tslices, axis=1), t)


def test_uneven_partition_validation():
    x = np.zeros((2, 10))
    with pytest.raises(ValueError, match="not divisible"):
        partition_features(x, 3)
    with pytest.raises(ValueError, match="sum to"):
        partition_features(x, (4, 4))
    with pytest.raises(ValueError, match="positive"):
        partition_sequence(x, (11, -1))
    # explicit sizes match the equal split
    np.testing.assert_array_equal(
        np.stack(partition_features(x, (5, 5))),
        np.stack(partition_features(x, 2)))


# ---------------------------------------------------------------------------
# split execution: gradient equivalence against the joint oracle
# ---------------------------------------------------------------------------


def _mnist_session(n=400):
    sci, owners = make_vertical_mnist_parties(n, seed=0, keep_frac=0.9)
    session = VerticalSession(*feature_parties(sci, owners))
    session.resolve(group="modp512")
    session.build(MNIST_CFG)
    return session


def _params_equal(p1, p2):
    return all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))


def test_split_matches_joint_bit_for_bit():
    """fit(mode="split") — pipelined AND sequential, uncompressed queue
    transport — reproduces the joint autodiff path's params bit-for-bit
    after K steps (the ISSUE's acceptance bar)."""
    joint = _mnist_session()
    h_joint = joint.fit(epochs=2, batch_size=64, eval_frac=0.1,
                        verbose=False)
    for sched in ("pipelined", "sequential"):
        split = _mnist_session()
        h_split = split.fit(epochs=2, batch_size=64, eval_frac=0.1,
                            verbose=False, mode="split", schedule=sched)
        assert _params_equal(joint.params, split.params), \
            f"{sched} split params diverged from the joint oracle"
        assert (h_split["final"]["val_accuracy"]
                == h_joint["final"]["val_accuracy"])
        steps_per_epoch = (len(split._train_idx) - 64) // 64 + 1
        assert split.transport_stats["steps"] == 2 * steps_per_epoch
        assert split.transport_stats["total_payload_bytes"] > 0


def test_split_fp16_stays_within_tolerance():
    joint = _mnist_session()
    joint.fit(epochs=2, batch_size=64, verbose=False)
    split = _mnist_session()
    split.fit(epochs=2, batch_size=64, verbose=False, mode="split",
              compression="fp16")
    diffs = [np.abs(np.asarray(a) - np.asarray(b)).max()
             for a, b in zip(jax.tree.leaves(joint.params),
                             jax.tree.leaves(split.params))]
    assert 0 < max(diffs) < 5e-2       # lossy but close


def test_int8_compression_cuts_measured_bytes_3x():
    base = _mnist_session()
    base.fit(epochs=1, batch_size=64, verbose=False, mode="split")
    comp = _mnist_session()
    h = comp.fit(epochs=1, batch_size=64, verbose=False, mode="split",
                 compression="int8")
    ratio = (base.transport_stats["total_payload_bytes"]
             / comp.transport_stats["total_payload_bytes"])
    assert ratio >= 3.0
    assert np.isfinite(h["final"]["loss"])


def test_measured_bytes_match_analytic_estimate():
    """The transport backend's measured per-step cut bytes equal the
    ``cut_layer_traffic`` analytic estimate for the MNIST config."""
    session = _mnist_session()
    session.fit(epochs=1, batch_size=64, verbose=False, mode="split")
    steps = session.transport_stats["steps"]
    analytic = cut_layer_traffic(
        n_owners=len(session.owners), batch=64, tokens_per_owner=1,
        cut_dim=session.adapter.model.k, bytes_per_el=4)  # f32 wire
    for owner in session.owners:
        per = session.transport_stats["per_owner"][owner.name]
        assert per["cut_payload_bytes"] == \
            analytic["per_owner_forward_bytes"] * steps
        assert per["grad_payload_bytes"] == \
            analytic["per_owner_backward_bytes"] * steps
    assert session.transport_stats["total_payload_bytes"] == \
        analytic["total_per_step_bytes"] * steps
    # the transcript now records MEASURED traffic for split sessions
    cuts = [m for m in session.transcript
            if m["kind"] == "cut_activations" and m.get("measured")]
    assert len(cuts) == len(session.owners)
    assert all(m["per_step_bytes"]
               == analytic["per_owner_forward_bytes"] for m in cuts)


def test_split_mode_guardrails():
    session = _mnist_session()
    with pytest.raises(ValueError, match="mode"):
        session.fit(epochs=1, batch_size=64, mode="telepathy")
    with pytest.raises(ValueError, match="schedule"):
        session.fit(epochs=1, batch_size=64, mode="split",
                    schedule="warp")
    with pytest.raises(ValueError, match="backend"):
        session.fit(epochs=1, batch_size=64, mode="split",
                    backend="carrier-pigeon")


def test_split_lm_training_smoke():
    """Sequence-split LM trains in split mode over the queue transport;
    loss tracks the joint path within tolerance (per-owner clipping and
    the f32 wire keep it close but not bitwise)."""
    cfg = get_config("llama3.2-3b", reduced=True)
    toks = make_token_dataset(16, 32, cfg.vocab, 0)
    split = VerticalSession(*sequence_parties(toks, cfg.split.n_owners))
    split.resolve(group="modp512")
    split.build(cfg)
    h = split.fit(steps=3, batch_size=4, verbose=False, mode="split")
    assert np.isfinite(h["final"]["loss"])
    joint = VerticalSession(*sequence_parties(toks, cfg.split.n_owners))
    joint.resolve(group="modp512")
    joint.build(cfg)
    hj = joint.fit(steps=3, batch_size=4, verbose=False)
    assert abs(h["final"]["loss"] - hj["final"]["loss"]) < 5e-2
    # the lossless codec ships the model's own cut dtype (bf16): the
    # measured bytes are the bf16 analytic estimate + the 4-byte aux
    # scalar riding along per step
    analytic = cut_layer_traffic(
        n_owners=cfg.split.n_owners, batch=4,
        tokens_per_owner=32 // cfg.split.n_owners,
        cut_dim=split.adapter.model.k, bytes_per_el=2)
    for v in split.transport_stats["per_owner"].values():
        assert v["cut_payload_bytes"] == \
            (analytic["per_owner_forward_bytes"] + 4) * 3


# ---------------------------------------------------------------------------
# transport-backed serving (measured cut bytes, not analytic)
# ---------------------------------------------------------------------------


def test_serving_through_transport_measures_cut_bytes():
    cfg = get_config("llama3.2-3b", reduced=True)
    toks = make_token_dataset(4, 16, cfg.vocab, 0)[:, :16]

    def serve(transport_backend):
        session = VerticalSession(*sequence_parties(
            toks, cfg.split.n_owners, with_labels=False))
        session.resolve(group="modp512")
        session.build(cfg)
        return session.serve_dataset(max_new=3, batch_slots=4,
                                     transport=transport_backend)

    results, engine = serve("direct")
    baseline, engine0 = serve(None)
    queued, _ = serve("queue")         # serialized wire (bf16 cut tensors)
    # identical generations through the channel vs the fused program
    for rid in results:
        assert results[rid].generated == baseline[rid].generated
        assert queued[rid].generated == baseline[rid].generated
    assert engine0.stats["cut_payload_bytes"] == 0
    st_ = engine.stats
    assert st_["cut_payload_bytes"] > 0
    assert st_["cut_wire_bytes"] >= st_["cut_payload_bytes"]
    # one wave: prefill ships P cut slices, then one per decode step
    assert st_["waves"] == 1
    assert st_["cut_messages"] == cfg.split.n_owners + (3 - 1)


# ---------------------------------------------------------------------------
# microbatch pipelining (GPipe): bit-for-bit vs the microbatched oracle
# ---------------------------------------------------------------------------


@given(st.sampled_from([2, 4]), st.integers(0, 3))
@settings(max_examples=3, deadline=None)
def test_microbatched_split_matches_microbatched_oracle(micro, seed):
    """fit(mode="split", microbatches=M) — M GPipe cut exchanges in
    flight per channel — reproduces the microbatched joint oracle
    (fit(mode="joint", microbatches=M)) bit-for-bit: same per-chunk
    programs, grads accumulated in chunk order at step-start params,
    one update per party per step (the ISSUE's acceptance bar)."""
    oracle = _mnist_session(320)
    h_o = oracle.fit(epochs=1, batch_size=64, eval_frac=0.1,
                     verbose=False, microbatches=micro,
                     shuffle_seed=seed)
    split = _mnist_session(320)
    h_s = split.fit(epochs=1, batch_size=64, eval_frac=0.1,
                    verbose=False, mode="split", schedule="pipelined",
                    microbatches=micro, shuffle_seed=seed)
    assert _params_equal(oracle.params, split.params), \
        f"microbatched split diverged from the oracle (M={micro})"
    assert h_s["final"]["loss"] == h_o["final"]["loss"]
    assert h_s["final"]["accuracy"] == h_o["final"]["accuracy"]
    # M chunks per step per direction on the wire
    steps = split.transport_stats["steps"]
    for per in split.transport_stats["per_owner"].values():
        # head_fwd + warmup round + M cut/grad chunks per step
        assert per["cut_payload_bytes"] > 0
    assert split.transport_stats["microbatches"] == micro


def test_microbatched_oracle_tracks_fused_joint():
    """GPipe chunk accumulation is the same math as the one-shot batch
    step — different rounding (chunked reductions), tiny param drift."""
    fused = _mnist_session(320)
    fused.fit(epochs=1, batch_size=64, verbose=False)
    oracle = _mnist_session(320)
    oracle.fit(epochs=1, batch_size=64, verbose=False, microbatches=4)
    diffs = [np.abs(np.asarray(a) - np.asarray(b)).max()
             for a, b in zip(jax.tree.leaves(fused.params),
                             jax.tree.leaves(oracle.params))]
    assert 0 < max(diffs) < 1e-4


def test_microbatch_validation():
    session = _mnist_session(320)
    with pytest.raises(ValueError, match="divide"):
        session.fit(epochs=1, batch_size=64, microbatches=3,
                    verbose=False)
    with pytest.raises(ValueError, match="pipelined"):
        session.fit(epochs=1, batch_size=64, mode="split",
                    schedule="sequential", microbatches=2, verbose=False)
    with pytest.raises(ValueError, match="microbatches"):
        session.fit(epochs=1, batch_size=64, microbatches=0,
                    verbose=False)


def test_int8_microbatched_split_trains():
    """Compression composes with microbatch pipelining: the codec sees
    per-chunk payloads and the run still converges sanely."""
    s = _mnist_session(320)
    h = s.fit(epochs=1, batch_size=64, verbose=False, mode="split",
              microbatches=2, compression="int8")
    assert np.isfinite(h["final"]["loss"])
    ratio = (s.cut_traffic(64)["total_per_step_bytes"]
             / s.transport_stats["total_payload_bytes_per_step"])
    assert ratio >= 3.0


# ---------------------------------------------------------------------------
# transport error paths
# ---------------------------------------------------------------------------


def test_bf16_payload_round_trips_over_queue_backend():
    """LM cut tensors are bf16 — the queue backend's wire frame must
    preserve the extension dtype end to end, payload-accounted at
    2 bytes/el."""
    import ml_dtypes
    a, b = transport.channel_pair("sci", "owner", backend="queue")
    x = RNG.normal(size=(6, 5, 8)).astype(ml_dtypes.bfloat16)
    a.send("cut_activations", {"x": x}, seq=3)
    m = b.recv()
    assert m.payload["x"].dtype == x.dtype
    assert m.payload_bytes == x.size * 2
    np.testing.assert_array_equal(m.payload["x"].astype(np.float32),
                                  x.astype(np.float32))


def test_protocol_desync_raises(monkeypatch):
    """An owner that ships a wrong-sequence cut chunk must fail the fit
    loudly (protocol desync), not silently misalign gradients."""
    from repro.federation.parties import OwnerComputeEndpoint

    real_ship = OwnerComputeEndpoint._ship_cut

    def corrupt(self, out, seq, kind="cut_activations"):
        if kind != "cut_activations":
            return real_ship(self, out, seq, kind)
        return real_ship(self, out, seq + 1 if seq >= 1 else seq)

    monkeypatch.setattr(OwnerComputeEndpoint, "_ship_cut", corrupt)
    session = _mnist_session(320)
    with pytest.raises(RuntimeError, match="desync"):
        session.fit(epochs=1, batch_size=64, verbose=False, mode="split")


def test_owner_thread_exception_surfaces(monkeypatch):
    """A crash on an owner's thread surfaces as the fit's RuntimeError
    (with the owner named), via the recv poll — not a 120 s timeout."""
    from repro.federation.parties import OwnerComputeEndpoint

    def boom(self, step, first_out=None):
        raise ValueError("owner-side kaboom")

    monkeypatch.setattr(OwnerComputeEndpoint, "_run_fwd", boom)
    session = _mnist_session(320)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="owner worker"):
        session.fit(epochs=1, batch_size=64, verbose=False, mode="split")
    assert time.monotonic() - t0 < 60.0


def test_quantize_pack_kernel_matches_ref():
    """The fused quantize+pack kernel emits the exact wire frame of the
    reference (int8 values bit-exact; packed f32 scales within float
    tolerance of the jnp oracle)."""
    from repro.kernels.quantize import (quantize_int8_ref,
                                        quantize_pack_int8,
                                        unpack_int8_ref)
    for shape in ((8, 64), (130, 64), (1, 128)):
        x = RNG.normal(size=shape).astype(np.float32) * 3.0
        packed = np.asarray(quantize_pack_int8(x, interpret=True))
        assert packed.shape == (shape[0], shape[1] + 4)
        assert packed.dtype == np.uint8
        q, s = unpack_int8_ref(packed)
        qr, sr = quantize_int8_ref(x)
        np.testing.assert_array_equal(q, np.asarray(qr))
        np.testing.assert_allclose(s[:, 0], np.asarray(sr)[:, 0],
                                   rtol=1e-6)
