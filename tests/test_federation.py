"""The party-centric federation API: batching round-trips, the
party-visibility contract, registry dispatch, and the full
resolve -> build -> fit session round-trip (claim C2 through the facade).
"""
import numpy as np
import pytest

from repro.testing.hypo import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core.vertical import (partition_features, partition_sequence,
                                 unpartition)
from repro.data import make_token_dataset, make_vertical_mnist_parties
from repro.federation import (DataOwner, DataScientist, PrivacyError,
                              VerticalSession, batching, build_adapter,
                              feature_parties, sequence_parties)

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# batching: one module, three layouts, all round-trip against core/vertical
# ---------------------------------------------------------------------------


@given(st.integers(1, 16), st.integers(1, 12), st.sampled_from([1, 2, 4]))
@settings(max_examples=25, deadline=None)
def test_feature_layout_round_trips(batch, width_per_owner, n_owners):
    x = RNG.normal(size=(batch, width_per_owner * n_owners))
    slices = partition_features(x, n_owners)
    stacked = batching.stack_feature_slices(slices)
    assert stacked.shape == (n_owners, batch, width_per_owner)
    np.testing.assert_array_equal(np.stack(slices), stacked)
    back = unpartition(batching.unstack_feature_slices(stacked), axis=-1)
    np.testing.assert_array_equal(back, x)


@given(st.integers(1, 8), st.integers(1, 16), st.sampled_from([1, 2, 4]))
@settings(max_examples=25, deadline=None)
def test_sequence_layout_round_trips(batch, s_per_owner, n_owners):
    toks = RNG.integers(0, 1000, (batch, s_per_owner * n_owners))
    ot = batching.sequence_owner_slices(toks, n_owners)
    assert ot.shape == (n_owners, batch, s_per_owner)
    np.testing.assert_array_equal(np.stack(partition_sequence(toks,
                                                              n_owners)), ot)
    np.testing.assert_array_equal(batching.merge_sequence_slices(ot), toks)


def test_imbalanced_feature_slices_stay_ragged():
    slices = [RNG.normal(size=(8, 588)), RNG.normal(size=(8, 196))]
    out = batching.stack_feature_slices(slices)
    assert isinstance(out, list) and out[0].shape == (8, 588)
    batch = batching.feature_batch(slices, np.zeros(8, np.int32))
    assert isinstance(batch["x_slices"], list)


def test_pad_contexts_serving_layout():
    ctxs = [np.arange(3), np.arange(5)]
    wave = batching.pad_contexts(ctxs, n_slots=4, length=6, pad=-1)
    assert wave.shape == (4, 6)
    np.testing.assert_array_equal(wave[0], [-1, -1, -1, 0, 1, 2])  # left pad
    np.testing.assert_array_equal(wave[1], [-1, 0, 1, 2, 3, 4])
    assert (wave[2:] == -1).all()                                  # empty slots
    with pytest.raises(ValueError):
        batching.pad_contexts([np.arange(9)], 1, 6)
    with pytest.raises(ValueError):
        batching.pad_contexts(ctxs, 1, 6)


def test_sequence_batch_assembles_owner_tokens():
    toks = make_token_dataset(6, 8, 50, 0)
    sci, owners = sequence_parties(toks, 2)
    batch = batching.sequence_batch([o._features for o in owners],
                                    sci.labels, idx=np.array([0, 2]))
    assert batch["owner_tokens"].shape == (2, 2, 4)
    assert batch["labels"].shape == (2, 8)
    merged = batching.merge_sequence_slices(np.asarray(batch["owner_tokens"]))
    np.testing.assert_array_equal(merged, toks[[0, 2], :-1])


# ---------------------------------------------------------------------------
# the party-visibility contract
# ---------------------------------------------------------------------------


def test_owner_exposes_no_labels_and_no_raw_features():
    owner = DataOwner("o", ["a", "b"], np.zeros((2, 4)))
    assert not hasattr(owner, "labels")
    with pytest.raises(PrivacyError):
        owner.features
    # metadata is fine; data is not
    assert owner.feature_shape == (4,) and owner.n_rows == 2


def test_scientist_holds_labels_only():
    sci = DataScientist(["a", "b"], np.array([1, 0]))
    assert sci.labels.tolist() == [1, 0]
    held = [v for v in sci.__dict__.values()]
    # the only array state is the labels dataset — nothing feature-shaped
    assert sci._vd.data.ndim == 1


def _short_session(n=300, epochs=1):
    sci, owners = make_vertical_mnist_parties(n, seed=0, keep_frac=0.9)
    session = VerticalSession(*feature_parties(sci, owners))
    session.resolve(group="modp512")
    session.build(MNIST_CFG)
    session.fit(epochs=epochs, batch_size=64, verbose=False)
    return session


def test_scientist_path_receives_only_cut_width_payloads():
    """Claim C4 through the facade: the transcript of owner->scientist
    messages contains ONLY PSI responses and cut-layer activations, and
    every activation payload has the cut width (64) — never the raw
    per-owner feature width (392)."""
    session = _short_session()
    raw_width = session.owners[0].feature_shape[0]
    to_scientist = [m for m in session.transcript
                    if m["to"] == "scientist"]
    assert to_scientist, "transcript must record cross-party traffic"
    assert {m["kind"] for m in to_scientist} <= {"psi_double_chunk",
                                                 "psi_server_set_chunk",
                                                 "psi_bloom_shard",
                                                 "cut_activations"}
    cuts = [m for m in to_scientist if m["kind"] == "cut_activations"]
    assert len(cuts) == len(session.owners)
    for m in cuts:
        assert m["width"] == session.adapter.model.k == 64
        assert m["width"] != raw_width and raw_width == 392
    # and the reverse direction carries only protocol messages
    from_scientist = {m["kind"] for m in session.transcript
                      if m["from"] == "scientist"}
    # (psi_blind_reuse is reuse *metadata* the session records, not a
    # payload-bearing message — no bytes cross for it)
    assert from_scientist <= {"psi_blind_chunk", "psi_blind_reuse",
                              "resolved_ids", "cut_gradients"}


def test_session_guardrails():
    sci, owners = make_vertical_mnist_parties(200, seed=0)
    session = VerticalSession(*feature_parties(sci, owners))
    with pytest.raises(RuntimeError, match="resolve"):
        session.fit(epochs=1)
    session.resolve(group="modp512")
    with pytest.raises(RuntimeError, match="build"):
        session.fit(epochs=1)
    session.build(MNIST_CFG)
    with pytest.raises(ValueError, match="exactly one"):
        session.fit(epochs=1, steps=1)
    # a label-free (serving) session must refuse to train
    toks = make_token_dataset(8, 16, 50, 0)[:, :16]
    s2 = VerticalSession(*sequence_parties(toks, 2, with_labels=False))
    s2.resolve(group="modp512")
    s2.build(get_config("llama3.2-3b", reduced=True))
    with pytest.raises(PrivacyError):
        s2.fit(steps=1, batch_size=2)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_dispatch():
    assert type(build_adapter(MNIST_CFG)).__name__ == "MLPAdapter"
    cfg = get_config("llama3.2-3b", reduced=True)
    assert type(build_adapter(cfg)).__name__ == "SplitLMAdapter"
    with pytest.raises(TypeError, match="no split-model adapter"):
        build_adapter(object())
    with pytest.raises(ValueError, match="text archs"):
        build_adapter(get_config("whisper-tiny", reduced=True))


# ---------------------------------------------------------------------------
# session round-trips
# ---------------------------------------------------------------------------


def test_session_round_trip_mnist_accuracy():
    """resolve -> build -> fit on vertical MNIST-like data reaches >85%
    val accuracy with the paper's Appendix-B hyperparameters — in TRUE
    split mode: every cut activation/gradient crosses a real transport
    channel (pipelined schedule, measured bytes).  Bit-for-bit identical
    to the joint path (tests/test_transport.py), so this also certifies
    the joint program."""
    sci, owners = make_vertical_mnist_parties(4000, seed=0, keep_frac=0.9)
    session = VerticalSession(*feature_parties(sci, owners))
    stats = session.resolve(group="modp512")
    assert stats["global_intersection"] > 3000
    session.build(MNIST_CFG)
    history = session.fit(epochs=30, batch_size=128, eval_frac=0.15,
                          verbose=False, mode="split")
    assert history["final"]["val_accuracy"] > 0.85
    ts = session.transport_stats
    assert ts["schedule"] == "pipelined" and ts["backend"] == "queue"
    assert ts["cut_payload_bytes_per_step"] == \
        len(session.owners) * 128 * session.adapter.model.k * 4


def test_session_sequence_fit_and_serve():
    """The LM path: sequence-slice owners train through the same facade,
    and the fitted model serves its aligned contexts."""
    cfg = get_config("llama3.2-3b", reduced=True)
    toks = make_token_dataset(16, 32, cfg.vocab, 0)
    session = VerticalSession(*sequence_parties(toks, cfg.split.n_owners))
    session.resolve(group="modp512")
    session.build(cfg)
    history = session.fit(steps=3, batch_size=4, verbose=False)
    assert np.isfinite(history["final"]["loss"])
    results, engine = session.serve_dataset(max_new=3, batch_slots=4,
                                            n_requests=4)
    assert len(results) == 4
    assert all(len(r.generated) == 3 for r in results.values())
    assert engine.stats["requests"] == 4


def test_hidden_mode_fit_reaches_parity_with_noinv():
    """ISSUE 10 acceptance: training on a mode="hidden" alignment (padded
    pseudonymous rows, scientist never learns which IDs matched) reaches
    accuracy parity with the noinv alignment — the ≤ HIDDEN_PAD - 1
    decoy rows per owner are noise the model shrugs off."""
    def run(mode):
        sci, owners = make_vertical_mnist_parties(3000, seed=0,
                                                  keep_frac=0.9)
        s = VerticalSession(*feature_parties(sci, owners))
        s.resolve(group="modp512", mode=mode)
        s.build(MNIST_CFG)
        h = s.fit(epochs=20, batch_size=128, eval_frac=0.15,
                  verbose=False, mode="split")
        return s, h["final"]["val_accuracy"]

    s_ref, acc_ref = run("noinv")
    s_hid, acc_hid = run("hidden")
    # same population, so the hidden view holds the same members plus
    # at most the decoy padding
    assert len(s_ref.scientist.ids) <= len(s_hid.scientist.ids)
    assert all(i.startswith("anon") for i in s_hid.scientist.ids)
    assert acc_ref > 0.8
    assert acc_hid > acc_ref - 0.06, \
        (f"hidden-mode fit lost accuracy: {acc_hid:.3f} vs "
         f"noinv {acc_ref:.3f}")
