"""§Perf optimization levers must be EXACT (or explicitly bounded)
transformations: grouped MoE dispatch, ring-buffer windowed caches,
microbatch gradient accumulation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import moe as moe_mod
from repro.models.model import SplitModel


def test_grouped_dispatch_equals_global_with_ample_capacity():
    cfg = get_config("deepseek-moe-16b", reduced=True)
    mc = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), 64, mc, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    out1, aux1 = moe_mod.moe_apply(params, x, mc, "swiglu")
    out2, aux2 = moe_mod.moe_apply(
        params, x, dataclasses.replace(mc, dispatch_groups=4), "swiglu")
    np.testing.assert_allclose(out1, out2, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)


def test_grouped_dispatch_gradients_flow():
    cfg = get_config("mixtral-8x7b", reduced=True)
    mc = dataclasses.replace(cfg.moe, dispatch_groups=2)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), 64, mc, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))

    def loss(p):
        out, aux = moe_mod.moe_apply(p, x, mc, "swiglu")
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params)
    gn = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(g))
    assert jnp.isfinite(gn) and gn > 0


def test_ring_cache_decode_matches_full_cache():
    cfg = get_config("mixtral-8x7b", reduced=True).replace(
        compute_dtype="float32", remat=False, swa_window=16)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, P = 2, 32, 2
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    ot = jnp.asarray(toks.reshape(B, P, S // P).transpose(1, 0, 2))
    new = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)).astype(np.int32))

    outs = {}
    for ring in (False, True):
        caches = model.cache_init(B, S, n_new=4, ring=ring)
        _, c = model.prefill(params, {"owner_tokens": ot}, caches)
        l1, c = model.decode_step(params, c, new, S, S // P)
        t2 = jnp.argmax(l1, -1)[:, None].astype(jnp.int32)
        l2, _ = model.decode_step(params, c, t2, S + 1, S // P + 1)
        outs[ring] = (np.asarray(l1), np.asarray(l2))
    # the ring cache is strictly smaller
    full_b = sum(a.size for a in jax.tree.leaves(
        model.cache_init(B, S, ring=False)))
    ring_b = sum(a.size for a in jax.tree.leaves(
        model.cache_init(B, S, ring=True)))
    assert ring_b < full_b
    for i in range(2):
        np.testing.assert_allclose(outs[False][i], outs[True][i],
                                   atol=2e-3, rtol=2e-3)


def test_microbatch_accumulation_matches_single_batch():
    import jax
    from repro.launch.steps import build, make_optimizer
    from repro.sharding.specs import make_rules
    cfg = get_config("llama3.2-3b", reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("t", 32, 4, "train")
    rules = make_rules(mesh, cfg)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(cfg)
    state = opt.init(params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (4, 33)).astype(np.int32)
    batch = {"owner_tokens": jnp.asarray(
        toks[:, :-1].reshape(4, 2, 16).transpose(1, 0, 2)),
        "labels": jnp.asarray(toks[:, 1:])}
    losses = {}
    for nm in (1, 4):
        fn, *_ = build(cfg, shape, mesh, rules, n_microbatches=nm)
        _, _, m = jax.jit(fn)(params, state, batch, 0)
        losses[nm] = float(m["loss"])
    assert losses[1] == pytest.approx(losses[4], rel=1e-4)
