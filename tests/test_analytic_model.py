"""Sanity properties of the analytic roofline cost model."""
import pytest

from benchmarks.analytic import fwd_flops, step_costs
from repro.configs import SHAPES, get_config, list_archs


def test_train_flops_close_to_6nd_for_dense():
    """Dense train FLOPs must be ~(4/3)x the 6ND convention (our model
    includes the remat recompute) plus an attention term."""
    cfg = get_config("llama3.2-3b")
    c = step_costs("llama3.2-3b", "train_4k")
    six_nd = 6.0 * cfg.param_count() * 4096 * 256
    assert 1.2 * six_nd < c.flops < 2.2 * six_nd


def test_moe_uses_active_params():
    c_moe = step_costs("mixtral-8x7b", "train_4k")
    cfg = get_config("mixtral-8x7b")
    full = 8.0 * cfg.param_count(active_only=False) * 4096 * 256
    active = 8.0 * cfg.param_count(active_only=True) * 4096 * 256
    assert c_moe.flops < 0.6 * full
    assert c_moe.flops > 0.8 * active


def test_decode_flops_linear_in_batch():
    c = step_costs("llama3.2-3b", "decode_32k")
    cfg = get_config("llama3.2-3b")
    # ~2*N per token x 128 requests, plus attention over the 32k cache
    assert c.flops > 2.0 * cfg.param_count() * 128
    assert c.flops < 10.0 * cfg.param_count() * 128


def test_swa_decode_cheaper_than_full():
    full = fwd_flops(get_config("llama3.2-3b"), SHAPES["decode_32k"])
    swa = fwd_flops(get_config("llama3.2-3b"), SHAPES["decode_32k"],
                    swa_override=4096)
    assert swa < full


def test_decode_memory_dominated_by_params_and_cache():
    cfg = get_config("gemma2-9b")
    c = step_costs("gemma2-9b", "decode_32k")
    params_bytes = cfg.param_count() * 4.0
    assert c.hbm_bytes > params_bytes          # params + cache
    assert c.hbm_bytes < 60 * params_bytes


@pytest.mark.parametrize("arch", list_archs())
def test_all_costs_positive(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        if shape.name == "long_500k" and cfg.long_context == "skip":
            continue
        c = step_costs(arch, shape.name)
        assert c.flops > 0 and c.hbm_bytes > 0 and c.coll_bytes_dev >= 0


def test_train_heavier_than_prefill_heavier_than_decode():
    for arch in ("llama3.2-3b", "zamba2-2.7b", "mixtral-8x7b"):
        t = step_costs(arch, "train_4k").flops
        p = step_costs(arch, "prefill_32k").flops
        d = step_costs(arch, "decode_32k").flops
        assert t > d and p > d
