"""The paper's dual-headed MLP SplitNN: exactness (claim C3), combine
strategies, per-segment optimizers, and learning (claim C2, small-scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG, MLPSplitConfig
from repro.core.splitnn import (MLPSplitNN, cut_layer_traffic,
                                make_split_train_step, train_state_init)
from repro.data import make_mnist_like
from repro.optim import multi_segment, sgd


def _batch(n=32, seed=0):
    rng = np.random.default_rng(seed)
    X, y = make_mnist_like(n, seed)
    xs = jnp.asarray(np.stack(np.split(X, 2, axis=1)))    # (P, B, 392)
    return {"x_slices": xs, "labels": jnp.asarray(y)}


def test_paper_architecture_dimensions():
    m = MLPSplitNN(MNIST_CFG)
    params = m.init(jax.random.PRNGKey(0))
    # heads: stacked (2, 392 -> 64); trunk: 128 -> 500 -> 10 (Appendix B)
    assert params["heads"][0]["w"].shape == (2, 392, 64)
    assert params["trunk"][0]["w"].shape == (128, 500)
    assert params["trunk"][1]["w"].shape == (500, 10)
    logits = m.forward(params, _batch()["x_slices"])
    assert logits.shape == (32, 10)


def test_split_equals_monolithic_forward_and_grads():
    """C3: the dual-headed SplitNN with concat combine IS the monolithic
    network whose first layer is block-diagonal.  Forward and gradients
    must match exactly."""
    m = MLPSplitNN(MNIST_CFG)
    params = m.init(jax.random.PRNGKey(1))
    batch = _batch(16, seed=2)

    # monolithic first layer: block-diag(W_h0, W_h1), concat biases
    w0, w1 = params["heads"][0]["w"][0], params["heads"][0]["w"][1]
    b0, b1 = params["heads"][0]["b"][0], params["heads"][0]["b"][1]
    W1 = jnp.zeros((784, 128)).at[:392, :64].set(w0).at[392:, 64:].set(w1)
    B1 = jnp.concatenate([b0, b1])

    def mono_loss(W1, B1, trunk, x_full, labels):
        h = jax.nn.relu(x_full @ W1 + B1)
        for i, layer in enumerate(trunk):
            h = h @ layer["w"] + layer["b"]
            if i < len(trunk) - 1:
                h = jax.nn.relu(h)
        logp = jax.nn.log_softmax(h)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))

    x_full = jnp.concatenate([batch["x_slices"][0], batch["x_slices"][1]], 1)
    loss_mono = mono_loss(W1, B1, params["trunk"], x_full, batch["labels"])
    loss_split, _ = m.loss_fn(params, batch)
    np.testing.assert_allclose(loss_split, loss_mono, rtol=1e-6)

    g_mono = jax.grad(mono_loss)(W1, B1, params["trunk"], x_full,
                                 batch["labels"])
    g_split = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    gh = g_split["heads"][0]["w"]
    np.testing.assert_allclose(gh[0], g_mono[:392, :64], atol=1e-6)
    np.testing.assert_allclose(gh[1], g_mono[392:, 64:], atol=1e-6)
    # C4 structurally: the split model HAS no cross-owner first-layer
    # params (the monolithic net's off-diagonal blocks) — owner p's raw
    # features touch only owner p's segment.
    assert gh.shape == (2, 392, 64)


@pytest.mark.parametrize("combine", ["concat", "sum", "mean", "max"])
def test_combine_strategies(combine):
    import dataclasses
    cfg = dataclasses.replace(
        MNIST_CFG, split=dataclasses.replace(MNIST_CFG.split,
                                             combine=combine))
    m = MLPSplitNN(cfg)
    params = m.init(jax.random.PRNGKey(0))
    logits = m.forward(params, _batch()["x_slices"])
    assert logits.shape == (32, 10)
    assert not jnp.isnan(logits).any()


def test_per_segment_learning_rates_differ():
    """Owners update with lr 0.01, the scientist with lr 0.1 (Appendix B):
    with SGD the update magnitude ratio must match exactly."""
    m = MLPSplitNN(MNIST_CFG)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(16)
    grads = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
    opt = multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})
    state = train_state_init(params, opt)
    updates, _ = opt.update(grads, state, params, 0)
    np.testing.assert_allclose(updates["heads"][0]["w"],
                               -0.01 * grads["heads"][0]["w"], rtol=1e-6)
    np.testing.assert_allclose(updates["trunk"][0]["w"],
                               -0.1 * grads["trunk"][0]["w"], rtol=1e-6)


def test_training_learns():
    """C2 (small scale): a few hundred steps beats chance by a wide margin."""
    m = MLPSplitNN(MNIST_CFG)
    params = m.init(jax.random.PRNGKey(0))
    opt = multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})
    state = train_state_init(params, opt)
    step = make_split_train_step(m.loss_fn, opt, donate=False)
    rng = np.random.default_rng(0)
    X, y = make_mnist_like(1024, 5)
    for i in range(200):
        idx = rng.integers(0, 1024, 128)
        b = {"x_slices": jnp.asarray(np.stack(np.split(X[idx], 2, 1))),
             "labels": jnp.asarray(y[idx])}
        params, state, metrics = step(params, state, b, i)
    assert float(metrics["accuracy"]) > 0.5  # chance = 0.1


def test_cut_layer_traffic_accounting():
    t = cut_layer_traffic(n_owners=2, batch=128, tokens_per_owner=1,
                          cut_dim=64, bytes_per_el=4)
    assert t["per_owner_forward_bytes"] == 128 * 64 * 4
    assert t["total_per_step_bytes"] == 2 * 2 * 128 * 64 * 4


@given(st.integers(2, 4), st.sampled_from(["concat", "sum", "mean", "max"]))
@settings(max_examples=8, deadline=None)
def test_n_owner_generalization(n_owners, combine):
    """The paper's future-work axis: >2 owners work out of the box."""
    import dataclasses
    from repro.configs.base import SplitConfig
    if 784 % n_owners:
        n_owners = 2
    cfg = MLPSplitConfig(split=SplitConfig(n_owners=n_owners, combine=combine,
                                           cut_dim=64))
    m = MLPSplitNN(cfg)
    params = m.init(jax.random.PRNGKey(0))
    X, y = make_mnist_like(8, 1)
    xs = jnp.asarray(np.stack(np.split(X, n_owners, axis=1)))
    loss, metrics = m.loss_fn(params, {"x_slices": xs,
                                       "labels": jnp.asarray(y)})
    assert jnp.isfinite(loss)


def test_imbalanced_vertical_split():
    """Paper §5.1 future work: owners with different feature widths."""
    from repro.configs.base import SplitConfig
    cfg = MLPSplitConfig(feature_splits=(588, 196),
                         split=SplitConfig(n_owners=2, combine="concat",
                                           cut_dim=64))
    m = MLPSplitNN(cfg)
    assert not m.symmetric
    params = m.init(jax.random.PRNGKey(0))
    assert params["heads"][0][0]["w"].shape == (588, 64)
    assert params["heads"][1][0]["w"].shape == (196, 64)
    X, y = make_mnist_like(32, 1)
    xs = [jnp.asarray(X[:, :588]), jnp.asarray(X[:, 588:])]
    loss, metrics = m.loss_fn(params, {"x_slices": xs,
                                       "labels": jnp.asarray(y)})
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: m.loss_fn(p, {"x_slices": xs,
                                             "labels": jnp.asarray(y)})[0])(
        params)
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0
