"""Small-mesh dry-run integration test: the full lower+compile path on 8
fake host devices (the production dry-run uses 512; same code path).
Runs in a subprocess because XLA_FLAGS must be set before jax init."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import dataclasses
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import build
from repro.launch import analysis
from repro.sharding.specs import make_rules, named

arch, kind, multi_pod = "%ARCH%", "%KIND%", %MULTI%
cfg = get_config(arch, reduced=True)
if multi_pod:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
else:
    mesh = jax.make_mesh((2, 4), ("data", "model"))
shape = ShapeConfig("t", 32, 4, kind)
rules = make_rules(mesh, cfg)
fn, args, specs, donate = build(cfg, shape, mesh, rules)
lowered = jax.jit(fn, in_shardings=named(mesh, specs),
                  donate_argnums=donate).lower(*args)
compiled = lowered.compile()
mem = analysis.extract_memory(compiled)
cost = analysis.extract_cost(compiled)
colls = analysis.collective_stats(compiled.as_text(),
                                  devices_per_pod=4 if multi_pod else 0)
print("RESULT " + json.dumps({
    "flops": cost["flops"], "temp": mem["temp_bytes"],
    "coll": colls["total_bytes"], "cross": colls["cross_pod_bytes"]}))
"""


def _run(arch, kind, multi_pod):
    src = (SCRIPT.replace("%ARCH%", arch).replace("%KIND%", kind)
           .replace("%MULTI%", str(multi_pod)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", src], capture_output=True,
                       text=True, env=env, timeout=540)
    assert r.returncode == 0, f"dry-run failed:\n{r.stdout}\n{r.stderr}"
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-3b", "zamba2-2.7b",
                                  "mixtral-8x7b"])
def test_train_lowers_and_compiles_single_pod(arch):
    out = _run(arch, "train", False)
    assert out["flops"] > 0


@pytest.mark.slow
def test_train_lowers_multi_pod_with_owner_axis():
    out = _run("llama3.2-3b", "train", True)
    assert out["flops"] > 0
    # the pod axis exists and collectives flow
    assert out["coll"] > 0


@pytest.mark.slow
def test_decode_lowers_and_compiles():
    out = _run("llama3.2-3b", "decode", False)
    assert out["flops"] > 0
