"""Process-per-party runtime (ISSUE 6): the multiprocess transport
backend must carry the queue backend's exact frames (bit-identical wire
accounting, same codec/latency/tap semantics), host owner + PSI actors in
spawned worker processes through the full session surface
(``fit``/``resolve``/``serve``), scale to many owners with uneven feature
widths, and survive the straggler/crash/rejoin chaos suite with clean
surfaced errors."""
import dataclasses
import multiprocessing as mp
import queue as _queue
import struct
import threading
import time
import zlib
from importlib.util import find_spec

import numpy as np
import pytest

from repro.configs.base import SplitConfig
from repro.configs.pyvertical_mnist import CONFIG
from repro.core import modexp
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, transport
from repro.federation.parties import OwnerComputeEndpoint, feature_parties
from repro.federation.process_transport import (HEADER_FMT, POISON_KIND,
                                                ProcessEndpoint,
                                                process_endpoint_pair)
from repro.federation.transport import _pack, _payload_nbytes

GROUP = "modp512"


# ---------------------------------------------------------------------------
# endpoint unit tests (both ends in-process, frames over a real pipe)
# ---------------------------------------------------------------------------


def _pair(**kw):
    return process_endpoint_pair("scientist", "owner0", **kw)


def test_roundtrip_stats_and_stash():
    a, b = _pair()
    try:
        x = np.arange(12, dtype=np.float32).reshape(3, 4)
        a.send("cut_activations", {"cut": x}, seq=3)
        a.send("head_fwd", {"idx": np.arange(5, dtype=np.int32)}, seq=4)
        # recv_kind skips + stashes the earlier-arriving other kind
        m = b.recv_kind("head_fwd", timeout=5.0)
        assert m.seq == 4 and m.sender == "scientist"
        m2 = b.recv_kind("cut_activations", timeout=5.0)
        assert m2.seq == 3
        np.testing.assert_array_equal(m2.payload["cut"], x)
        assert b.empty()
        assert a.sent_stats["messages"] == 2
        assert b.recv_stats["messages"] == 2
        assert (a.sent_stats["by_kind"]["cut_activations"]["wire_bytes"]
                == b.recv_stats["by_kind"]["cut_activations"]["wire_bytes"])
        assert a.sent_stats["payload_bytes"] == x.nbytes + 5 * 4
    finally:
        a.close()
        b.close()


def test_recv_timeout_raises_queue_empty():
    a, b = _pair()
    try:
        t0 = time.monotonic()
        with pytest.raises(_queue.Empty):
            b.recv(timeout=0.3)
        assert 0.2 < time.monotonic() - t0 < 5.0
    finally:
        a.close()
        b.close()


def test_wire_bytes_bit_identical_to_queue_backend():
    """The acceptance invariant at the unit level: the same payload
    crosses both backends with the exact same measured payload and wire
    bytes (the process transport header is uncounted, playing the role
    of the queue backend's uncounted Message envelope)."""
    rng = np.random.default_rng(0)
    payload = {"cut": rng.normal(size=(7, 9)).astype(np.float32),
               "aux": np.float32(1.5).reshape(())}
    qa, qb = transport.channel_pair("a", "b", backend="queue")
    mq = qa.send("cut_activations", payload, seq=0)
    qb.recv_kind("cut_activations")
    pa, pb = _pair()
    try:
        mp_ = pa.send("cut_activations", payload, seq=0)
        got = pb.recv_kind("cut_activations", timeout=5.0)
        assert mp_.wire_bytes == mq.wire_bytes
        assert mp_.payload_bytes == mq.payload_bytes
        assert got.wire_bytes == mq.wire_bytes
        assert (pa.sent_stats["by_kind"]["cut_activations"]
                == qa.sent_stats["by_kind"]["cut_activations"])
    finally:
        pa.close()
        pb.close()


def test_frame_layout_golden():
    """The transport header is frozen:
    [u16 kind_len][kind][i64 seq][f64 not_before][i64 payload_bytes]
    [u32 crc32(blob)] followed by the exact ``transport._pack`` blob."""
    c1, c2 = mp.Pipe(duplex=True)
    ep = ProcessEndpoint("a", "b", c1)
    try:
        payload = {"x": np.arange(3, dtype=np.float32)}
        ep.send("ping", payload, seq=5)
        assert c2.poll(5.0)
        frame = c2.recv_bytes()
        blob = _pack(payload)
        assert frame == (struct.pack("<H", 4) + b"ping"
                         + struct.pack(HEADER_FMT, 5, 0.0,
                                       _payload_nbytes(payload),
                                       zlib.crc32(blob) & 0xFFFFFFFF)
                         + blob)
    finally:
        ep.close()
        c2.close()


def test_latency_injection_delays_delivery():
    a, b = _pair(latency_s=0.2)
    try:
        t0 = time.monotonic()
        a.send("ping", {"x": np.zeros(1, np.float32)})
        b.recv_kind("ping", timeout=5.0)
        assert time.monotonic() - t0 >= 0.15
    finally:
        a.close()
        b.close()


def test_bandwidth_models_transit_time():
    a, b = _pair(bandwidth_bps=64_000.0)
    try:
        t0 = time.monotonic()
        m = a.send("bulk", {"x": np.zeros(4096, np.float32)})
        b.recv_kind("bulk", timeout=30.0)
        expect = m.wire_bytes / 64_000.0
        assert time.monotonic() - t0 >= 0.5 * expect
    finally:
        a.close()
        b.close()


def test_tap_observes_both_directions():
    seen = []
    a, b = _pair(tap=lambda m, blob: seen.append((m.kind, m.sender,
                                                  len(blob))))
    try:
        a.send("ping", {"x": np.zeros(2, np.float32)})
        b.send("pong", {"x": np.zeros(2, np.float32)})
        a.recv_kind("pong", timeout=5.0)
        b.recv_kind("ping", timeout=5.0)
        kinds = {(k, s) for k, s, _ in seen}
        assert ("ping", "scientist") in kinds     # a's send
        assert ("pong", "owner0") in kinds        # a's recv
        assert all(n > 0 for _, _, n in seen)
    finally:
        a.close()
        b.close()


def test_poison_pill_surfaces_peer_error():
    a, b = _pair()
    try:
        try:
            raise ValueError("owner-side kaboom")
        except ValueError as e:
            a.send_error(e, "tb-line-1\ntb-line-2")
        with pytest.raises(RuntimeError,
                           match="died: ValueError: owner-side kaboom"):
            b.recv(timeout=5.0)
        assert b.peer_error is not None
        assert "tb-line-2" in str(b.peer_error)
        # sticky: every subsequent receive re-raises
        with pytest.raises(RuntimeError, match="died"):
            b.recv(timeout=5.0)
    finally:
        a.close()
        b.close()


def test_severed_pipe_raises_clean_runtime_error():
    a, b = _pair()
    a.close()
    try:
        with pytest.raises(RuntimeError, match="connection .* closed"):
            b.recv(timeout=5.0)
    finally:
        b.close()


def test_send_after_close_rejected():
    a, b = _pair()
    a.close()
    b.close()
    with pytest.raises(RuntimeError, match="closed"):
        a.send("ping", {})


# ---------------------------------------------------------------------------
# satellite: spin-wait tunable
# ---------------------------------------------------------------------------


def test_spin_wait_env_override(monkeypatch):
    """``REPRO_SPIN_WAIT_S`` overrides the hybrid-wait spin window;
    garbage or negative values fall back to the core-count default."""
    default = (transport.SPIN_WAIT_S
               if transport._effective_cores() > 1
               else transport.SPIN_WAIT_SINGLE_CORE_S)
    monkeypatch.delenv("REPRO_SPIN_WAIT_S", raising=False)
    assert transport.spin_wait_s() == default
    monkeypatch.setenv("REPRO_SPIN_WAIT_S", "0.0125")
    assert transport.spin_wait_s() == 0.0125
    # endpoints pick the override up at construction
    a, b = _pair()
    try:
        assert a.spin_s == 0.0125
    finally:
        a.close()
        b.close()
    ch_a, _ = transport.channel_pair("a", "b", backend="queue")
    assert ch_a.outbox.spin_s == 0.0125
    monkeypatch.setenv("REPRO_SPIN_WAIT_S", "not-a-float")
    assert transport.spin_wait_s() == default
    monkeypatch.setenv("REPRO_SPIN_WAIT_S", "-3.0")
    assert transport.spin_wait_s() == default


# ---------------------------------------------------------------------------
# satellite: gmpy2 modexp backend selection
# ---------------------------------------------------------------------------


def test_modexp_backend_selection_matches_environment():
    """``HAVE_GMPY2`` must reflect what's actually importable, and the
    live backend must agree with builtin ``pow`` either way (this
    container ships without gmpy2, so CI pins the pure-Python path;
    docs/BENCHMARKS.md records the measured speedup where it exists)."""
    assert modexp.HAVE_GMPY2 == (find_spec("gmpy2") is not None)
    rng = np.random.default_rng(7)
    for _ in range(16):
        base = int(rng.integers(2, 1 << 60))
        exp = int(rng.integers(1, 1 << 60))
        mod = int(rng.integers(3, 1 << 60)) | 1
        assert modexp.powmod(base, exp, mod) == pow(base, exp, mod)


@pytest.mark.skipif(not modexp.HAVE_GMPY2,
                    reason="gmpy2 not installed (optional dev dep)")
def test_gmpy2_powmod_agrees_with_builtin():
    from gmpy2 import powmod as gpowmod
    rng = np.random.default_rng(11)
    for _ in range(32):
        base = int(rng.integers(2, 1 << 61))
        exp = int(rng.integers(1, 1 << 61))
        mod = int(rng.integers(3, 1 << 61)) | 1
        assert int(gpowmod(base, exp, mod)) == pow(base, exp, mod)


# ---------------------------------------------------------------------------
# worker harness (runtime.py) driven on a thread — the exact child code
# path, visible to the coverage tracer
# ---------------------------------------------------------------------------


def _owner_spec(owner_index=0, n_rows=40, seed=0, **kw):
    import jax

    from repro.federation import runtime
    from repro.federation.registry import build_adapter

    adapter = build_adapter(CONFIG)
    params = adapter.init(jax.random.PRNGKey(seed))
    leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(
        adapter.owner_param_slice(params, owner_index))]
    rng = np.random.default_rng(seed)
    return runtime.OwnerWorkerSpec(
        name=f"owner{owner_index}",
        ids=[f"subject-{i:08d}" for i in range(n_rows)],
        features=rng.normal(size=(n_rows, 392)).astype(np.float32),
        owner_index=owner_index, config=CONFIG, init_seed=seed,
        param_leaves=leaves, **kw), leaves


def test_owner_worker_main_serves_protocol_on_a_thread():
    from repro.federation import runtime

    spec, leaves = _owner_spec()
    parent, child = mp.Pipe(duplex=True)
    th = threading.Thread(target=runtime.owner_worker_main,
                          args=(spec, child), daemon=True)
    th.start()
    ep = ProcessEndpoint("scientist", "owner0", parent)
    try:
        ep.send("barrier", {}, seq=-1)
        assert ep.recv_kind("barrier_ack", timeout=120.0).kind == \
            "barrier_ack"
        # pull_params ships the worker's numbered numpy leaves back
        ep.send("pull_params", {}, seq=-1)
        m = ep.recv_kind("params_dump", timeout=60.0)
        assert len(m.payload) == len(leaves)
        for i, leaf in enumerate(leaves):
            np.testing.assert_array_equal(m.payload[str(i)], leaf)
        ep.send("stop", {})
        th.join(timeout=60.0)
        assert not th.is_alive()
    finally:
        ep.close()


def test_worker_failure_ships_poison_pill():
    from repro.federation import runtime

    spec, leaves = _owner_spec()
    spec.param_leaves = leaves[:1]          # wrong arity: unflatten dies
    parent, child = mp.Pipe(duplex=True)
    th = threading.Thread(target=runtime.owner_worker_main,
                          args=(spec, child), daemon=True)
    th.start()
    ep = ProcessEndpoint("scientist", "owner0", parent)
    try:
        with pytest.raises(RuntimeError, match="party 'owner0' died"):
            ep.recv(timeout=120.0)
        assert ep.peer_error is not None
        th.join(timeout=30.0)
        assert not th.is_alive()
    finally:
        ep.close()


def test_spawned_psi_worker_lifecycle():
    """Spawn, handshake, clean stop: exit code 0 and no surfaced error
    (PSI workers are jax-free, so this round-trips in seconds)."""
    from repro.core.psi import GROUPS
    from repro.federation import runtime
    from repro.federation.parties import DataOwner

    owner = DataOwner("owner0", [f"id-{i}" for i in range(8)],
                      np.zeros((8, 4), np.float32))
    w = runtime.spawn_psi_worker(owner, group=GROUP)
    try:
        w.endpoint.send("psi_hello", {
            "group": np.frombuffer(GROUP.encode(), np.uint8),
            "mode": np.frombuffer(b"noinv", np.uint8),
            "nb": np.int64(GROUPS[GROUP][2]),
            "n_items": np.int64(8), "chunk_size": np.int64(4),
            "blind_tag": np.zeros(16, np.uint8),
            "base_tag": np.zeros(16, np.uint8),
            "server_tag": np.zeros(16, np.uint8),
            "have_resp": np.uint8(0)})
        m = w.endpoint.recv_kind("psi_hello_ack", timeout=60.0)
        assert int(np.asarray(m.payload["n_server_items"]).reshape(-1)[0]) \
            == 8
        assert w.error is None
        assert "alive" in repr(w)
    finally:
        try:
            w.endpoint.send("psi_stop", {})
        except RuntimeError:
            pass
        w.shutdown()
    assert w.proc.exitcode == 0
    assert w.error is None


# ---------------------------------------------------------------------------
# session surface: resolve / fit through spawned workers
# ---------------------------------------------------------------------------


def _mnist_session(n=320, seed=0, keep_frac=0.9, feature_splits=None):
    sci, owners = make_vertical_mnist_parties(
        n, seed=seed, keep_frac=keep_frac, feature_splits=feature_splits)
    return VerticalSession(*feature_parties(sci, owners))


def test_resolve_process_matches_direct():
    s1 = _mnist_session(400, keep_frac=0.8)
    s1.resolve(group=GROUP, backend="direct")
    s2 = _mnist_session(400, keep_frac=0.8)
    st = s2.resolve(group=GROUP, backend="process")
    assert s2.scientist.ids == s1.scientist.ids
    assert st["backend"] == "process"
    assert st["global_intersection"] == len(s1.scientist.ids)
    for name, wire in st["per_party_wire"].items():
        assert wire["sent_wire_bytes"] > 0
        assert wire["recv_wire_bytes"] > 0


def test_resolve_process_broadcasts_aligned_ids():
    """Broadcast fan-out: after a process-backend resolve every owner
    holds the same resolved ID order as the scientist (the invariant
    split training builds on)."""
    s = _mnist_session(200, keep_frac=0.7)
    s.resolve(group=GROUP, backend="process")
    for owner in s.owners:
        assert owner.ids == s.scientist.ids


def _params_equal(p1, p2):
    import jax
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    return len(l1) == len(l2) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(l1, l2))


@pytest.mark.slow
def test_fit_process_bit_identical_to_queue():
    """The tentpole acceptance property: mode="split" through spawned
    worker processes reproduces the thread-backed queue run bit for bit
    — params, losses, and measured cut/grad wire bytes."""
    def run(backend):
        s = _mnist_session()
        s.resolve(group=GROUP)
        s.build(CONFIG)
        h = s.fit(mode="split", epochs=1, batch_size=64,
                  microbatches=2, backend=backend, verbose=False,
                  timeout=180.0)
        return s, h

    sq, hq = run("queue")
    sp, hp = run("process")
    assert _params_equal(sq.params, sp.params)
    assert hq["train"] == hp["train"]
    wq, wp = (h["transport"]["per_owner"] for h in (hq, hp))
    assert set(wq) == set(wp)
    for name in wq:
        for key in ("cut_payload_bytes", "cut_wire_bytes",
                    "grad_payload_bytes", "grad_wire_bytes"):
            assert wq[name][key] == wp[name][key], (name, key)
    assert hp["transport"]["backend"] == "process"


@pytest.mark.slow
def test_eight_owner_uneven_widths_end_to_end():
    """Many-owner scale-out: 8 spawned workers with uneven feature
    widths resolve + train end-to-end, and each owner's measured cut
    traffic matches the (owner-independent) cut width."""
    splits = (200, 60, 120, 84, 96, 40, 104, 80)       # sums to 784
    cfg = dataclasses.replace(
        CONFIG, feature_splits=splits,
        split=SplitConfig(n_owners=8, cut_layer=1, combine="concat",
                          cut_dim=64, owner_lr=0.01, scientist_lr=0.1))
    s = _mnist_session(256, seed=1, keep_frac=0.95, feature_splits=splits)
    s.resolve(group=GROUP, backend="process")
    s.build(cfg)
    h = s.fit(mode="split", epochs=1, batch_size=64, backend="process",
              verbose=False, timeout=300.0)
    assert [o.feature_shape[0] for o in s.owners] == list(splits)
    per_owner = h["transport"]["per_owner"]
    assert len(per_owner) == 8
    # cut width is owner-independent: every owner ships identical bytes
    assert len({v["cut_wire_bytes"] for v in per_owner.values()}) == 1
    assert h["train"], "training must produce history"


# ---------------------------------------------------------------------------
# chaos: stragglers, crashes, rejoin — process backend
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_process_fit_owner_crash_surfaces_cleanly(monkeypatch):
    """A worker process that dies mid-step (chaos-injected on its first
    ``head_fwd``) surfaces as an owner-attributed RuntimeError on the
    scientist side — via poison pill or exit code, never a hang."""
    monkeypatch.setenv("REPRO_CHAOS_PARTY", "owner0:crash_fwd")
    s = _mnist_session(200)
    s.resolve(group=GROUP)
    s.build(CONFIG)
    with pytest.raises(RuntimeError, match="owner worker 'owner0'"):
        s.fit(mode="split", epochs=1, batch_size=64, backend="process",
              verbose=False, timeout=60.0)


@pytest.mark.slow
def test_process_fit_wedged_owner_times_out(monkeypatch):
    """A wedged worker (hangs on its first ``head_fwd``, never answers)
    bounds the step by ``timeout`` instead of hanging the scientist;
    teardown escalates to terminate."""
    monkeypatch.setenv("REPRO_CHAOS_PARTY", "owner1:wedge_fwd")
    s = _mnist_session(200)
    s.resolve(group=GROUP)
    s.build(CONFIG)
    with pytest.raises(RuntimeError,
                       match="timed out waiting for 'cut_activations' "
                             "from 'owner1'"):
        s.fit(mode="split", epochs=1, batch_size=64, backend="process",
              verbose=False, timeout=6.0)


def test_queue_fit_wedged_owner_times_out(monkeypatch):
    """The same straggler guarantee on the thread-backed queue backend
    (until this PR only resolve had a wedged-owner timeout test)."""
    orig = OwnerComputeEndpoint.handle

    def wedged(self, msg):
        if msg.kind == "head_fwd" and self.owner.name == "owner0":
            time.sleep(5.0)
        return orig(self, msg)

    monkeypatch.setattr(OwnerComputeEndpoint, "handle", wedged)
    s = _mnist_session(200)
    s.resolve(group=GROUP)
    s.build(CONFIG)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError,
                       match="timed out waiting for 'cut_activations' "
                             "from 'owner0'"):
        s.fit(mode="split", epochs=1, batch_size=64, backend="queue",
              verbose=False, timeout=1.5)
    assert time.monotonic() - t0 < 60.0


def test_process_psi_crash_surfaces_and_owner_rejoins(monkeypatch):
    """A PSI worker crash mid-round surfaces cleanly; clearing the fault
    and re-resolving (the rejoin) succeeds and matches the in-process
    engine."""
    monkeypatch.setenv("REPRO_CHAOS_PARTY", "owner0:crash_psi")
    s = _mnist_session(120, keep_frac=0.8)
    with pytest.raises(RuntimeError, match="owner0"):
        s.resolve(group=GROUP, backend="process", timeout=60.0)
    # fault cleared -> the owner rejoins with a fresh worker
    monkeypatch.delenv("REPRO_CHAOS_PARTY")
    s2 = _mnist_session(120, keep_frac=0.8)
    s2.resolve(group=GROUP, backend="process")
    ref = _mnist_session(120, keep_frac=0.8)
    ref.resolve(group=GROUP, backend="direct")
    assert s2.scientist.ids == ref.scientist.ids


def test_process_psi_wedged_worker_times_out(monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS_PARTY", "owner0:wedge_psi")
    s = _mnist_session(120, keep_frac=0.8)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="timed out"):
        s.resolve(group=GROUP, backend="process", timeout=4.0)
    assert time.monotonic() - t0 < 60.0


# ---------------------------------------------------------------------------
# serving through the process boundary
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serving_process_transport_matches_queue():
    from repro.configs import get_config
    from repro.data import make_token_dataset
    from repro.federation.parties import sequence_parties

    cfg = get_config("llama3.2-3b", reduced=True)
    toks = make_token_dataset(4, 16, cfg.vocab, 0)[:, :16]

    def serve(transport_backend):
        session = VerticalSession(*sequence_parties(
            toks, cfg.split.n_owners, with_labels=False))
        session.resolve(group=GROUP)
        session.build(cfg)
        return session.serve_dataset(max_new=3, batch_slots=4,
                                     transport=transport_backend)

    queued, engine_q = serve("queue")
    proc, engine_p = serve("process")
    for rid in queued:
        assert proc[rid].generated == queued[rid].generated
    assert engine_p.stats["cut_wire_bytes"] == \
        engine_q.stats["cut_wire_bytes"]
    assert engine_p.stats["cut_messages"] == \
        engine_q.stats["cut_messages"]


def test_repeat_and_delta_resolve_on_process_backend():
    """ISSUE 10 on spawned workers: round 2 with unchanged populations
    re-ships nothing (caches are mirrored back to the parent parties
    across worker generations), and a ±2 churn round takes the delta
    path — O(hello)/O(Δ) upload bytes, asserted on round wire stats."""
    s = _mnist_session(200, keep_frac=1.0)
    st1 = s.resolve(group=GROUP, backend="process")
    ids1 = list(s.scientist.ids)
    full_up = max(r["upload_wire_bytes"] for r in st1["rounds"])

    st2 = s.resolve(group=GROUP, backend="process")
    assert s.scientist.ids == ids1
    for r in st2["rounds"]:
        assert r["upload_skipped"] and r["resp_skipped"]
        assert r["server_leg_skipped"]
        assert r["upload_wire_bytes"] < 1024
        assert r["download_wire_bytes"] < 1024

    sci = s.scientist
    pop = list(sci._full.ids)
    new_ids = pop[2:] + ["fresh-0", "fresh-1"]
    new_data = np.concatenate(
        [sci._full.data[2:], np.zeros((2,) + sci._full.data.shape[1:],
                                      sci._full.data.dtype)])
    sci.update_rows(new_ids, new_data)
    st3 = s.resolve(group=GROUP, backend="process")
    for r in st3["rounds"]:
        assert r["delta_used"] and r["server_leg_skipped"]
        assert r["upload_wire_bytes"] < 0.05 * full_up
    expect = sorted(set(pop[2:]))
    assert s.scientist.ids == expect
    for o in s.owners:
        assert o.ids == expect


def test_hidden_resolve_process_matches_queue():
    """mode="hidden" through spawned workers is bit-stable with the
    thread-backed queue backend: identical pseudonymous ID order and
    identical aligned feature bytes on every party."""
    sq = _mnist_session(150, seed=4, keep_frac=0.85)
    sq.resolve(group=GROUP, mode="hidden", backend="queue")
    sp = _mnist_session(150, seed=4, keep_frac=0.85)
    st = sp.resolve(group=GROUP, mode="hidden", backend="process")
    assert st["mode"] == "hidden"
    assert sp.scientist.ids == sq.scientist.ids
    assert sp.scientist.ids and \
        all(i.startswith("anon") for i in sp.scientist.ids)
    assert sp.scientist._vd.data.tobytes() == \
        sq.scientist._vd.data.tobytes()
    for oq, op in zip(sq.owners, sp.owners):
        assert op.ids == sp.scientist.ids
        assert op._vd.data.tobytes() == oq._vd.data.tobytes()
