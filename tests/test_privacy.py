"""Privacy hooks: distance correlation properties, cut noise, NoPeek,
wire defences, and the norm-attack AUC metric."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.privacy import (deterministic_cut_noise,
                                distance_correlation, gaussian_cut_noise,
                                label_inference_auc, nopeek_penalty,
                                obfuscate_cut_gradient)
from repro.testing.hypo import given, settings
from repro.testing.hypo import strategies as st


def test_dcor_of_identical_is_one():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)))
    d = float(distance_correlation(x, x))
    np.testing.assert_allclose(d, 1.0, atol=1e-5)


def test_dcor_linear_transform_high():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 8)))
    z = x @ jnp.asarray(rng.normal(size=(8, 4)))
    assert float(distance_correlation(x, z)) > 0.5


def test_dcor_independent_below_dependent():
    """Small-sample dcor has positive bias, so test the ORDERING: an
    independent z scores well below a linear transform of x."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(128, 8)))
    z_ind = jnp.asarray(rng.normal(size=(128, 8)))
    z_dep = x @ jnp.asarray(rng.normal(size=(8, 8)))
    d_ind = float(distance_correlation(x, z_ind))
    d_dep = float(distance_correlation(x, z_dep))
    assert d_ind < 0.6 and d_ind < d_dep - 0.2


def test_dcor_bounded():
    rng = np.random.default_rng(3)
    for i in range(3):
        x = jnp.asarray(rng.normal(size=(32, 4)))
        z = jnp.asarray(rng.normal(size=(32, 6))) * (10.0 ** i)
        d = float(distance_correlation(x, z))
        assert -1e-6 <= d <= 1.0 + 1e-6


def test_gaussian_noise_changes_cut_but_preserves_shape():
    x = jnp.ones((4, 8))
    y = gaussian_cut_noise(jax.random.PRNGKey(0), x, 0.5)
    assert y.shape == x.shape and not np.allclose(y, x)
    y0 = gaussian_cut_noise(jax.random.PRNGKey(0), x, 0.0)
    np.testing.assert_array_equal(y0, x)


def test_nopeek_penalty_zero_weight():
    x = jnp.ones((8, 4))
    assert float(nopeek_penalty(x, x, 0.0)) == 0.0


def test_nopeek_reduces_under_noise():
    """Noisier cut representations leak less (lower dcor with raw input) —
    the Titcombe et al. defence direction."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(96, 16)))
    w = jnp.asarray(rng.normal(size=(16, 8)))
    clean = x @ w
    key = jax.random.PRNGKey(0)
    noisy = gaussian_cut_noise(key, clean, 25.0)
    d_clean = float(distance_correlation(x, clean))
    d_noisy = float(distance_correlation(x, noisy))
    assert d_noisy < d_clean


# ---------------------------------------------------------------------------
# property tests (hypothesis via repro.testing.hypo)
# ---------------------------------------------------------------------------


@settings(max_examples=15)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=8, max_value=48),
       st.integers(min_value=2, max_value=8))
def test_dcor_bounded_and_symmetric(seed, batch, dim):
    """dcor in [0, 1] and dcor(x, z) == dcor(z, x) for arbitrary
    batches."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch, dim)))
    z = jnp.asarray(rng.normal(size=(batch, dim + 1)) * 3.0)
    d_xz = float(distance_correlation(x, z))
    d_zx = float(distance_correlation(z, x))
    assert -1e-6 <= d_xz <= 1.0 + 1e-6
    assert d_xz == pytest.approx(d_zx, abs=1e-5)


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_dcor_copies_near_one_independent_near_zero(seed):
    """dcor(x, x) ≈ 1 always; large independent batches score near 0
    (small-sample bias shrinks with B)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(256, 6)))
    z = jnp.asarray(rng.normal(size=(256, 6)))
    assert float(distance_correlation(x, x)) == pytest.approx(1.0,
                                                              abs=1e-4)
    # finite-sample dcor of independent batches has positive bias
    # (O(B^-1/2) scale) — bound it well below the dependent regime
    assert float(distance_correlation(x, z)) < 0.4


@settings(max_examples=10)
@given(st.floats(min_value=1e-3, max_value=10.0),
       st.integers(min_value=0, max_value=10 ** 6))
def test_nopeek_gradients_finite_at_weight_boundaries(weight, seed):
    """grad of the NoPeek penalty stays finite across the weight range
    even for degenerate inputs (duplicated rows — zero pairwise
    distances — are the sqrt'(0) danger zone the 1e-12 floor exists
    for).  Uses the stacked-owner convention: (P, B, F) vs (P, B, k)."""
    rng = np.random.default_rng(seed)
    # duplicate rows within each owner's batch
    x = np.repeat(rng.normal(size=(2, 8, 4)), 2, axis=1)
    z0 = jnp.asarray(np.repeat(rng.normal(size=(2, 8, 3)), 2, axis=1))

    def pen(z):
        return nopeek_penalty(jnp.asarray(x), z, weight)

    g = jax.grad(pen)(z0)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.isfinite(float(pen(z0)))


# ---------------------------------------------------------------------------
# wire defences (deterministic transforms on shipped tensors)
# ---------------------------------------------------------------------------


def test_deterministic_cut_noise_replays_bitwise():
    cut = np.random.default_rng(0).normal(size=(16, 8)).astype(np.float32)
    a = deterministic_cut_noise(cut, 0.3, seed=7, tag="s3")
    b = deterministic_cut_noise(cut, 0.3, seed=7, tag="s3")
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(
        a, deterministic_cut_noise(cut, 0.3, seed=7, tag="s4"))
    np.testing.assert_array_equal(
        deterministic_cut_noise(cut, 0.0, seed=7, tag="s3"), cut)


def test_grad_norm_mode_unit_equalizes_per_example_norms():
    g = np.random.default_rng(1).normal(size=(32, 8)).astype(np.float32)
    g[::2] *= 25.0                      # norm signal
    out = obfuscate_cut_gradient(g, norm_mode="unit")
    norms = np.linalg.norm(out.reshape(32, -1), axis=1)
    assert np.std(norms) / np.mean(norms) < 1e-5
    # directions preserved per example
    cos = np.sum(out * g, axis=1) / (
        np.linalg.norm(out, axis=1) * np.linalg.norm(g, axis=1))
    np.testing.assert_allclose(cos, 1.0, atol=1e-5)


def test_grad_norm_mode_sign_collapses_magnitudes():
    g = np.random.default_rng(2).normal(size=(16, 4)).astype(np.float32)
    out = obfuscate_cut_gradient(g, norm_mode="sign")
    mags = np.unique(np.abs(out[out != 0.0]))
    assert len(mags) == 1               # one common magnitude
    np.testing.assert_array_equal(np.sign(out), np.sign(g))


def test_obfuscate_rejects_unknown_mode_and_replays_noise():
    g = np.ones((4, 4), np.float32)
    with pytest.raises(ValueError, match="grad_norm_mode"):
        obfuscate_cut_gradient(g, norm_mode="bogus")
    a = obfuscate_cut_gradient(g, noise_std=0.5, seed=3, tag="g1o0")
    b = obfuscate_cut_gradient(g, noise_std=0.5, seed=3, tag="g1o0")
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(
        a, obfuscate_cut_gradient(g, noise_std=0.5, seed=3, tag="g1o1"))


def test_label_inference_auc_detects_norm_signal():
    rng = np.random.default_rng(4)
    labels = rng.random(400) < 0.15
    norms = rng.normal(1.0, 0.05, 400)
    norms[labels] += 1.0                # positives have larger grads
    assert label_inference_auc(norms, labels) > 0.95
    # no signal -> chance; degenerate labels -> exactly chance
    assert abs(label_inference_auc(rng.normal(size=400), labels)
               - 0.5) < 0.1
    assert label_inference_auc(norms, np.zeros(400, bool)) == 0.5


# ---------------------------------------------------------------------------
# ISSUE 10 bugfix: SplitConfig.nopeek_weight must actually train
# ---------------------------------------------------------------------------


def _nopeek_fit(weight, steps=5):
    import dataclasses
    from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(240, seed=0, keep_frac=0.9)
    s = VerticalSession(*feature_parties(sci, owners))
    s.resolve(group="modp512")
    cfg = dataclasses.replace(
        MNIST_CFG, split=dataclasses.replace(MNIST_CFG.split,
                                             nopeek_weight=weight))
    s.build(cfg)
    h = s.fit(steps=steps, batch_size=64, verbose=False, mode="split")
    return [float(r["loss"]) for r in h["train"]]


def test_nopeek_weight_changes_split_fit_loss_trail():
    """The silently-ignored-weight bug: split-mode fit() with
    nopeek_weight > 0 must optimize a different objective — the loss
    trail diverges from the undefended run, while weight=0 reruns stay
    bit-identical (the regularizer is baked at trace time)."""
    base = _nopeek_fit(0.0)
    again = _nopeek_fit(0.0)
    assert base == again                   # deterministic baseline
    defended = _nopeek_fit(0.3)
    assert all(np.isfinite(v) for v in defended)
    assert defended != base, \
        "nopeek_weight > 0 did not change split-mode training"


def test_nopeek_weight_also_regularizes_joint_loss():
    """MLPSplitNN.loss_fn: weight w adds exactly w * sum of per-owner
    distance correlations between raw slices and cut activations."""
    import dataclasses
    from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
    from repro.core.splitnn import MLPSplitNN
    m0 = MLPSplitNN(MNIST_CFG)
    cfg1 = dataclasses.replace(
        MNIST_CFG, split=dataclasses.replace(MNIST_CFG.split,
                                             nopeek_weight=0.7))
    m1 = MLPSplitNN(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(32, 784)).astype(np.float32))
    sizes = m0.splits
    offs = np.cumsum([0] + list(sizes))
    batch = {"x_slices": jnp.stack([x[:, offs[i]:offs[i + 1]]
                                    for i in range(len(sizes))]),
             "labels": jnp.asarray(rng.integers(0, 10, 32))}
    l0 = float(m0.loss_fn(params, batch)[0])
    l1 = float(m1.loss_fn(params, batch)[0])
    cut = m0.heads_forward(params["heads"], batch["x_slices"])
    pen = sum(float(distance_correlation(xs, c))
              for xs, c in zip(batch["x_slices"], cut))
    np.testing.assert_allclose(l1 - l0, 0.7 * pen, rtol=1e-4)


def test_nopeek_unsupported_by_sequence_lm_raises_loudly():
    """The other half of the bugfix contract: an adapter that cannot
    honor the weight must refuse it instead of silently ignoring it."""
    import dataclasses
    from repro.configs import get_config
    from repro.federation.registry import build_adapter
    cfg = get_config("llama3.2-3b", reduced=True)
    bad = dataclasses.replace(
        cfg, split=dataclasses.replace(cfg.split, nopeek_weight=0.1))
    with pytest.raises(ValueError, match="nopeek_weight"):
        build_adapter(bad)
