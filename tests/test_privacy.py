"""Privacy hooks: distance correlation properties, cut noise, NoPeek."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.privacy import (distance_correlation, gaussian_cut_noise,
                                nopeek_penalty)


def test_dcor_of_identical_is_one():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)))
    d = float(distance_correlation(x, x))
    np.testing.assert_allclose(d, 1.0, atol=1e-5)


def test_dcor_linear_transform_high():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 8)))
    z = x @ jnp.asarray(rng.normal(size=(8, 4)))
    assert float(distance_correlation(x, z)) > 0.5


def test_dcor_independent_below_dependent():
    """Small-sample dcor has positive bias, so test the ORDERING: an
    independent z scores well below a linear transform of x."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(128, 8)))
    z_ind = jnp.asarray(rng.normal(size=(128, 8)))
    z_dep = x @ jnp.asarray(rng.normal(size=(8, 8)))
    d_ind = float(distance_correlation(x, z_ind))
    d_dep = float(distance_correlation(x, z_dep))
    assert d_ind < 0.6 and d_ind < d_dep - 0.2


def test_dcor_bounded():
    rng = np.random.default_rng(3)
    for i in range(3):
        x = jnp.asarray(rng.normal(size=(32, 4)))
        z = jnp.asarray(rng.normal(size=(32, 6))) * (10.0 ** i)
        d = float(distance_correlation(x, z))
        assert -1e-6 <= d <= 1.0 + 1e-6


def test_gaussian_noise_changes_cut_but_preserves_shape():
    x = jnp.ones((4, 8))
    y = gaussian_cut_noise(jax.random.PRNGKey(0), x, 0.5)
    assert y.shape == x.shape and not np.allclose(y, x)
    y0 = gaussian_cut_noise(jax.random.PRNGKey(0), x, 0.0)
    np.testing.assert_array_equal(y0, x)


def test_nopeek_penalty_zero_weight():
    x = jnp.ones((8, 4))
    assert float(nopeek_penalty(x, x, 0.0)) == 0.0


def test_nopeek_reduces_under_noise():
    """Noisier cut representations leak less (lower dcor with raw input) —
    the Titcombe et al. defence direction."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(96, 16)))
    w = jnp.asarray(rng.normal(size=(16, 8)))
    clean = x @ w
    key = jax.random.PRNGKey(0)
    noisy = gaussian_cut_noise(key, clean, 25.0)
    d_clean = float(distance_correlation(x, clean))
    d_noisy = float(distance_correlation(x, noisy))
    assert d_noisy < d_clean
