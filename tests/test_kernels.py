"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps in
interpret mode (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_attention.ops import block_attention
from repro.kernels.block_attention.ref import attention_ref
from repro.kernels.cut_fusion.ops import cut_fusion
from repro.kernels.cut_fusion.ref import cut_fusion_ref
from repro.kernels.mamba2_scan.ops import mamba2_scan
from repro.kernels.mamba2_scan.ref import ssd_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# block_attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # B, Sq, Skv, nh, nkv, hd, kind, window, softcap
    (2, 128, 128, 4, 4, 64, "causal", 0, 0.0),
    (2, 256, 256, 8, 2, 64, "causal", 0, 0.0),      # GQA group 4
    (1, 192, 192, 4, 2, 128, "local", 64, 0.0),     # SWA
    (1, 128, 128, 2, 2, 64, "bidir", 0, 0.0),       # whisper encoder
    (1, 256, 256, 4, 2, 64, "causal", 0, 50.0),     # gemma2 softcap
    (2, 100, 100, 4, 4, 32, "causal", 0, 0.0),      # ragged (padding path)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_attention_matches_oracle(case, dtype):
    B, Sq, Skv, nh, nkv, hd, kind, window, cap = case
    q = jnp.asarray(RNG.normal(size=(B, Sq, nh, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, nkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, nkv, hd)), dtype)
    out = block_attention(q, k, v, kind=kind, window=window, softcap=cap,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, kind=kind, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_block_attention_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    q = jnp.asarray(RNG.normal(size=(1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 256, 2, 64)), jnp.float32)
    outs = [block_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# cut_fusion
# ---------------------------------------------------------------------------

CUT_CASES = [
    (2, 128, 64, 128, "concat"),
    (4, 256, 64, 96, "concat"),
    (2, 100, 60, 70, "concat"),       # ragged
    (2, 128, 64, 128, "sum"),
    (3, 128, 64, 128, "mean"),
]


@pytest.mark.parametrize("case", CUT_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cut_fusion_matches_oracle(case, dtype):
    P, T, K, D, combine = case
    z = jnp.asarray(RNG.normal(size=(P, T, K)), dtype)
    w = jnp.asarray(RNG.normal(size=(P, K, D)), dtype)
    out = cut_fusion(z, w, combine=combine, block_m=64, block_n=64,
                     block_k=32, interpret=True)
    ref = cut_fusion_ref(z, w, combine=combine)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# mamba2_scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 128, 4, 32, 1, 16, 32),
    (1, 96, 4, 32, 2, 16, 32),       # grouped B/C + ragged seq
    (2, 256, 8, 64, 1, 64, 64),      # zamba2-like dims
    (1, 64, 2, 16, 1, 8, 64),        # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba2_scan_matches_oracle(case, dtype):
    B, S, H, P, G, N, chunk = case
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bi = jnp.asarray(RNG.normal(size=(B, S, G, N)), dtype)
    Ci = jnp.asarray(RNG.normal(size=(B, S, G, N)), dtype)
    y, st = mamba2_scan(x, dt, A, Bi, Ci, chunk=chunk, interpret=True)
    yr, sr = ssd_ref(x, dt, A, Bi, Ci, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(st, sr, **_tol(dtype))


def test_mamba2_scan_chunk_independence():
    """The chunked recurrence must be exact: chunk size cannot change y."""
    B, S, H, P, G, N = 1, 128, 2, 16, 1, 8
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, size=(B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    Bi = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    Ci = jnp.asarray(RNG.normal(size=(B, S, G, N)), jnp.float32)
    ys = [mamba2_scan(x, dt, A, Bi, Ci, chunk=c, interpret=True)[0]
          for c in (16, 32, 128)]
    for y in ys[1:]:
        np.testing.assert_allclose(y, ys[0], atol=1e-4, rtol=1e-4)
