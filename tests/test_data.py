"""Data pipeline: determinism, alignment, learnable structure."""
import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.data import (batches, make_mnist_like, make_token_dataset,
                        make_vertical_mnist_parties)


def test_mnist_like_shapes_and_range():
    X, y = make_mnist_like(100, seed=0)
    assert X.shape == (100, 784) and y.shape == (100,)
    assert X.min() >= 0.0 and X.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_mnist_like_deterministic():
    X1, y1 = make_mnist_like(50, seed=3)
    X2, y2 = make_mnist_like(50, seed=3)
    np.testing.assert_array_equal(X1, X2)
    np.testing.assert_array_equal(y1, y2)


def test_mnist_like_classes_separable_by_mean():
    """Class structure exists: per-class mean images differ measurably."""
    X, y = make_mnist_like(2000, seed=1)
    means = np.stack([X[y == c].mean(0) for c in range(10)])
    dists = np.linalg.norm(means[:, None] - means[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    assert dists.min() > 0.5


def test_vertical_parties_have_partial_overlap():
    sci, owners = make_vertical_mnist_parties(200, seed=0, keep_frac=0.7)
    assert len(sci.ids) == 200
    for ds in owners.values():
        assert 80 < len(ds.ids) < 200         # true subsets
        assert ds.data.shape[1] == 392        # half images


def test_token_dataset_has_learnable_structure():
    """Order-2 Markov structure: the same (t-1, t-2) context predicts the
    same next token most of the time."""
    toks = make_token_dataset(64, 128, vocab=97, seed=0)
    assert toks.shape == (64, 129)
    hits = total = 0
    from collections import Counter, defaultdict
    ctx = defaultdict(Counter)
    for row in toks[:32]:
        for j in range(2, len(row)):
            ctx[(row[j - 1], row[j - 2])][row[j]] += 1
    for c, counter in ctx.items():
        n = sum(counter.values())
        if n >= 3:
            hits += counter.most_common(1)[0][1]
            total += n
    assert total > 0 and hits / total > 0.6


@given(st.integers(10, 100), st.integers(1, 16))
@settings(max_examples=20, deadline=None)
def test_batches_partition_without_duplication(n, bs):
    data = {"x": np.arange(n)}
    seen = []
    for b in batches(data, bs, seed=0, epochs=1):
        seen.extend(b["x"].tolist())
    assert len(seen) == len(set(seen)) == n - (n % bs)


def test_batches_seeded_shuffle_deterministic():
    data = {"x": np.arange(64)}
    a = [b["x"].tolist() for b in batches(data, 8, seed=5)]
    b = [b["x"].tolist() for b in batches(data, 8, seed=5)]
    assert a == b
