"""The §3.1 data-resolution protocol: alignment invariants (claim C1)."""
import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.core.resolution import VerticalDataset, resolve
from repro.core.vertical import (make_ids, partition_features,
                                 partition_sequence, scatter_to_owners,
                                 unpartition)

GROUP = "modp512"


def _setup(n, keep, seed, n_owners=2):
    rng = np.random.default_rng(seed)
    ids = make_ids(n)
    X = rng.normal(size=(n, 4 * n_owners)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    slices = partition_features(X, n_owners)
    raw = scatter_to_owners(ids, slices, rng, keep)
    sci = VerticalDataset(ids, y)
    owners = {f"o{i}": VerticalDataset(i_, d_) for i, (i_, d_) in
              enumerate(raw)}
    return ids, X, y, slices, sci, owners


@given(st.integers(20, 120), st.floats(0.5, 1.0), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_resolution_aligns_all_parties(n, keep, seed):
    ids, X, y, slices, sci, owners = _setup(n, keep, seed)
    s_al, o_al, stats = resolve(sci, owners, group=GROUP)
    # identical ID order everywhere
    for ds in o_al.values():
        assert ds.ids == s_al.ids
    # aligned rows reconstruct the original subjects exactly
    idx = [ids.index(i) for i in s_al.ids]
    np.testing.assert_array_equal(s_al.data, y[idx])
    for k, ds in o_al.items():
        p = int(k[1:])
        np.testing.assert_array_equal(ds.data, slices[p][idx])
    # global intersection is exactly the set intersection
    expect = set(ids)
    for ds in owners.values():
        expect &= set(ds.ids)
    assert stats["global_intersection"] == len(expect)
    assert len(s_al.ids) == len(expect)


def test_three_owners():
    ids, X, y, slices, sci, owners = _setup(60, 0.8, 3, n_owners=3)
    s_al, o_al, _ = resolve(sci, owners, group=GROUP)
    assert len(o_al) == 3
    for ds in o_al.values():
        assert ds.ids == s_al.ids


def test_duplicate_ids_rejected():
    with pytest.raises(ValueError):
        VerticalDataset(["a", "a"], np.zeros((2, 1)))


@given(st.integers(1, 8), st.integers(1, 6), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_partition_unpartition_roundtrip(n_owners, per_owner, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(7, n_owners * per_owner)).astype(np.float32)
    np.testing.assert_array_equal(
        unpartition(partition_features(x, n_owners)), x)
    t = rng.integers(0, 100, size=(3, n_owners * per_owner))
    np.testing.assert_array_equal(
        unpartition(partition_sequence(t, n_owners), axis=1), t)


def test_partition_rejects_indivisible():
    with pytest.raises(ValueError):
        partition_features(np.zeros((2, 7)), 2)
