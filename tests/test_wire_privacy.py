"""Wire-privacy regression: what actually crosses the boundary during
a masked-sum fit (WIRE_PROTOCOL.md invariant 11).

These tests tap every serialized frame of real training runs — the
same observed-traffic discipline as the PSI privacy tests — and assert
that NO frame of a masked run carries a per-owner unmasked activation,
in any encoding the protocol could accidentally emit (raw f32 bytes,
the bare fixed-point quantization) nor as a statistical shadow
(correlation of the ring elements with the true cut).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core import masking
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties, transport
from repro.federation.transport import _unpack

SUM_CFG = dataclasses.replace(MNIST_CFG, split=dataclasses.replace(
    MNIST_CFG.split, combine="sum"))

_CACHE: dict = {}


def _fit_with_tap(aggregation):
    """Split fit on the queue backend with every serialized frame
    captured.  Returns [(sender, kind, blob)]."""
    if aggregation in _CACHE:
        return _CACHE[aggregation]
    captured = []
    orig = transport.channel_pair

    def tapped(a, b, **kw):
        kw["tap"] = lambda msg, blob: captured.append(
            (msg.sender, msg.kind, blob))
        return orig(a, b, **kw)

    transport.channel_pair = tapped
    try:
        sci, owners = make_vertical_mnist_parties(200, seed=0,
                                                  keep_frac=0.9)
        s = VerticalSession(*feature_parties(sci, owners))
        s.resolve(group="modp512")
        s.build(SUM_CFG)
        s.fit(steps=2, batch_size=64, verbose=False, mode="split",
              backend="queue", aggregation=aggregation)
    finally:
        transport.channel_pair = orig
    _CACHE[aggregation] = captured
    return captured


def _owner_cuts(captured):
    """-> {(sender, kind, seq-order-index): payload dict} for every
    owner->scientist cut-bearing frame."""
    out = []
    for sender, kind, blob in captured:
        if sender != "scientist" and kind in ("cut_activations",
                                              "warmup_cuts"):
            out.append((sender, kind, _unpack(blob)))
    return out


def test_masked_run_ships_no_unmasked_activation_bytes():
    """Exact-bytes check: the f32 cut an owner would have shipped in a
    plain run — and its bare fixed-point quantization — appear nowhere
    in ANY frame of the masked run.  Both runs share init params and
    batch order, so the plain step-0/warmup cuts are byte-for-byte what
    the masked owners computed before masking."""
    plain = _fit_with_tap(None)
    masked = _fit_with_tap("masked_sum")
    quant = masking.make_quant_program()
    haystack = b"\x00".join(blob for _, _, blob in masked)
    needles = 0
    for sender, kind, payload in _owner_cuts(plain):
        cut = np.asarray(payload["x"], np.float32)
        for needle in (cut.tobytes(),
                       np.asarray(quant(cut)).tobytes()):
            assert needle not in haystack, \
                f"unmasked {kind} bytes from {sender} on the wire"
            needles += 1
    assert needles >= 8          # 2 owners x (warmup + 2 steps) x 2


def test_masked_frames_carry_only_ring_elements():
    """Schema check on observed traffic: every cut-bearing frame of a
    masked run is ring-coded — a uint32 ``mq`` entry (plus at most the
    f32 ``aux`` scalar), never an ``x``/``qp`` codec entry."""
    masked = _fit_with_tap("masked_sum")
    frames = _owner_cuts(masked)
    assert frames, "tap captured no owner cut traffic"
    for sender, kind, payload in frames:
        assert set(payload) <= {"mq", "aux"}, (sender, kind)
        assert payload["mq"].dtype == np.uint32


def test_ring_elements_are_uncorrelated_with_the_true_cut():
    """Statistical check: the shipped ring element mq = q + mask is
    uniform mod 2^32 — it neither correlates with the true quantized
    cut nor concentrates in the small-integer band the bare
    quantization lives in."""
    plain = _fit_with_tap(None)
    masked = _fit_with_tap("masked_sum")
    quant = masking.make_quant_program()
    # owner threads interleave nondeterministically on the global tap —
    # match frames within each (sender, kind) FIFO stream
    def streams(frames):
        out: dict = {}
        for sender, kind, payload in frames:
            out.setdefault((sender, kind), []).append(payload)
        return out

    plain_s, masked_s = streams(_owner_cuts(plain)), streams(
        _owner_cuts(masked))
    assert set(plain_s) == set(masked_s)
    checked = 0
    for key in sorted(plain_s):
        assert len(plain_s[key]) == len(masked_s[key])
        for pl_p, pl_m in zip(plain_s[key], masked_s[key]):
            q = np.asarray(quant(np.asarray(pl_p["x"], np.float32)),
                           np.int64).ravel()
            mq = pl_m["mq"].view(np.int32).astype(np.int64).ravel()
            if np.std(q) == 0:
                continue
            r = np.corrcoef(q, mq)[0, 1]
            assert abs(r) < 0.1, \
                f"ring element correlates with cut: {r}"
            # bare quantization lives in ±2^24: a masked element
            # landing there is a coin flip per element, never the
            # whole frame
            in_band = np.mean(np.abs(mq) <= masking.QCLIP)
            assert in_band < 0.05, \
                "masked frame not uniform over the ring"
            checked += 1
    assert checked >= 4


def test_plain_run_does_leak_the_cut_bytes():
    """Control for the exact-bytes check: in the PLAIN run the cut
    bytes trivially are on the wire — so the masked-run assertion above
    is falsifiable, not vacuous."""
    plain = _fit_with_tap(None)
    haystack = b"\x00".join(blob for _, _, blob in plain)
    sender, kind, payload = _owner_cuts(plain)[0]
    assert np.asarray(payload["x"], np.float32).tobytes() in haystack
