"""Wire-native PSI (ISSUE 5): entity resolution over the transport layer
must be bit-identical to the in-process engine, survive protocol chaos
(reordered chunks, mid-round owner crashes, degenerate sets) with correct
results or clean surfaced errors, keep its frame layouts frozen (golden
conformance), and leak nothing but blinded bytes onto the wire."""
import struct
import threading
import time

import numpy as np
import pytest

from repro.testing.hypo import given, settings, strategies as st

from repro.core.modexp import ModexpPool
from repro.core.psi import GROUPS, PSIClient, PSIServer, psi_round
from repro.federation import transport
from repro.federation.psi_transport import (CLIENT_KINDS, SERVER_KINDS,
                                            WIRE_KINDS, PSIServerEndpoint,
                                            blind_tag, serve_psi,
                                            wire_psi_round)
from repro.federation.transport import _pack, _unpack

GROUP = "modp512"
NB = GROUPS[GROUP][2]


def _wire_round(xs, ys, *, mode="noinv", chunk_size=16, latency_s=0.0,
                pool=None, timeout=120.0):
    """One full wire round over a fresh queue channel pair.  Returns
    (intersection, stats, client_endpoint, worker)."""
    client = PSIClient(xs, GROUP, mode=mode)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue",
                                        latency_s=latency_s)
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        inter, stats = wire_psi_round(client, ep_c, worker=worker,
                                      pool=pool, chunk_size=chunk_size,
                                      timeout=timeout)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    return inter, stats, ep_c, worker


# ---------------------------------------------------------------------------
# bit-identity: wire engine == in-process engine
# ---------------------------------------------------------------------------


@given(st.lists(st.text(min_size=1, max_size=8), min_size=0, max_size=40),
       st.lists(st.text(min_size=1, max_size=8), min_size=0, max_size=40),
       st.integers(1, 17),
       st.sampled_from(["noinv", "bloom"]))
@settings(max_examples=8, deadline=None)
def test_wire_round_bit_identical_to_in_process(xs, ys, chunk, mode):
    """Random uneven sets (duplicates allowed), both protocol variants,
    any chunk size: the wire engine returns the exact intersection list
    — same elements, same client order, same duplicate multiplicity —
    as the in-process PR 4 engine."""
    ref, _ = psi_round(PSIClient(xs, GROUP, mode=mode),
                       PSIServer(ys, group=GROUP), chunk_size=chunk)
    got, stats = _wire_round(xs, ys, mode=mode, chunk_size=chunk)[:2]
    assert got == ref
    assert sorted(set(got)) == sorted(set(xs) & set(ys))
    assert stats["n_chunks"] == max(1, -(-len(xs) // chunk))


def test_wire_round_parallel_pool_bit_identical():
    """A parallel client-side modexp pool changes nothing about the
    intersection the wire engine returns."""
    xs = [f"id-{i}" for i in range(120)] + ["dup"] * 3
    ys = [f"id-{i + 40}" for i in range(120)] + ["dup"]
    ref, _ = psi_round(PSIClient(xs, GROUP), PSIServer(ys, group=GROUP),
                       chunk_size=32)
    with ModexpPool(2) as pool:
        got, stats, _, _ = _wire_round(xs, ys, chunk_size=32, pool=pool)
    assert got == ref
    assert got.count("dup") == 3


@pytest.mark.parametrize("chunk_size", [13, 64, 4096])
def test_session_resolve_queue_matches_direct(chunk_size):
    """session.resolve(backend="queue") aligns every party to the exact
    ID list the in-process engine produces, at any chunk size."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def build():
        sci, owners = make_vertical_mnist_parties(180, seed=5,
                                                  keep_frac=0.8)
        return VerticalSession(*feature_parties(sci, owners))

    s_d, s_q = build(), build()
    st_d = s_d.resolve(group=GROUP)
    st_q = s_q.resolve(group=GROUP, backend="queue",
                       chunk_size=chunk_size)
    assert s_d.scientist.ids == s_q.scientist.ids
    assert (st_d["global_intersection"] == st_q["global_intersection"])
    for o_d, o_q in zip(s_d.owners, s_q.owners):
        assert o_d.ids == o_q.ids
    assert st_q["backend"] == "queue"
    # protocol-data byte accounting matches the in-process engine's
    for r_d, r_q in zip(st_d["rounds"], st_q["rounds"]):
        assert r_q["client_upload_bytes"] == r_d["client_upload_bytes"]
        assert r_q["upload_wire_bytes"] > 0
        assert r_q["download_wire_bytes"] > 0


def test_session_resolve_queue_parallel_pool_matches_serial():
    """parallelism on the queue backend: ONE modexp pool is shared by
    the client driver and every owner actor thread (executors are
    thread-safe), and the result stays bit-identical to the serial
    direct engine."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def build():
        sci, owners = make_vertical_mnist_parties(160, seed=7,
                                                  keep_frac=0.85)
        return VerticalSession(*feature_parties(sci, owners))

    s_q, s_d = build(), build()
    st_q = s_q.resolve(group=GROUP, backend="queue", parallelism=2,
                       chunk_size=32)
    s_d.resolve(group=GROUP)
    assert s_q.scientist.ids == s_d.scientist.ids
    if st_q["parallelism"]:                      # host allowed workers
        assert st_q["parallelism"] == 2


def test_session_resolve_queue_bloom_mode():
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(120, seed=2, keep_frac=0.9)
    s_d = VerticalSession(*feature_parties(sci, owners))
    sci2, owners2 = make_vertical_mnist_parties(120, seed=2,
                                                keep_frac=0.9)
    s_q = VerticalSession(*feature_parties(sci2, owners2))
    st_d = s_d.resolve(group=GROUP, mode="bloom")
    st_q = s_q.resolve(group=GROUP, mode="bloom", backend="queue",
                       chunk_size=32)
    assert s_d.scientist.ids == s_q.scientist.ids
    assert st_q["rounds"][0]["bloom_bytes"] == \
        st_d["rounds"][0]["bloom_bytes"]
    kinds = {m["kind"] for m in s_q.transcript}
    assert "psi_bloom_shard" in kinds
    assert "psi_server_set_chunk" not in kinds


def test_session_resolve_backend_guardrails():
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(60, seed=0)
    session = VerticalSession(*feature_parties(sci, owners))
    with pytest.raises(ValueError, match="backend"):
        session.resolve(group=GROUP, backend="carrier-pigeon")
    with pytest.raises(ValueError, match="queue"):
        session.resolve(group=GROUP, backend="direct", latency_s=0.01)


# ---------------------------------------------------------------------------
# blinded-upload memoization on the wire (measured bytes, not code)
# ---------------------------------------------------------------------------


def test_repeat_round_same_owner_skips_upload_bytes():
    """Round 2 against the same owner transfers ZERO psi_blind_chunk
    bytes: the server cached the upload by content tag.  Asserted on
    measured channel stats across two owner rounds."""
    xs = [f"id-{i}" for i in range(90)]
    ys = [f"id-{i + 30}" for i in range(90)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        i1, st1 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
        sent_after_r1 = ep_c.sent_stats["by_kind"]["psi_blind_chunk"].copy()
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert i1 == i2
    assert not st1["upload_skipped"] and st2["upload_skipped"]
    after_r2 = ep_c.sent_stats["by_kind"]["psi_blind_chunk"]
    # byte saving: round 2 added no blind-chunk traffic at all
    assert after_r2["payload_bytes"] == sent_after_r1["payload_bytes"]
    assert after_r2["count"] == sent_after_r1["count"]
    # and round 1's upload was exactly the packed blinded set (+ the
    # 8-byte base header per chunk)
    n_chunks = -(-len(xs) // 16)
    assert sent_after_r1["payload_bytes"] == \
        st1["client_upload_bytes"] + 8 * n_chunks
    assert worker.rounds_served == 2


def test_owner_level_blind_cache_survives_actor_recreation():
    """The upload cache lives on the DataOwner, not the actor: a fresh
    channel + fresh PSIServerEndpoint for the same owner still skips the
    re-upload (the session creates actors per resolve)."""
    from repro.federation.parties import DataOwner
    owner = DataOwner("o0", [f"id-{i}" for i in range(40)],
                      np.zeros((40, 2), np.float32))
    client = PSIClient([f"id-{i + 10}" for i in range(40)], GROUP)
    uploads = []
    for _ in range(2):
        ep_c, ep_s = transport.channel_pair("scientist", "o0",
                                            backend="queue")
        worker = owner.psi_endpoint(ep_s, GROUP)
        th = threading.Thread(target=worker.run, daemon=True)
        th.start()
        try:
            _, stats = wire_psi_round(client, ep_c, worker=worker,
                                      chunk_size=8)
        finally:
            ep_c.send("psi_stop", {})
            th.join(timeout=10.0)
        uploads.append(
            ep_c.sent_stats["by_kind"].get(
                "psi_blind_chunk", {"payload_bytes": 0})["payload_bytes"])
    assert uploads[0] > 0 and uploads[1] == 0


def test_session_resolve_logs_blind_reuse_transcript_entry():
    """Owner rounds 2..N reuse the memoized blind — the session must say
    so in the transcript (the PR 4 gap this PR closes), on both
    backends."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    for backend in ("direct", "queue"):
        sci, owners = make_vertical_mnist_parties(100, seed=1, n_owners=4)
        session = VerticalSession(*feature_parties(sci, owners))
        stats = session.resolve(group=GROUP, chunk_size=32,
                                backend=backend)
        reuse = [m for m in session.transcript
                 if m["kind"] == "psi_blind_reuse"]
        assert [m["to"] for m in reuse] == ["owner1", "owner2", "owner3"]
        for m in reuse:
            assert m["recompute_skipped"] is True
            assert m["reused_upload_bytes"] == \
                stats["rounds"][0]["client_upload_bytes"]


# ---------------------------------------------------------------------------
# chaos: reordering, interleaving, crashes, timeouts, degenerate sets
# ---------------------------------------------------------------------------


class _ScramblingEndpoint:
    """Wraps an owner-side endpoint, reordering the first two outgoing
    messages of one kind (chaos: a misbehaving network/owner)."""

    def __init__(self, inner, kind):
        self._inner, self._kind, self._held = inner, kind, None

    def send(self, kind, payload, *, seq=0):
        if kind == self._kind and self._held is None:
            self._held = (kind, payload, seq)
            return None
        out = self._inner.send(kind, payload, seq=seq)
        if self._held is not None and kind == self._kind:
            k, p, s = self._held
            self._held = None
            self._inner.send(k, p, seq=s)
        return out

    def __getattr__(self, name):
        return getattr(self._inner, name)


@pytest.mark.parametrize("kind", ["psi_double_chunk",
                                  "psi_server_set_chunk"])
def test_reordered_chunks_raise_clean_desync(kind):
    """Swapped same-kind chunks must fail loudly with a protocol-desync
    error on the scientist side — never a silently wrong intersection."""
    xs = [f"id-{i}" for i in range(60)]
    ys = [f"id-{i + 20}" for i in range(60)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint("owner0", server,
                               _ScramblingEndpoint(ep_s, kind))
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        with pytest.raises(RuntimeError, match="desync"):
            wire_psi_round(client, ep_c, worker=worker, chunk_size=8,
                           timeout=30.0)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)


class _DelayingEndpoint:
    """Holds back every message of one kind until ``psi_done`` — the
    legal-but-hostile arrival order (kinds fully interleaved/inverted)."""

    def __init__(self, inner, kind):
        self._inner, self._kind, self._held = inner, kind, []

    def send(self, kind, payload, *, seq=0):
        if kind == self._kind:
            self._held.append((kind, payload, seq))
            return None
        if kind == "psi_done":
            for k, p, s in self._held:
                self._inner.send(k, p, seq=s)
            self._held = []
        return self._inner.send(kind, payload, seq=seq)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_desynchronized_kind_arrival_still_exact():
    """Cross-kind arrival order is NOT part of the protocol contract:
    with the whole server-set stream arriving after every double-blind
    response, the stash-based receive still produces the exact
    intersection."""
    xs = [f"id-{i}" for i in range(50)] + ["dup"] * 2
    ys = [f"id-{i + 15}" for i in range(50)] + ["dup"]
    ref, _ = psi_round(PSIClient(xs, GROUP), PSIServer(ys, group=GROUP),
                       chunk_size=8)
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint(
        "owner0", server,
        _DelayingEndpoint(ep_s, "psi_server_set_chunk"))
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        inter, _ = wire_psi_round(client, ep_c, worker=worker,
                                  chunk_size=8, timeout=30.0)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert inter == ref


def test_owner_crash_mid_round_surfaces_cleanly(monkeypatch):
    """An owner actor that dies mid-round (after its first double-blind
    chunk) surfaces as a named RuntimeError on the scientist side within
    the poll interval — not a hang, not a full-timeout stall."""
    calls = {"n": 0}
    real = PSIServer.respond_chunk

    def flaky(self, packed):
        calls["n"] += 1
        if calls["n"] > 1:
            raise ValueError("owner-side kaboom")
        return real(self, packed)

    monkeypatch.setattr(PSIServer, "respond_chunk", flaky)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="PSI owner worker 'owner0'"):
        _wire_round([f"id-{i}" for i in range(60)],
                    [f"id-{i + 20}" for i in range(60)], chunk_size=8,
                    timeout=60.0)
    assert time.monotonic() - t0 < 30.0


def test_session_resolve_queue_surfaces_owner_crash(monkeypatch):
    """The same crash through the full session.resolve surface."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def boom(self, packed):
        raise ValueError("owner-side kaboom")

    monkeypatch.setattr(PSIServer, "respond_chunk", boom)
    sci, owners = make_vertical_mnist_parties(80, seed=0)
    session = VerticalSession(*feature_parties(sci, owners))
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="PSI owner worker"):
        session.resolve(group=GROUP, backend="queue", chunk_size=16)
    assert time.monotonic() - t0 < 30.0


def test_unresponsive_owner_times_out_cleanly():
    """A wedged owner (thread never started) bounds the round by the
    receive deadline instead of hanging the scientist forever."""
    client = PSIClient(["a", "b"], GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="timed out"):
        wire_psi_round(client, ep_c, chunk_size=1, timeout=2.5)
    assert 2.0 < time.monotonic() - t0 < 10.0


def test_group_mismatch_surfaces_cleanly():
    client = PSIClient(["a", "b"], "modp512")
    server = PSIServer(["b", "c"], group="modp2048")
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        with pytest.raises(RuntimeError, match="PSI owner worker"):
            wire_psi_round(client, ep_c, worker=worker, chunk_size=1,
                           timeout=30.0)
        assert "mismatch" in repr(worker.error)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)


@pytest.mark.parametrize("mode", ["noinv", "bloom"])
def test_degenerate_sets_over_the_wire(mode):
    """Empty / disjoint / duplicate-heavy sets round-trip the wire with
    the exact in-process results."""
    cases = [([], ["a"]), (["a"], []), ([], []),
             (["a", "b"], ["c", "d"]),                      # disjoint
             (["x"] * 5 + ["y"], ["x", "z"]),               # duplicates
             (["solo"], ["solo"])]
    for xs, ys in cases:
        ref, _ = psi_round(PSIClient(xs, GROUP, mode=mode),
                           PSIServer(ys, group=GROUP), chunk_size=2)
        got, stats = _wire_round(xs, ys, mode=mode, chunk_size=2)[:2]
        assert got == ref, (xs, ys, mode)
        assert stats["client_upload_bytes"] == NB * len(xs)


# ---------------------------------------------------------------------------
# golden wire-frame conformance (frozen layouts)
# ---------------------------------------------------------------------------

# Byte-exact frames for fixed payloads: any change to the frame format
# OR to a PSI kind's payload schema (entry names, order, dtypes) fails
# these.  Layout: [u32 n_entries] then per entry [u16 len][name]
# [u16 len][dtype.name][u8 ndim][i64 dims...][i64 nbytes][buffer],
# little-endian throughout (docs/WIRE_PROTOCOL.md §1).
GOLDEN_FRAMES = {
    "psi_hello":
        "0600000004006d6f6465050075696e7438010500000000000000050000000000"
        "00006e6f696e76050067726f7570050075696e74380107000000000000000700"
        "0000000000006d6f64703531320900626c696e645f746167050075696e743801"
        "1000000000000000100000000000000030313233343536373839616263646566"
        "07006e5f6974656d730500696e74363401010000000000000008000000000000"
        "0003000000000000000a006368756e6b5f73697a650500696e74363401010000"
        "00000000000800000000000000020000000000000002006e620500696e743634"
        "01010000000000000008000000000000004000000000000000",
    "psi_blind_chunk":
        "02000000040064617461050075696e7438010800000000000000080000000000"
        "000000010203040506070400626173650500696e743634010100000000000000"
        "08000000000000000000000000000000",
    "psi_hello_ack_noinv":
        "030000000c00626c696e645f636163686564050075696e743801010000000000"
        "00000100000000000000000e006e5f7365727665725f6974656d730500696e74"
        "3634010100000000000000080000000000000003000000000000000f006e5f73"
        "65727665725f6368756e6b730500696e74363401010000000000000008000000"
        "000000000200000000000000",
    "psi_hello_ack_bloom":
        "050000000c00626c696e645f636163686564050075696e743801010000000000"
        "00000100000000000000010e006e5f7365727665725f6974656d730500696e74"
        "36340101000000000000000800000000000000030000000000000008006e5f73"
        "68617264730500696e7436340101000000000000000800000000000000010000"
        "00000000000c0073686172645f6e5f626974730500696e743634010100000000"
        "000000080000000000000080000000000000000e0073686172645f6e5f686173"
        "6865730500696e74363401010000000000000008000000000000001e00000000"
        "000000",
    "psi_server_set_chunk":
        "02000000040064617461050075696e7438010400000000000000040000000000"
        "0000000102030400626173650500696e74363401010000000000000008000000"
        "000000000200000000000000",
    "psi_double_chunk":
        "02000000040064617461050075696e7438010400000000000000040000000000"
        "0000000102030400626173650500696e74363401010000000000000008000000"
        "000000000200000000000000",
    "psi_bloom_shard":
        "01000000040064617461050075696e7438010200000000000000020000000000"
        "0000ff00",
    "psi_done":
        "0100000008006e5f6368756e6b730500696e7436340101000000000000000800"
        "0000000000000200000000000000",
    "empty": "00000000",
}


def _u8(b):
    return np.frombuffer(b, np.uint8)


def _canonical_payloads():
    """The fixed payloads the goldens were frozen from — mirroring the
    exact dict construction order of the live actors."""
    return {
        "psi_hello": {"mode": _u8(b"noinv"), "group": _u8(b"modp512"),
                      "blind_tag": _u8(b"0123456789abcdef"),
                      "n_items": np.int64(3), "chunk_size": np.int64(2),
                      "nb": np.int64(64)},
        "psi_blind_chunk": {"data": _u8(bytes(range(8))),
                            "base": np.int64(0)},
        "psi_hello_ack_noinv": {"blind_cached": np.uint8(0),
                                "n_server_items": np.int64(3),
                                "n_server_chunks": np.int64(2)},
        "psi_hello_ack_bloom": {"blind_cached": np.uint8(1),
                                "n_server_items": np.int64(3),
                                "n_shards": np.int64(1),
                                "shard_n_bits": np.int64(128),
                                "shard_n_hashes": np.int64(30)},
        "psi_server_set_chunk": {"data": _u8(bytes(range(4))),
                                 "base": np.int64(2)},
        "psi_double_chunk": {"data": _u8(bytes(range(4))),
                             "base": np.int64(2)},
        "psi_bloom_shard": {"data": _u8(b"\xff\x00")},
        "psi_done": {"n_chunks": np.int64(2)},
        "empty": {},
    }


def _parse_frame(blob):
    """Independent minimal parser of the documented layout (deliberately
    NOT _unpack — this is the conformance oracle)."""
    (n,) = struct.unpack_from("<I", blob, 0)
    off = 4
    entries = []
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", blob, off)
        off += 2
        name = blob[off:off + ln].decode()
        off += ln
        (ld,) = struct.unpack_from("<H", blob, off)
        off += 2
        dtype = blob[off:off + ld].decode()
        off += ld
        (ndim,) = struct.unpack_from("<B", blob, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", blob, off)
        off += 8 * ndim
        (nbytes,) = struct.unpack_from("<q", blob, off)
        off += 8
        entries.append((name, dtype, shape, blob[off:off + nbytes]))
        off += nbytes
    assert off == len(blob), "trailing bytes in frame"
    return entries


def test_golden_frames_byte_exact():
    for kind, payload in _canonical_payloads().items():
        assert _pack(payload).hex() == GOLDEN_FRAMES[kind], \
            f"wire frame layout changed for {kind}"


def test_golden_frames_parse_and_round_trip():
    for kind, payload in _canonical_payloads().items():
        blob = bytes.fromhex(GOLDEN_FRAMES[kind])
        entries = _parse_frame(blob)
        assert [e[0] for e in entries] == list(payload)
        back = _unpack(blob)
        assert set(back) == set(payload)
        for name in payload:
            np.testing.assert_array_equal(np.asarray(back[name]),
                                          np.asarray(payload[name]))
            assert back[name].dtype == np.asarray(payload[name]).dtype


def test_pack_round_trips_zero_length_and_max_chunk_payloads():
    # empty payload dict and a zero-length chunk (an owner with no rows)
    assert _pack({}) == b"\x00\x00\x00\x00"
    assert _unpack(_pack({})) == {}
    zero = {"data": np.zeros(0, np.uint8), "base": np.int64(0)}
    back = _unpack(_pack(zero))
    assert back["data"].shape == (0,) and back["data"].dtype == np.uint8
    # a full DEFAULT_CHUNK noinv chunk at modp2048 width (the largest
    # frame the protocol emits): exact payload + header-overhead budget
    from repro.core.psi import DEFAULT_CHUNK
    data = np.arange(DEFAULT_CHUNK * 256, dtype=np.uint64)
    data = (data % 251).astype(np.uint8)
    blob = _pack({"data": data, "base": np.int64(12345)})
    back = _unpack(blob)
    np.testing.assert_array_equal(back["data"], data)
    assert back["base"].reshape(-1)[0] == 12345
    overhead = len(blob) - data.nbytes - 8
    assert overhead < 128                      # headers stay tiny


def test_live_traffic_conforms_to_frame_schema():
    """Parse every frame of a real round with the independent parser and
    check each kind's entry schema (names, dtypes) — the conformance
    gate on actual traffic, not synthetic payloads."""
    captured = []
    xs = [f"id-{i}" for i in range(20)]
    ys = [f"id-{i + 5}" for i in range(20)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair(
        "scientist", "owner0", backend="queue",
        tap=lambda msg, blob: captured.append((msg.kind, blob)))
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        wire_psi_round(client, ep_c, worker=worker, chunk_size=4)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    schema = {
        "psi_hello": [("mode", "uint8"), ("group", "uint8"),
                      ("blind_tag", "uint8"), ("n_items", "int64"),
                      ("chunk_size", "int64"), ("nb", "int64")],
        "psi_hello_ack": [("blind_cached", "uint8"),
                          ("n_server_items", "int64"),
                          ("n_server_chunks", "int64")],
        "psi_blind_chunk": [("data", "uint8"), ("base", "int64")],
        "psi_server_set_chunk": [("data", "uint8"), ("base", "int64")],
        "psi_double_chunk": [("data", "uint8"), ("base", "int64")],
        "psi_done": [("n_chunks", "int64")],
        "psi_stop": [],
    }
    seen = set()
    for kind, blob in captured:
        seen.add(kind)
        entries = _parse_frame(blob)
        assert [(e[0], e[1]) for e in entries] == schema[kind], kind
        assert kind in WIRE_KINDS or kind == "psi_stop"
    assert {"psi_hello", "psi_hello_ack", "psi_blind_chunk",
            "psi_server_set_chunk", "psi_double_chunk",
            "psi_done"} <= seen


# ---------------------------------------------------------------------------
# privacy on the wire (observed traffic, not code inspection)
# ---------------------------------------------------------------------------


def _resolve_with_tap(mode):
    """session.resolve(backend="queue") with every serialized frame
    captured.  Returns (session, [(sender, kind, blob)])."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    captured = []
    orig = transport.channel_pair

    def tapped(a, b, **kw):
        kw["tap"] = lambda msg, blob: captured.append(
            (msg.sender, msg.kind, blob))
        return orig(a, b, **kw)

    transport.channel_pair = tapped
    try:
        sci, owners = make_vertical_mnist_parties(80, seed=4,
                                                  keep_frac=0.9)
        session = VerticalSession(*feature_parties(sci, owners))
        session.resolve(group=GROUP, mode=mode, backend="queue",
                        chunk_size=16)
    finally:
        transport.channel_pair = orig
    return session, captured


@pytest.mark.parametrize("mode", ["noinv", "bloom"])
def test_no_raw_ids_on_the_wire(mode):
    """Every byte of every frame of a full resolve: raw IDs never cross
    in any encoding the protocol could accidentally emit — plaintext,
    sha256(id), or the unblinded group element H(id)."""
    import hashlib
    from repro.core.psi import hash_to_group
    session, captured = _resolve_with_tap(mode)
    assert captured, "tap captured no traffic"
    all_ids = set(session.scientist.ids)
    for o in session.owners:
        all_ids |= set(o.ids)
    p = GROUPS[GROUP][0]
    needles = []
    for i in sorted(all_ids)[:40]:                    # bound test cost
        needles.append(i.encode())
        needles.append(hashlib.sha256(i.encode()).digest())
        needles.append(hash_to_group(i.encode(), p, NB).to_bytes(NB,
                                                                 "big"))
    blobs = b"\x00".join(blob for _, _, blob in captured)
    for needle in needles:
        assert needle not in blobs, \
            f"identifying bytes leaked onto the wire: {needle[:16]!r}"


def test_bloom_mode_server_set_crosses_only_compressed():
    """In bloom mode the owner's set reaches the scientist ONLY as bloom
    shard bitmaps, within the Angelou et al. byte budget (~12x under the
    raw packed set) — asserted on the measured frames."""
    session, captured = _resolve_with_tap("bloom")
    owner_kinds = {k for s, k, _ in captured if s != "scientist"}
    assert "psi_server_set_chunk" not in owner_kinds
    assert "psi_bloom_shard" in owner_kinds
    for owner in session.owners:
        raw = NB * owner.n_rows
        shard_bytes = sum(
            len(b) for s, k, b in captured
            if s == owner.name and k == "psi_bloom_shard")
        assert 0 < shard_bytes < raw / 8, \
            "bloom frames exceed the compression byte budget"


def test_only_protocol_kinds_cross_the_boundary():
    _, captured = _resolve_with_tap("noinv")
    assert {k for _, k, _ in captured} <= set(WIRE_KINDS)
    assert set(CLIENT_KINDS) & {k for s, k, _ in captured
                                if s == "scientist"}
    assert set(SERVER_KINDS) & {k for s, k, _ in captured
                                if s != "scientist"}


# ---------------------------------------------------------------------------
# pipelining under injected latency
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pipelined_chunks_amortize_latency():
    """With 8 ms one-way latency and 12 chunks in flight, the round pays
    O(1) RTTs, not one RTT per chunk (the sequential floor).  Bounded
    generously for CI noise; the tight version is the BENCH_psi wire
    gate."""
    xs = [f"id-{i}" for i in range(96)]
    ys = [f"id-{i + 32}" for i in range(96)]
    lat = 8e-3
    n_chunks = 12

    def once(latency):
        t0 = time.perf_counter()
        inter = _wire_round(xs, ys, chunk_size=8, latency_s=latency)[0]
        assert sorted(set(inter)) == sorted(set(xs) & set(ys))
        return time.perf_counter() - t0

    base = min(once(0.0) for _ in range(2))
    timed = min(once(lat) for _ in range(2))
    seq_floor = n_chunks * 2 * lat                    # per-chunk RTTs
    assert timed - base < 0.75 * seq_floor, \
        (f"latency not amortized: {1e3 * (timed - base):.0f} ms added "
         f"vs sequential floor {1e3 * seq_floor:.0f} ms")
