"""Wire-native PSI (ISSUE 5): entity resolution over the transport layer
must be bit-identical to the in-process engine, survive protocol chaos
(reordered chunks, mid-round owner crashes, degenerate sets) with correct
results or clean surfaced errors, keep its frame layouts frozen (golden
conformance), and leak nothing but blinded bytes onto the wire."""
import struct
import threading
import time

import numpy as np
import pytest

from repro.testing.hypo import given, settings, strategies as st

from repro.core.modexp import ModexpPool
from repro.core.psi import GROUPS, PSIClient, PSIServer, psi_round
from repro.federation import transport
from repro.federation.psi_transport import (CLIENT_KINDS, SERVER_KINDS,
                                            WIRE_KINDS, PSIServerEndpoint,
                                            blind_tag, serve_psi,
                                            wire_psi_round)
from repro.federation.transport import _pack, _unpack

GROUP = "modp512"
NB = GROUPS[GROUP][2]


def _wire_round(xs, ys, *, mode="noinv", chunk_size=16, latency_s=0.0,
                pool=None, timeout=120.0):
    """One full wire round over a fresh queue channel pair.  Returns
    (intersection, stats, client_endpoint, worker)."""
    client = PSIClient(xs, GROUP, mode=mode)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue",
                                        latency_s=latency_s)
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        inter, stats = wire_psi_round(client, ep_c, worker=worker,
                                      pool=pool, chunk_size=chunk_size,
                                      timeout=timeout)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    return inter, stats, ep_c, worker


# ---------------------------------------------------------------------------
# bit-identity: wire engine == in-process engine
# ---------------------------------------------------------------------------


@given(st.lists(st.text(min_size=1, max_size=8), min_size=0, max_size=40),
       st.lists(st.text(min_size=1, max_size=8), min_size=0, max_size=40),
       st.integers(1, 17),
       st.sampled_from(["noinv", "bloom"]))
@settings(max_examples=8, deadline=None)
def test_wire_round_bit_identical_to_in_process(xs, ys, chunk, mode):
    """Random uneven sets (duplicates allowed), both protocol variants,
    any chunk size: the wire engine returns the exact intersection list
    — same elements, same client order, same duplicate multiplicity —
    as the in-process PR 4 engine."""
    ref, _ = psi_round(PSIClient(xs, GROUP, mode=mode),
                       PSIServer(ys, group=GROUP), chunk_size=chunk)
    got, stats = _wire_round(xs, ys, mode=mode, chunk_size=chunk)[:2]
    assert got == ref
    assert sorted(set(got)) == sorted(set(xs) & set(ys))
    assert stats["n_chunks"] == max(1, -(-len(xs) // chunk))


def test_wire_round_parallel_pool_bit_identical():
    """A parallel client-side modexp pool changes nothing about the
    intersection the wire engine returns."""
    xs = [f"id-{i}" for i in range(120)] + ["dup"] * 3
    ys = [f"id-{i + 40}" for i in range(120)] + ["dup"]
    ref, _ = psi_round(PSIClient(xs, GROUP), PSIServer(ys, group=GROUP),
                       chunk_size=32)
    with ModexpPool(2) as pool:
        got, stats, _, _ = _wire_round(xs, ys, chunk_size=32, pool=pool)
    assert got == ref
    assert got.count("dup") == 3


@pytest.mark.parametrize("chunk_size", [13, 64, 4096])
def test_session_resolve_queue_matches_direct(chunk_size):
    """session.resolve(backend="queue") aligns every party to the exact
    ID list the in-process engine produces, at any chunk size."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def build():
        sci, owners = make_vertical_mnist_parties(180, seed=5,
                                                  keep_frac=0.8)
        return VerticalSession(*feature_parties(sci, owners))

    s_d, s_q = build(), build()
    st_d = s_d.resolve(group=GROUP)
    st_q = s_q.resolve(group=GROUP, backend="queue",
                       chunk_size=chunk_size)
    assert s_d.scientist.ids == s_q.scientist.ids
    assert (st_d["global_intersection"] == st_q["global_intersection"])
    for o_d, o_q in zip(s_d.owners, s_q.owners):
        assert o_d.ids == o_q.ids
    assert st_q["backend"] == "queue"
    # protocol-data byte accounting matches the in-process engine's
    for r_d, r_q in zip(st_d["rounds"], st_q["rounds"]):
        assert r_q["client_upload_bytes"] == r_d["client_upload_bytes"]
        assert r_q["upload_wire_bytes"] > 0
        assert r_q["download_wire_bytes"] > 0


def test_session_resolve_queue_parallel_pool_matches_serial():
    """parallelism on the queue backend: ONE modexp pool is shared by
    the client driver and every owner actor thread (executors are
    thread-safe), and the result stays bit-identical to the serial
    direct engine."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def build():
        sci, owners = make_vertical_mnist_parties(160, seed=7,
                                                  keep_frac=0.85)
        return VerticalSession(*feature_parties(sci, owners))

    s_q, s_d = build(), build()
    st_q = s_q.resolve(group=GROUP, backend="queue", parallelism=2,
                       chunk_size=32)
    s_d.resolve(group=GROUP)
    assert s_q.scientist.ids == s_d.scientist.ids
    if st_q["parallelism"]:                      # host allowed workers
        assert st_q["parallelism"] == 2


def test_session_resolve_queue_bloom_mode():
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(120, seed=2, keep_frac=0.9)
    s_d = VerticalSession(*feature_parties(sci, owners))
    sci2, owners2 = make_vertical_mnist_parties(120, seed=2,
                                                keep_frac=0.9)
    s_q = VerticalSession(*feature_parties(sci2, owners2))
    st_d = s_d.resolve(group=GROUP, mode="bloom")
    st_q = s_q.resolve(group=GROUP, mode="bloom", backend="queue",
                       chunk_size=32)
    assert s_d.scientist.ids == s_q.scientist.ids
    assert st_q["rounds"][0]["bloom_bytes"] == \
        st_d["rounds"][0]["bloom_bytes"]
    kinds = {m["kind"] for m in s_q.transcript}
    assert "psi_bloom_shard" in kinds
    assert "psi_server_set_chunk" not in kinds


def test_session_resolve_backend_guardrails():
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(60, seed=0)
    session = VerticalSession(*feature_parties(sci, owners))
    with pytest.raises(ValueError, match="backend"):
        session.resolve(group=GROUP, backend="carrier-pigeon")
    with pytest.raises(ValueError, match="queue"):
        session.resolve(group=GROUP, backend="direct", latency_s=0.01)


# ---------------------------------------------------------------------------
# blinded-upload memoization on the wire (measured bytes, not code)
# ---------------------------------------------------------------------------


def test_repeat_round_same_owner_skips_upload_bytes():
    """Round 2 against the same owner transfers ZERO psi_blind_chunk
    bytes: the server cached the upload by content tag.  Asserted on
    measured channel stats across two owner rounds."""
    xs = [f"id-{i}" for i in range(90)]
    ys = [f"id-{i + 30}" for i in range(90)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        i1, st1 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
        sent_after_r1 = ep_c.sent_stats["by_kind"]["psi_blind_chunk"].copy()
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert i1 == i2
    assert not st1["upload_skipped"] and st2["upload_skipped"]
    after_r2 = ep_c.sent_stats["by_kind"]["psi_blind_chunk"]
    # byte saving: round 2 added no blind-chunk traffic at all
    assert after_r2["payload_bytes"] == sent_after_r1["payload_bytes"]
    assert after_r2["count"] == sent_after_r1["count"]
    # and round 1's upload was exactly the packed blinded set (+ the
    # 8-byte base header per chunk)
    n_chunks = -(-len(xs) // 16)
    assert sent_after_r1["payload_bytes"] == \
        st1["client_upload_bytes"] + 8 * n_chunks
    assert worker.rounds_served == 2


def test_owner_level_blind_cache_survives_actor_recreation():
    """The upload cache lives on the DataOwner, not the actor: a fresh
    channel + fresh PSIServerEndpoint for the same owner still skips the
    re-upload (the session creates actors per resolve)."""
    from repro.federation.parties import DataOwner
    owner = DataOwner("o0", [f"id-{i}" for i in range(40)],
                      np.zeros((40, 2), np.float32))
    client = PSIClient([f"id-{i + 10}" for i in range(40)], GROUP)
    uploads = []
    for _ in range(2):
        ep_c, ep_s = transport.channel_pair("scientist", "o0",
                                            backend="queue")
        worker = owner.psi_endpoint(ep_s, GROUP)
        th = threading.Thread(target=worker.run, daemon=True)
        th.start()
        try:
            _, stats = wire_psi_round(client, ep_c, worker=worker,
                                      chunk_size=8)
        finally:
            ep_c.send("psi_stop", {})
            th.join(timeout=10.0)
        uploads.append(
            ep_c.sent_stats["by_kind"].get(
                "psi_blind_chunk", {"payload_bytes": 0})["payload_bytes"])
    assert uploads[0] > 0 and uploads[1] == 0


def test_session_resolve_logs_blind_reuse_transcript_entry():
    """Owner rounds 2..N reuse the memoized blind — the session must say
    so in the transcript (the PR 4 gap this PR closes), on both
    backends."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    for backend in ("direct", "queue"):
        sci, owners = make_vertical_mnist_parties(100, seed=1, n_owners=4)
        session = VerticalSession(*feature_parties(sci, owners))
        stats = session.resolve(group=GROUP, chunk_size=32,
                                backend=backend)
        reuse = [m for m in session.transcript
                 if m["kind"] == "psi_blind_reuse"]
        assert [m["to"] for m in reuse] == ["owner1", "owner2", "owner3"]
        for m in reuse:
            assert m["recompute_skipped"] is True
            assert m["reused_upload_bytes"] == \
                stats["rounds"][0]["client_upload_bytes"]


# ---------------------------------------------------------------------------
# chaos: reordering, interleaving, crashes, timeouts, degenerate sets
# ---------------------------------------------------------------------------


class _ScramblingEndpoint:
    """Wraps an owner-side endpoint, reordering the first two outgoing
    messages of one kind (chaos: a misbehaving network/owner)."""

    def __init__(self, inner, kind):
        self._inner, self._kind, self._held = inner, kind, None

    def send(self, kind, payload, *, seq=0):
        if kind == self._kind and self._held is None:
            self._held = (kind, payload, seq)
            return None
        out = self._inner.send(kind, payload, seq=seq)
        if self._held is not None and kind == self._kind:
            k, p, s = self._held
            self._held = None
            self._inner.send(k, p, seq=s)
        return out

    def __getattr__(self, name):
        return getattr(self._inner, name)


@pytest.mark.parametrize("kind", ["psi_double_chunk",
                                  "psi_server_set_chunk"])
def test_reordered_chunks_raise_clean_desync(kind):
    """Swapped same-kind chunks must fail loudly with a protocol-desync
    error on the scientist side — never a silently wrong intersection."""
    xs = [f"id-{i}" for i in range(60)]
    ys = [f"id-{i + 20}" for i in range(60)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint("owner0", server,
                               _ScramblingEndpoint(ep_s, kind))
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        with pytest.raises(RuntimeError, match="desync"):
            wire_psi_round(client, ep_c, worker=worker, chunk_size=8,
                           timeout=30.0)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)


class _DelayingEndpoint:
    """Holds back every message of one kind until ``psi_done`` — the
    legal-but-hostile arrival order (kinds fully interleaved/inverted)."""

    def __init__(self, inner, kind):
        self._inner, self._kind, self._held = inner, kind, []

    def send(self, kind, payload, *, seq=0):
        if kind == self._kind:
            self._held.append((kind, payload, seq))
            return None
        if kind == "psi_done":
            for k, p, s in self._held:
                self._inner.send(k, p, seq=s)
            self._held = []
        return self._inner.send(kind, payload, seq=seq)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_desynchronized_kind_arrival_still_exact():
    """Cross-kind arrival order is NOT part of the protocol contract:
    with the whole server-set stream arriving after every double-blind
    response, the stash-based receive still produces the exact
    intersection."""
    xs = [f"id-{i}" for i in range(50)] + ["dup"] * 2
    ys = [f"id-{i + 15}" for i in range(50)] + ["dup"]
    ref, _ = psi_round(PSIClient(xs, GROUP), PSIServer(ys, group=GROUP),
                       chunk_size=8)
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint(
        "owner0", server,
        _DelayingEndpoint(ep_s, "psi_server_set_chunk"))
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        inter, _ = wire_psi_round(client, ep_c, worker=worker,
                                  chunk_size=8, timeout=30.0)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert inter == ref


def test_owner_crash_mid_round_surfaces_cleanly(monkeypatch):
    """An owner actor that dies mid-round (after its first double-blind
    chunk) surfaces as a named RuntimeError on the scientist side within
    the poll interval — not a hang, not a full-timeout stall."""
    calls = {"n": 0}
    real = PSIServer.respond_chunk

    def flaky(self, packed):
        calls["n"] += 1
        if calls["n"] > 1:
            raise ValueError("owner-side kaboom")
        return real(self, packed)

    monkeypatch.setattr(PSIServer, "respond_chunk", flaky)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="PSI owner worker 'owner0'"):
        _wire_round([f"id-{i}" for i in range(60)],
                    [f"id-{i + 20}" for i in range(60)], chunk_size=8,
                    timeout=60.0)
    assert time.monotonic() - t0 < 30.0


def test_session_resolve_queue_surfaces_owner_crash(monkeypatch):
    """The same crash through the full session.resolve surface."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def boom(self, packed):
        raise ValueError("owner-side kaboom")

    monkeypatch.setattr(PSIServer, "respond_chunk", boom)
    sci, owners = make_vertical_mnist_parties(80, seed=0)
    session = VerticalSession(*feature_parties(sci, owners))
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="PSI owner worker"):
        session.resolve(group=GROUP, backend="queue", chunk_size=16)
    assert time.monotonic() - t0 < 30.0


def test_unresponsive_owner_times_out_cleanly():
    """A wedged owner (thread never started) bounds the round by the
    receive deadline instead of hanging the scientist forever."""
    client = PSIClient(["a", "b"], GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="timed out"):
        wire_psi_round(client, ep_c, chunk_size=1, timeout=2.5)
    assert 2.0 < time.monotonic() - t0 < 10.0


def test_group_mismatch_surfaces_cleanly():
    client = PSIClient(["a", "b"], "modp512")
    server = PSIServer(["b", "c"], group="modp2048")
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        with pytest.raises(RuntimeError, match="PSI owner worker"):
            wire_psi_round(client, ep_c, worker=worker, chunk_size=1,
                           timeout=30.0)
        assert "mismatch" in repr(worker.error)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)


@pytest.mark.parametrize("mode", ["noinv", "bloom"])
def test_degenerate_sets_over_the_wire(mode):
    """Empty / disjoint / duplicate-heavy sets round-trip the wire with
    the exact in-process results."""
    cases = [([], ["a"]), (["a"], []), ([], []),
             (["a", "b"], ["c", "d"]),                      # disjoint
             (["x"] * 5 + ["y"], ["x", "z"]),               # duplicates
             (["solo"], ["solo"])]
    for xs, ys in cases:
        ref, _ = psi_round(PSIClient(xs, GROUP, mode=mode),
                           PSIServer(ys, group=GROUP), chunk_size=2)
        got, stats = _wire_round(xs, ys, mode=mode, chunk_size=2)[:2]
        assert got == ref, (xs, ys, mode)
        assert stats["client_upload_bytes"] == NB * len(xs)


# ---------------------------------------------------------------------------
# golden wire-frame conformance (frozen layouts)
# ---------------------------------------------------------------------------

# Byte-exact frames for fixed payloads: any change to the frame format
# OR to a PSI kind's payload schema (entry names, order, dtypes) fails
# these.  Layout: [u32 n_entries] then per entry [u16 len][name]
# [u16 len][dtype.name][u8 ndim][i64 dims...][i64 nbytes][buffer],
# little-endian throughout (docs/WIRE_PROTOCOL.md §1).
GOLDEN_FRAMES = {
    "psi_hello":
        "0900000004006d6f6465050075696e7438010500000000000000050000000000"
        "00006e6f696e76050067726f7570050075696e74380107000000000000000700"
        "0000000000006d6f64703531320900626c696e645f746167050075696e743801"
        "1000000000000000100000000000000030313233343536373839616263646566"
        "0800626173655f746167050075696e7438011000000000000000100000000000"
        "0000000000000000000000000000000000000a007365727665725f7461670500"
        "75696e7438011000000000000000100000000000000000000000000000000000"
        "0000000000000900686176655f72657370050075696e74380101000000000000"
        "0001000000000000000007006e5f6974656d730500696e743634010100000000"
        "000000080000000000000003000000000000000a006368756e6b5f73697a6505"
        "00696e7436340101000000000000000800000000000000020000000000000002"
        "006e620500696e74363401010000000000000008000000000000004000000000"
        "000000",
    "psi_blind_chunk":
        "02000000040064617461050075696e7438010800000000000000080000000000"
        "000000010203040506070400626173650500696e743634010100000000000000"
        "08000000000000000000000000000000",
    "psi_delta_chunk":
        "03000000040064617461050075696e7438010800000000000000080000000000"
        "00000001020304050607070072656d6f7665640500696e743634010200000000"
        "0000001000000000000000010000000000000003000000000000000a006e5f72"
        "657461696e65640500696e743634010100000000000000080000000000000002"
        "00000000000000",
    "psi_lift_chunk":
        "02000000040064617461050075696e7438010400000000000000040000000000"
        "0000000102030400626173650500696e74363401010000000000000008000000"
        "000000000200000000000000",
    "psi_hello_ack_noinv":
        "060000000c00626c696e645f636163686564050075696e743801010000000000"
        "0000010000000000000000080064656c74615f6f6b050075696e743801010000"
        "00000000000100000000000000000d007365727665725f636163686564050075"
        "696e74380101000000000000000100000000000000000a007365727665725f74"
        "6167050075696e74380110000000000000001000000000000000666564636261"
        "393837363534333231300e006e5f7365727665725f6974656d730500696e7436"
        "34010100000000000000080000000000000003000000000000000f006e5f7365"
        "727665725f6368756e6b730500696e7436340101000000000000000800000000"
        "0000000200000000000000",
    "psi_hello_ack_bloom":
        "080000000c00626c696e645f636163686564050075696e743801010000000000"
        "0000010000000000000001080064656c74615f6f6b050075696e743801010000"
        "00000000000100000000000000000d007365727665725f636163686564050075"
        "696e74380101000000000000000100000000000000000a007365727665725f74"
        "6167050075696e74380110000000000000001000000000000000666564636261"
        "393837363534333231300e006e5f7365727665725f6974656d730500696e7436"
        "340101000000000000000800000000000000030000000000000008006e5f7368"
        "617264730500696e743634010100000000000000080000000000000001000000"
        "000000000c0073686172645f6e5f626974730500696e74363401010000000000"
        "0000080000000000000080000000000000000e0073686172645f6e5f68617368"
        "65730500696e74363401010000000000000008000000000000001e0000000000"
        "0000",
    "psi_server_set_chunk":
        "02000000040064617461050075696e7438010400000000000000040000000000"
        "0000000102030400626173650500696e74363401010000000000000008000000"
        "000000000200000000000000",
    "psi_double_chunk":
        "02000000040064617461050075696e7438010400000000000000040000000000"
        "0000000102030400626173650500696e74363401010000000000000008000000"
        "000000000200000000000000",
    "psi_delta_ack":
        "02000000040064617461050075696e7438010400000000000000040000000000"
        "00000001020307006e5f746f74616c0500696e74363401010000000000000008"
        "000000000000000300000000000000",
    "psi_keep_mask":
        "0200000004006b6565700500696e743634010300000000000000180000000000"
        "00000000000000000000020000000000000005000000000000000400726f7773"
        "0500696e74363401030000000000000018000000000000000700000000000000"
        "01000000000000000400000000000000",
    "psi_bloom_shard":
        "01000000040064617461050075696e7438010200000000000000020000000000"
        "0000ff00",
    "psi_done":
        "0200000008006e5f6368756e6b730500696e7436340101000000000000000800"
        "00000000000002000000000000000a006d6f646578705f6f70730500696e7436"
        "3401010000000000000008000000000000000500000000000000",
    "empty": "00000000",
}


def _u8(b):
    return np.frombuffer(b, np.uint8)


def _canonical_payloads():
    """The fixed payloads the goldens were frozen from — mirroring the
    exact dict construction order of the live actors."""
    zero_tag = b"\x00" * 16
    return {
        "psi_hello": {"mode": _u8(b"noinv"), "group": _u8(b"modp512"),
                      "blind_tag": _u8(b"0123456789abcdef"),
                      "base_tag": _u8(zero_tag),
                      "server_tag": _u8(zero_tag),
                      "have_resp": np.uint8(0),
                      "n_items": np.int64(3), "chunk_size": np.int64(2),
                      "nb": np.int64(64)},
        "psi_blind_chunk": {"data": _u8(bytes(range(8))),
                            "base": np.int64(0)},
        "psi_delta_chunk": {"data": _u8(bytes(range(8))),
                            "removed": np.array([1, 3], np.int64),
                            "n_retained": np.int64(2)},
        "psi_lift_chunk": {"data": _u8(bytes(range(4))),
                           "base": np.int64(2)},
        "psi_hello_ack_noinv": {"blind_cached": np.uint8(0),
                                "delta_ok": np.uint8(0),
                                "server_cached": np.uint8(0),
                                "server_tag": _u8(b"fedcba9876543210"),
                                "n_server_items": np.int64(3),
                                "n_server_chunks": np.int64(2)},
        "psi_hello_ack_bloom": {"blind_cached": np.uint8(1),
                                "delta_ok": np.uint8(0),
                                "server_cached": np.uint8(0),
                                "server_tag": _u8(b"fedcba9876543210"),
                                "n_server_items": np.int64(3),
                                "n_shards": np.int64(1),
                                "shard_n_bits": np.int64(128),
                                "shard_n_hashes": np.int64(30)},
        "psi_server_set_chunk": {"data": _u8(bytes(range(4))),
                                 "base": np.int64(2)},
        "psi_double_chunk": {"data": _u8(bytes(range(4))),
                             "base": np.int64(2)},
        "psi_delta_ack": {"data": _u8(bytes(range(4))),
                          "n_total": np.int64(3)},
        "psi_keep_mask": {"keep": np.array([0, 2, 5], np.int64),
                          "rows": np.array([7, 1, 4], np.int64)},
        "psi_bloom_shard": {"data": _u8(b"\xff\x00")},
        "psi_done": {"n_chunks": np.int64(2),
                     "modexp_ops": np.int64(5)},
        "empty": {},
    }


def _parse_frame(blob):
    """Independent minimal parser of the documented layout (deliberately
    NOT _unpack — this is the conformance oracle)."""
    (n,) = struct.unpack_from("<I", blob, 0)
    off = 4
    entries = []
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", blob, off)
        off += 2
        name = blob[off:off + ln].decode()
        off += ln
        (ld,) = struct.unpack_from("<H", blob, off)
        off += 2
        dtype = blob[off:off + ld].decode()
        off += ld
        (ndim,) = struct.unpack_from("<B", blob, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", blob, off)
        off += 8 * ndim
        (nbytes,) = struct.unpack_from("<q", blob, off)
        off += 8
        entries.append((name, dtype, shape, blob[off:off + nbytes]))
        off += nbytes
    assert off == len(blob), "trailing bytes in frame"
    return entries


def test_golden_frames_byte_exact():
    for kind, payload in _canonical_payloads().items():
        assert _pack(payload).hex() == GOLDEN_FRAMES[kind], \
            f"wire frame layout changed for {kind}"


def test_golden_frames_parse_and_round_trip():
    for kind, payload in _canonical_payloads().items():
        blob = bytes.fromhex(GOLDEN_FRAMES[kind])
        entries = _parse_frame(blob)
        assert [e[0] for e in entries] == list(payload)
        back = _unpack(blob)
        assert set(back) == set(payload)
        for name in payload:
            np.testing.assert_array_equal(np.asarray(back[name]),
                                          np.asarray(payload[name]))
            assert back[name].dtype == np.asarray(payload[name]).dtype


def test_pack_round_trips_zero_length_and_max_chunk_payloads():
    # empty payload dict and a zero-length chunk (an owner with no rows)
    assert _pack({}) == b"\x00\x00\x00\x00"
    assert _unpack(_pack({})) == {}
    zero = {"data": np.zeros(0, np.uint8), "base": np.int64(0)}
    back = _unpack(_pack(zero))
    assert back["data"].shape == (0,) and back["data"].dtype == np.uint8
    # a full DEFAULT_CHUNK noinv chunk at modp2048 width (the largest
    # frame the protocol emits): exact payload + header-overhead budget
    from repro.core.psi import DEFAULT_CHUNK
    data = np.arange(DEFAULT_CHUNK * 256, dtype=np.uint64)
    data = (data % 251).astype(np.uint8)
    blob = _pack({"data": data, "base": np.int64(12345)})
    back = _unpack(blob)
    np.testing.assert_array_equal(back["data"], data)
    assert back["base"].reshape(-1)[0] == 12345
    overhead = len(blob) - data.nbytes - 8
    assert overhead < 128                      # headers stay tiny


def test_live_traffic_conforms_to_frame_schema():
    """Parse every frame of a real round with the independent parser and
    check each kind's entry schema (names, dtypes) — the conformance
    gate on actual traffic, not synthetic payloads."""
    captured = []
    xs = [f"id-{i}" for i in range(20)]
    ys = [f"id-{i + 5}" for i in range(20)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair(
        "scientist", "owner0", backend="queue",
        tap=lambda msg, blob: captured.append((msg.kind, blob)))
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        wire_psi_round(client, ep_c, worker=worker, chunk_size=4)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    schema = {
        "psi_hello": [("mode", "uint8"), ("group", "uint8"),
                      ("blind_tag", "uint8"), ("base_tag", "uint8"),
                      ("server_tag", "uint8"), ("have_resp", "uint8"),
                      ("n_items", "int64"),
                      ("chunk_size", "int64"), ("nb", "int64")],
        "psi_hello_ack": [("blind_cached", "uint8"),
                          ("delta_ok", "uint8"),
                          ("server_cached", "uint8"),
                          ("server_tag", "uint8"),
                          ("n_server_items", "int64"),
                          ("n_server_chunks", "int64")],
        "psi_blind_chunk": [("data", "uint8"), ("base", "int64")],
        "psi_server_set_chunk": [("data", "uint8"), ("base", "int64")],
        "psi_double_chunk": [("data", "uint8"), ("base", "int64")],
        "psi_done": [("n_chunks", "int64"), ("modexp_ops", "int64")],
        "psi_stop": [],
    }
    seen = set()
    for kind, blob in captured:
        seen.add(kind)
        entries = _parse_frame(blob)
        assert [(e[0], e[1]) for e in entries] == schema[kind], kind
        assert kind in WIRE_KINDS or kind == "psi_stop"
    assert {"psi_hello", "psi_hello_ack", "psi_blind_chunk",
            "psi_server_set_chunk", "psi_double_chunk",
            "psi_done"} <= seen


# ---------------------------------------------------------------------------
# privacy on the wire (observed traffic, not code inspection)
# ---------------------------------------------------------------------------


def _resolve_with_tap(mode):
    """session.resolve(backend="queue") with every serialized frame
    captured.  Returns (session, [(sender, kind, blob)])."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    captured = []
    orig = transport.channel_pair

    def tapped(a, b, **kw):
        kw["tap"] = lambda msg, blob: captured.append(
            (msg.sender, msg.kind, blob))
        return orig(a, b, **kw)

    transport.channel_pair = tapped
    try:
        sci, owners = make_vertical_mnist_parties(80, seed=4,
                                                  keep_frac=0.9)
        session = VerticalSession(*feature_parties(sci, owners))
        session.resolve(group=GROUP, mode=mode, backend="queue",
                        chunk_size=16)
    finally:
        transport.channel_pair = orig
    return session, captured


@pytest.mark.parametrize("mode", ["noinv", "bloom", "hidden"])
def test_no_raw_ids_on_the_wire(mode):
    """Every byte of every frame of a full resolve: raw IDs never cross
    in any encoding the protocol could accidentally emit — plaintext,
    sha256(id), or the unblinded group element H(id).  (Populations, not
    the aligned view — in hidden mode the view holds pseudonyms.)"""
    import hashlib
    from repro.core.psi import hash_to_group
    session, captured = _resolve_with_tap(mode)
    assert captured, "tap captured no traffic"
    all_ids = set(session.scientist._full.ids)
    for o in session.owners:
        all_ids |= set(o._full.ids)
    p = GROUPS[GROUP][0]
    needles = []
    for i in sorted(all_ids)[:40]:                    # bound test cost
        needles.append(i.encode())
        needles.append(hashlib.sha256(i.encode()).digest())
        needles.append(hash_to_group(i.encode(), p, NB).to_bytes(NB,
                                                                 "big"))
    blobs = b"\x00".join(blob for _, _, blob in captured)
    for needle in needles:
        assert needle not in blobs, \
            f"identifying bytes leaked onto the wire: {needle[:16]!r}"


def test_bloom_mode_server_set_crosses_only_compressed():
    """In bloom mode the owner's set reaches the scientist ONLY as bloom
    shard bitmaps, within the Angelou et al. byte budget (~12x under the
    raw packed set) — asserted on the measured frames."""
    session, captured = _resolve_with_tap("bloom")
    owner_kinds = {k for s, k, _ in captured if s != "scientist"}
    assert "psi_server_set_chunk" not in owner_kinds
    assert "psi_bloom_shard" in owner_kinds
    for owner in session.owners:
        raw = NB * owner.n_rows
        shard_bytes = sum(
            len(b) for s, k, b in captured
            if s == owner.name and k == "psi_bloom_shard")
        assert 0 < shard_bytes < raw / 8, \
            "bloom frames exceed the compression byte budget"


def test_only_protocol_kinds_cross_the_boundary():
    _, captured = _resolve_with_tap("noinv")
    assert {k for _, k, _ in captured} <= set(WIRE_KINDS)
    assert set(CLIENT_KINDS) & {k for s, k, _ in captured
                                if s == "scientist"}
    assert set(SERVER_KINDS) & {k for s, k, _ in captured
                                if s != "scientist"}


# ---------------------------------------------------------------------------
# pipelining under injected latency
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pipelined_chunks_amortize_latency():
    """With 8 ms one-way latency and 12 chunks in flight, the round pays
    O(1) RTTs, not one RTT per chunk (the sequential floor).  Bounded
    generously for CI noise; the tight version is the BENCH_psi wire
    gate."""
    xs = [f"id-{i}" for i in range(96)]
    ys = [f"id-{i + 32}" for i in range(96)]
    lat = 8e-3
    n_chunks = 12

    def once(latency):
        t0 = time.perf_counter()
        inter = _wire_round(xs, ys, chunk_size=8, latency_s=latency)[0]
        assert sorted(set(inter)) == sorted(set(xs) & set(ys))
        return time.perf_counter() - t0

    base = min(once(0.0) for _ in range(2))
    timed = min(once(lat) for _ in range(2))
    seq_floor = n_chunks * 2 * lat                    # per-chunk RTTs
    assert timed - base < 0.75 * seq_floor, \
        (f"latency not amortized: {1e3 * (timed - base):.0f} ms added "
         f"vs sequential floor {1e3 * seq_floor:.0f} ms")


# ---------------------------------------------------------------------------
# delta resolution (ISSUE 10): O(Δ) repeat rounds after population churn
# ---------------------------------------------------------------------------


def _tapped_pair(ys):
    """Queue pair + running worker with a both-directions frame tap.
    Returns (client-endpoint, worker, thread, captured [(kind, nbytes)])."""
    captured = []
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair(
        "scientist", "owner0", backend="queue",
        tap=lambda m, b: captured.append((m.kind, len(b))))
    worker, th = serve_psi("owner0", server, ep_s)
    return ep_c, worker, th, captured


def test_delta_round_after_small_churn_is_o_delta():
    """±4 churn on a 200-item set: the repeat round ships one small
    psi_delta_chunk (no blind chunks, no server-set leg), costs O(Δ)
    modexp on both sides, and returns the exact from-scratch result."""
    xs = [f"id-{i}" for i in range(200)]
    ys = [f"id-{i + 50}" for i in range(200)]
    client = PSIClient(xs, GROUP)
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        _, st1 = wire_psi_round(client, ep_c, worker=worker,
                                chunk_size=32)
        mark = len(captured)
        ops_mark = client.ops
        xs2 = xs[4:] + [f"new-{i}" for i in range(4)]
        client.update_items(xs2)
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=32)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    ref, _ = psi_round(PSIClient(list(client.items), GROUP),
                       PSIServer(ys, group=GROUP), chunk_size=32)
    assert i2 == ref
    assert st2["delta_used"] and not st2["upload_skipped"]
    assert st2["server_leg_skipped"]
    # O(Δ) modexp: 4 fresh client blinds (spent in update_items) + the
    # server's 4 responses; nothing else on either side
    client_delta_ops = client.ops - ops_mark
    assert client_delta_ops == 4
    assert st2["server_modexp_ops"] == 4
    assert st2["client_modexp_ops"] == 0          # server leg cached
    assert client_delta_ops + st2["server_modexp_ops"] \
        <= 0.05 * st1["modexp_ops"]
    # O(Δ) wire: no full upload, no server-set re-ship, tiny delta frame
    kinds2 = [k for k, _ in captured[mark:]]
    assert "psi_blind_chunk" not in kinds2
    assert "psi_server_set_chunk" not in kinds2
    delta_bytes = sum(n for k, n in captured[mark:]
                      if k == "psi_delta_chunk")
    assert 0 < delta_bytes < 0.05 * st1["client_upload_bytes"]


def test_unchanged_update_is_empty_delta_and_hello_only_round():
    """update_items with the identical list records no delta; the repeat
    round degenerates to the O(hello) cached path: zero modexp, zero
    chunk frames in either direction."""
    xs = [f"id-{i}" for i in range(60)]
    ys = [f"id-{i + 20}" for i in range(60)]
    client = PSIClient(xs, GROUP)
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        i1, _ = wire_psi_round(client, ep_c, worker=worker, chunk_size=16)
        mark = len(captured)
        client.update_items(list(xs))
        assert client._delta is None
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert i2 == i1
    assert st2["upload_skipped"] and st2["resp_skipped"]
    assert not st2["delta_used"]
    assert st2["modexp_ops"] == 0
    kinds2 = {k for k, _ in captured[mark:]}
    assert kinds2 <= {"psi_hello", "psi_hello_ack", "psi_done",
                      "psi_stop"}


def test_removal_only_delta_costs_zero_modexp():
    """A shrink-only churn (tombstones, nothing added) still splices:
    zero modexp anywhere, exact intersection."""
    xs = [f"id-{i}" for i in range(80)]
    ys = [f"id-{i + 10}" for i in range(80)]
    client = PSIClient(xs, GROUP)
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        wire_psi_round(client, ep_c, worker=worker, chunk_size=16)
        ops_mark = client.ops
        client.update_items(xs[10:])
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    ref, _ = psi_round(PSIClient(xs[10:], GROUP),
                       PSIServer(ys, group=GROUP), chunk_size=16)
    assert i2 == ref
    assert st2["delta_used"]
    assert client.ops == ops_mark
    assert st2["modexp_ops"] == 0


def test_full_churn_falls_back_to_full_upload():
    """100% churn: no delta is recorded and the round re-runs the full
    protocol (fresh blind chunks), still exact."""
    xs = [f"id-{i}" for i in range(50)]
    ys = [f"id-{i + 100}" for i in range(100)]
    client = PSIClient(xs, GROUP)
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        wire_psi_round(client, ep_c, worker=worker, chunk_size=16)
        mark = len(captured)
        xs2 = [f"id-{i + 120}" for i in range(50)]      # disjoint from xs
        client.update_items(xs2)
        assert client._delta is None
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    ref, _ = psi_round(PSIClient(xs2, GROUP),
                       PSIServer(ys, group=GROUP), chunk_size=16)
    assert i2 == ref and len(i2) > 0
    assert not st2["delta_used"] and not st2["upload_skipped"]
    assert "psi_blind_chunk" in [k for k, _ in captured[mark:]]


def test_duplicate_ids_in_delta_keep_multiset_semantics():
    """Churn that raises an existing ID's multiplicity and adds new
    duplicates: the spliced round matches the from-scratch engine with
    exact duplicate multiplicity."""
    xs = [f"id-{i}" for i in range(40)]
    ys = [f"id-{i + 5}" for i in range(40)] + ["dup-x"]
    client = PSIClient(xs, GROUP)
    ep_c, worker, th, _ = _tapped_pair(ys)
    try:
        wire_psi_round(client, ep_c, worker=worker, chunk_size=8)
        xs2 = xs[2:] + ["dup-x", "dup-x", "id-20"]      # id-20 now twice
        client.update_items(xs2)
        assert client._delta is not None
        i2, st2 = wire_psi_round(client, ep_c, worker=worker,
                                 chunk_size=8)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    ref, _ = psi_round(PSIClient(list(client.items), GROUP),
                       PSIServer(ys, group=GROUP), chunk_size=8)
    assert i2 == ref
    assert st2["delta_used"]
    assert i2.count("dup-x") == 2 and i2.count("id-20") == 2


def test_hidden_delta_round_reuses_response_leg():
    """Hidden mode: after ±2 churn the repeat round uses the delta path
    (tiny upload, cached server leg) and the keep-mask stays a correct
    padded superset of the true member positions."""
    import math
    from repro.core.psi import HIDDEN_PAD
    xs = [f"id-{i}" for i in range(100)]
    ys = [f"id-{i + 30}" for i in range(100)]
    client = PSIClient(xs, GROUP, mode="hidden")
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        wire_psi_round(client, ep_c, worker=worker, chunk_size=16)
        mark = len(captured)
        xs2 = xs[2:] + ["fresh-0", "fresh-1"]
        client.update_items(xs2)
        keep, st2 = wire_psi_round(client, ep_c, worker=worker,
                                   chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert st2["delta_used"] and st2["server_leg_skipped"]
    assert "psi_blind_chunk" not in [k for k, _ in captured[mark:]]
    members = {i for i, it in enumerate(client.items) if it in set(ys)}
    target = min(len(client.items),
                 math.ceil(max(len(members), 1) / HIDDEN_PAD)
                 * HIDDEN_PAD)
    assert members <= set(keep)
    assert len(keep) == target == st2["hidden_kept"]


# ---------------------------------------------------------------------------
# hidden mode (ISSUE 10): membership hiding on the wire
# ---------------------------------------------------------------------------


def _hidden_round_profile(xs, ys):
    """Run one hidden round; return ({kind: sorted frame lengths},
    stats)."""
    client = PSIClient(xs, GROUP, mode="hidden")
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        _, stats = wire_psi_round(client, ep_c, worker=worker,
                                  chunk_size=8)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    profile = {}
    for k, n in captured:
        profile.setdefault(k, []).append(n)
    return {k: sorted(v) for k, v in profile.items()}, stats


def test_hidden_mode_wire_indistinguishable_member_vs_nonmember():
    """Swap one probe ID between member and non-member: every frame kind
    appears the same number of times with the same byte lengths, and the
    padded keep count is identical — a wire observer (or the scientist
    counting frames) cannot tell whether the probe matched."""
    ys = [f"id-{i}" for i in range(30)]
    base = [f"id-{i}" for i in range(10)] + [f"out-{i}" for i in range(9)]
    prof_a, st_a = _hidden_round_profile(base + ["id-20"], ys)   # member
    prof_b, st_b = _hidden_round_profile(base + ["out-99"], ys)  # not
    assert prof_a == prof_b
    assert st_a["hidden_kept"] == st_b["hidden_kept"]
    assert "psi_double_chunk" not in prof_a          # never unblinded back


def test_hidden_mode_ships_no_double_blind_leg():
    """The hidden response is keep positions + rows only: no
    psi_double_chunk and no per-item unblind work on the client."""
    xs = [f"id-{i}" for i in range(64)]
    ys = [f"id-{i + 16}" for i in range(64)]
    client = PSIClient(xs, GROUP, mode="hidden")
    ep_c, worker, th, captured = _tapped_pair(ys)
    try:
        keep, stats = wire_psi_round(client, ep_c, worker=worker,
                                     chunk_size=16)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    kinds = {k for k, _ in captured}
    assert "psi_double_chunk" not in kinds
    assert "psi_keep_mask" in kinds
    assert len(stats["hidden_rows"]) == len(keep)


def test_session_hidden_resolve_bit_stable_direct_vs_queue():
    """mode="hidden" through the session: pseudonymous aligned views are
    bit-identical between the direct and queue backends, and every party
    ends on the same ID list with decoy padding ≤ HIDDEN_PAD - 1."""
    from repro.core.psi import HIDDEN_PAD
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    views = {}
    for backend in ("direct", "queue"):
        sci, owners = make_vertical_mnist_parties(120, seed=7,
                                                  keep_frac=0.85)
        session = VerticalSession(*feature_parties(sci, owners))
        st = session.resolve(group=GROUP, mode="hidden", backend=backend,
                             chunk_size=16)
        ids = session.scientist.ids
        assert ids and all(i.startswith("anon") for i in ids)
        for o in session.owners:
            assert o.ids == ids
        true_members = set(session.scientist._full.ids)
        for o in session.owners:
            true_members &= set(o._full.ids)
        assert len(true_members) <= len(ids) \
            <= len(true_members) + HIDDEN_PAD - 1
        views[backend] = (list(ids),
                          session.scientist._vd.data.tobytes(),
                          [o._vd.data.tobytes() for o in session.owners])
        assert st["mode"] == "hidden"
    assert views["direct"] == views["queue"]


# ---------------------------------------------------------------------------
# session-level repeat & delta resolution (ISSUE 10 bugfix: response-leg
# cache makes the unchanged repeat round O(hello) wire bytes)
# ---------------------------------------------------------------------------


def test_session_repeat_resolve_is_hello_only_on_queue():
    """Second resolve with unchanged populations: every owner round is
    fully cached — zero modexp, no chunk frames, only the hello/ack/done
    envelope crosses the wire."""
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(150, seed=2, keep_frac=0.9)
    session = VerticalSession(*feature_parties(sci, owners))
    st1 = session.resolve(group=GROUP, backend="queue", chunk_size=32)
    ids1 = list(session.scientist.ids)
    st2 = session.resolve(group=GROUP, backend="queue", chunk_size=32)
    assert session.scientist.ids == ids1
    assert st2["global_intersection"] == st1["global_intersection"]
    for r in st2["rounds"]:
        assert r["upload_skipped"] and r["resp_skipped"]
        assert r["server_leg_skipped"]
        assert r["client_modexp_ops"] == 0
        assert r["server_modexp_ops"] == 0
        # O(hello): psi_hello + psi_hello_ack + psi_done + psi_stop only
        assert r["upload_wire_bytes"] < 1024
        assert r["download_wire_bytes"] < 1024
    reuse = [m for m in session.transcript
             if m["kind"] == "psi_resp_reuse"]
    assert len(reuse) >= 0                 # transcript stays parseable


def test_session_delta_resolve_after_churn_is_o_delta_on_queue():
    """±2 churn of the scientist's population between resolves: every
    owner round takes the delta path, total modexp and upload bytes
    collapse to O(Δ), the aligned result is exact, and the transcript
    records the reuse."""
    import numpy as np
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(200, seed=3, keep_frac=1.0)
    session = VerticalSession(*feature_parties(sci, owners))
    st1 = session.resolve(group=GROUP, backend="queue", chunk_size=64)
    full_ops = sum(r["client_modexp_ops"] + r["server_modexp_ops"]
                   for r in st1["rounds"])
    full_up = max(r["upload_wire_bytes"] for r in st1["rounds"])
    s = session.scientist
    pop = list(s._full.ids)
    new_ids = pop[2:] + ["fresh-0", "fresh-1"]
    new_data = np.concatenate(
        [s._full.data[2:], np.zeros((2,) + s._full.data.shape[1:],
                                    s._full.data.dtype)])
    s.update_rows(new_ids, new_data)
    st2 = session.resolve(group=GROUP, backend="queue", chunk_size=64)
    for r in st2["rounds"]:
        assert r["delta_used"] and r["server_leg_skipped"]
        assert r["upload_wire_bytes"] < 0.05 * full_up
    delta_ops = sum(r["client_modexp_ops"] + r["server_modexp_ops"]
                    for r in st2["rounds"])
    assert delta_ops <= 0.05 * full_ops
    # exactness: the fresh IDs are unknown to owners, 2 dropped IDs gone
    expect = sorted(set(pop[2:]))
    assert session.scientist.ids == expect
    for o in session.owners:
        assert o.ids == expect
    reuse = [m for m in session.transcript
             if m["kind"] == "psi_delta_reuse"]
    assert [m["to"] for m in reuse] == [o.name for o in session.owners]


# ---------------------------------------------------------------------------
# protocol guards + population-update edge paths (coverage of the loud
# failure modes the desync/validation layer promises)
# ---------------------------------------------------------------------------


def _hello_payload(server, **over):
    from repro.federation.psi_transport import ZERO_TAG, _u8
    pl = {"mode": _u8(b"noinv"), "group": _u8(server.group.encode()),
          "blind_tag": _u8(b"x" * 16), "base_tag": _u8(ZERO_TAG),
          "server_tag": _u8(ZERO_TAG), "have_resp": np.uint8(0),
          "n_items": np.int64(4), "chunk_size": np.int64(2),
          "nb": np.int64(server._nb)}
    pl.update(over)
    return pl


def test_owner_endpoint_rejects_malformed_protocol():
    """Every _on_hello validation arm raises loudly instead of serving a
    desynchronized round; unknown kinds raise; heartbeats are acked."""
    import types

    from repro.federation.psi_transport import _u8

    server = PSIServer([f"s{i}" for i in range(4)], group="modp512")
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint("owner0", server, ep_s)

    def msg(kind, payload=None, seq=0):
        return types.SimpleNamespace(kind=kind, payload=payload or {},
                                     seq=seq)

    with pytest.raises(RuntimeError, match="unknown message kind"):
        worker.handle(msg("not_a_psi_kind"))
    with pytest.raises(RuntimeError, match="unknown PSI mode"):
        worker.handle(msg("psi_hello",
                          _hello_payload(server, mode=_u8(b"nonsense"))))
    with pytest.raises(RuntimeError, match="element width mismatch"):
        worker.handle(msg("psi_hello",
                          _hello_payload(server, nb=np.int64(1))))
    with pytest.raises(RuntimeError, match="chunk_size must be positive"):
        worker.handle(msg("psi_hello",
                          _hello_payload(server,
                                         chunk_size=np.int64(0))))
    with pytest.raises(RuntimeError, match="delta chunk without"):
        worker.handle(msg("psi_delta_chunk",
                          {"data": _u8(b""),
                           "removed": np.array([], np.int64),
                           "n_retained": np.int64(0)}))
    with pytest.raises(RuntimeError, match="lift chunk outside"):
        worker.handle(msg("psi_lift_chunk",
                          {"data": _u8(b""), "base": np.int64(0)}))
    with pytest.raises(RuntimeError, match="blind chunk outside"):
        worker.handle(msg("psi_blind_chunk",
                          {"data": _u8(b""), "base": np.int64(0)}))
    # heartbeat is acked, not fatal
    assert worker.handle(msg("heartbeat", seq=7))
    ack = ep_c.recv(timeout=5.0)
    assert ack.kind == "heartbeat_ack" and ack.seq == 7


def test_client_mode_and_group_validation():
    with pytest.raises(ValueError, match="unknown PSI mode"):
        PSIClient(["a"], "modp512", mode="nonsense")
    with pytest.raises(ValueError, match="group mismatch"):
        psi_round(PSIClient(["a"], "modp512"),
                  PSIServer(["a"], group="modp2048"))


def test_update_items_before_any_blinding_is_a_plain_swap():
    """Churning a client that never ran a round has no memoized upload
    to splice — the population swaps and no delta is recorded."""
    client = PSIClient(["a", "b"], "modp512")
    client.update_items(["b", "c"])
    assert list(client.items) == ["b", "c"]
    assert client._delta is None
    assert client.ops == 0                  # nothing blinded yet


def test_reorder_only_update_records_no_delta():
    """Same multiset, different order: nothing was added or removed, so
    there is no delta to ship and the blinded upload keeps its canonical
    positional order (peers' caches stay valid)."""
    client = PSIClient([f"c{i}" for i in range(6)], "modp512")
    server = PSIServer([f"c{i}" for i in range(3, 9)], group="modp512")
    psi_round(client, server, chunk_size=4)
    items = list(client.items)
    ops0 = client.ops
    client.update_items(items[::-1])
    assert client._delta is None
    assert list(client.items) == items     # base order preserved
    assert client.ops == ops0              # nothing re-blinded


def test_server_population_update_invalidates_response_leg():
    """PSIServer.update_items: the owner's own set churns between
    rounds — the server leg's content tag changes (so a caching client
    re-downloads it) while only genuinely new items get blinded, and the
    next round resolves the NEW intersection exactly."""
    client = PSIClient([f"c{i}" for i in range(8)], "modp512")
    server = PSIServer([f"c{i}" for i in range(4, 12)], group="modp512")
    i1, _ = psi_round(client, server, chunk_size=4)
    assert sorted(i1) == [f"c{i}" for i in range(4, 8)]
    tag1 = server.server_leg_tag("noinv", None, 4)
    ops0 = server.ops

    server.update_items([f"c{i}" for i in range(2, 10)])
    # no-op update is free
    server.update_items([f"c{i}" for i in range(2, 10)])
    tag2 = server.server_leg_tag("noinv", None, 4)
    assert tag2 != tag1
    i2, _ = psi_round(client, server, chunk_size=4)
    assert sorted(i2) == [f"c{i}" for i in range(2, 8)]
    # round 2 cost: 8 fresh double-blind responses + ONLY the two
    # genuinely-new own items (c2, c3) blinded — the 6 retained own
    # blinds were reused from the element cache
    assert server.ops - ops0 == len(client.items) + 2


def test_blind_cached_but_client_response_lost_reships_doubles():
    """The client loses its transcript cache (fresh process) while the
    owner still holds the blind/response caches: the owner replays the
    double-blind leg from its response cache — zero modexp, zero upload
    bytes, same intersection."""
    xs = [f"x{i}" for i in range(12)]
    ys = [f"x{i}" for i in range(6, 18)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker, th = serve_psi("owner0", server, ep_s)
    try:
        i1, _ = wire_psi_round(client, ep_c, worker=worker, chunk_size=4)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)

    client.round_cache.clear()
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    w2 = PSIServerEndpoint("owner0", worker.server, ep_s,
                           blind_cache=worker._blind_cache,
                           resp_cache=worker._resp_cache,
                           lift_cache=worker._lift_cache)
    th = threading.Thread(target=w2.run, daemon=True)
    th.start()
    try:
        i2, st2 = wire_psi_round(client, ep_c, worker=w2, chunk_size=4)
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert sorted(i2) == sorted(i1)
    assert st2["blind_cached"] and st2["upload_skipped"]
    assert not st2["resp_skipped"]
    assert ep_c.recv_stats["by_kind"]["psi_double_chunk"]["count"] > 0
    assert st2["server_modexp_ops"] == 0    # replayed from the cache
