"""Per-architecture smoke tests (deliverable f): reduced variants of each
assigned family run one forward/train step and one prefill+decode step on
CPU; output shapes verified, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.model import SplitModel

ARCHS = list_archs()
B, S = 2, 32


def _batch(cfg, with_labels=True):
    half = S // 2
    if cfg.modality == "text":
        b = {"tokens": jnp.ones((B, S), jnp.int32)}
        if with_labels:
            b["labels"] = jnp.zeros((B, S), jnp.int32)
    elif cfg.modality == "vision_text":
        b = {"patches": jnp.zeros((B, half, cfg.d_frontend), jnp.float32),
             "tokens": jnp.ones((B, half), jnp.int32)}
        if with_labels:
            b["labels"] = jnp.concatenate(
                [jnp.full((B, half), -100, jnp.int32),
                 jnp.zeros((B, half), jnp.int32)], axis=1)
    else:
        b = {"frames": jnp.zeros((B, half, cfg.d_frontend), jnp.float32),
             "tokens": jnp.ones((B, half), jnp.int32)}
        if with_labels:
            b["labels"] = jnp.zeros((B, half), jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_reduced(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.d_model <= 512 and cfg.n_layers <= len(cfg.block_pattern) * 2
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    S_out = batch["labels"].shape[1]
    assert logits.shape == (B, S_out, cfg.vocab)
    assert not jnp.isnan(logits).any(), "NaN in logits"

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, batch)[0]))(params)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(arch):
    cfg = get_config(arch, reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.cache_init(B, S, n_new=4)
    batch = _batch(cfg, with_labels=False)
    if cfg.modality == "text":
        P = cfg.split.n_owners
        t = batch.pop("tokens")
        batch["owner_tokens"] = t.reshape(B, P, S // P).transpose(1, 0, 2)
    logits, caches = jax.jit(model.prefill)(params, batch, caches)
    assert logits.shape == (B, cfg.vocab)
    assert not jnp.isnan(logits).any()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches = jax.jit(model.decode_step)(
        params, caches, tok, S, S // max(cfg.split.n_owners, 1))
    assert logits2.shape == (B, cfg.vocab)
    assert not jnp.isnan(logits2).any()


@pytest.mark.parametrize("arch", ["llama3.2-3b", "zamba2-2.7b",
                                  "mixtral-8x7b", "xlstm-125m"])
def test_swa_long_context_variant(arch):
    """The explicit sliding-window variant used for long_500k lowers and
    runs at reduced scale."""
    cfg = get_config(arch, reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.cache_init(B, S, n_new=4)
    P = cfg.split.n_owners
    batch = {"owner_tokens": jnp.ones((P, B, S // P), jnp.int32)}
    _, caches = jax.jit(lambda p, b, c: model.prefill(
        p, b, c, swa_override=16))(params, batch, caches)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, _ = jax.jit(lambda p, c, t: model.decode_step(
        p, c, t, S, S // P, swa_override=16))(params, caches, tok)
    assert not jnp.isnan(logits).any()


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (spot checks per source)."""
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (126, 16384, 128, 8, 53248, 128256)
    c = get_config("gemma2-9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (42, 3584, 16, 8, 14336, 256000)
    assert c.logit_softcap == 30.0 and c.attn_softcap == 50.0
    c = get_config("deepseek-moe-16b")
    assert c.moe.n_experts == 64 and c.moe.top_k == 6 and c.moe.n_shared == 2
    c = get_config("mixtral-8x7b")
    assert c.moe.n_experts == 8 and c.moe.top_k == 2
    c = get_config("zamba2-2.7b")
    assert c.ssm.d_state == 64 and c.n_layers == 54
    c = get_config("qwen2-vl-72b")
    assert c.rope == "mrope" and c.vocab == 152064
    c = get_config("whisper-tiny")
    assert c.enc_dec and c.n_enc_layers == 4
    c = get_config("nemotron-4-15b")
    assert c.mlp == "relu2" and c.vocab == 256000
    c = get_config("xlstm-125m")
    assert c.d_ff == 0 and set(c.block_pattern) == {"slstm", "mlstm"}
    c = get_config("llama3.2-3b")
    assert (c.n_layers, c.d_model) == (28, 3072)
