"""Sharding rules: divisibility guards, owner-axis placement, MoE
expert-parallel fallback — pure spec-level tests (no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.model import SplitModel
from repro.sharding.specs import (ShardingRules, abstract_mesh, make_rules,
                                  param_specs)


@pytest.fixture(scope="module")
def mesh16():
    # spec construction only consults mesh.shape / axis_names
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def mesh_pod():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _specs(arch, mesh, **kw):
    cfg = get_config(arch)
    model = SplitModel(cfg)
    rules = make_rules(mesh, cfg, **kw)
    return cfg, param_specs(model.param_specs(), cfg, mesh, rules)


def test_attention_weights_tensor_parallel(mesh16):
    cfg, specs = _specs("llama3.2-3b", mesh16)
    wq = specs["trunk"]["blocks"]["units"]["b0"]["attn"]["wq"]["w"]
    assert wq == P(None, None, "model")          # (units, d, qd)
    wo = specs["trunk"]["blocks"]["units"]["b0"]["attn"]["wo"]["w"]
    assert wo == P(None, "model", None)


def test_owner_dim_sharded_over_pod(mesh_pod):
    cfg, specs = _specs("llama3.2-3b", mesh_pod)
    embed = specs["heads"]["embed"]["table"]     # (P, vocab, d)
    assert embed[0] == "pod"
    wq = specs["heads"]["blocks"]["units"]["b0"]["attn"]["wq"]["w"]
    assert wq[0] == "pod"
    # trunk never carries the pod axis (scientist-owned, pod-replicated)
    for leaf in jax.tree.leaves(
            specs["trunk"], is_leaf=lambda s: isinstance(s, P)):
        assert "pod" not in tuple(leaf)


def test_whisper_single_owner_not_sharded_over_pod(mesh_pod):
    cfg, specs = _specs("whisper-tiny", mesh_pod)
    # P=1 cannot shard over a 2-pod axis: divisibility guard replicates
    fp = specs["heads"]["front_proj"]["w"]
    assert fp[0] is None


def test_moe_expert_parallel_when_divisible(mesh16):
    cfg, specs = _specs("deepseek-moe-16b", mesh16)   # 64 experts % 16 == 0
    w_in = specs["trunk"]["blocks"]["units"]["b0"]["ffn"]["w_in"]
    assert w_in == P(None, "model", None, None)       # (units, E, d, d_e)


def test_moe_tensor_parallel_fallback(mesh16):
    cfg, specs = _specs("mixtral-8x7b", mesh16)       # 8 experts % 16 != 0
    w_in = specs["trunk"]["blocks"]["units"]["b0"]["ffn"]["w_in"]
    assert w_in == P(None, None, None, "model")       # shard d_expert
    w_out = specs["trunk"]["blocks"]["units"]["b0"]["ffn"]["w_out"]
    assert w_out == P(None, None, "model", None)


def test_fsdp_only_when_zero_sharding(mesh16):
    _, specs = _specs("llama3-405b", mesh16)          # zero_sharding=True
    wq = specs["trunk"]["blocks"]["units"]["b0"]["attn"]["wq"]["w"]
    assert wq == P(None, "data", "model")
    _, specs = _specs("llama3.2-3b", mesh16)          # zero_sharding=False
    wq = specs["trunk"]["blocks"]["units"]["b0"]["attn"]["wq"]["w"]
    assert wq == P(None, None, "model")


def test_indivisible_vocab_replicated(mesh16):
    # whisper vocab 51865 is not divisible by 16: guard must replicate
    cfg, specs = _specs("whisper-tiny", mesh16)
    emb = specs["trunk"]["embed"]["table"]
    assert emb == P(None, None)


def test_norm_scales_replicated(mesh16):
    _, specs = _specs("gemma2-9b", mesh16)
    s = specs["trunk"]["out_norm"]["scale"]
    assert s == P(None)
