"""Core attention: chunked-vs-direct equivalence, masks, GQA, KV cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.models.attention import attention, init_kv_cache, update_kv_cache

RNG = np.random.default_rng(1)


def _qkv(B, Sq, Skv, nh, nkv, hd):
    return (jnp.asarray(RNG.normal(size=(B, Sq, nh, hd)), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, Skv, nkv, hd)), jnp.float32),
            jnp.asarray(RNG.normal(size=(B, Skv, nkv, hd)), jnp.float32))


def test_chunked_equals_direct():
    q, k, v = _qkv(2, 512, 512, 4, 2, 32)
    direct = attention(q, k, v, chunk=4096)
    chunked = attention(q, k, v, chunk=128)
    np.testing.assert_allclose(direct, chunked, atol=1e-5, rtol=1e-5)


def test_causal_mask_blocks_future():
    """Changing future tokens must not change past outputs."""
    q, k, v = _qkv(1, 64, 64, 2, 2, 16)
    out1 = attention(q, k, v)
    k2 = k.at[:, 32:].set(RNG.normal(size=(1, 32, 2, 16)))
    v2 = v.at[:, 32:].set(RNG.normal(size=(1, 32, 2, 16)))
    out2 = attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :32], out2[:, :32], atol=1e-6)
    assert not np.allclose(out1[:, 33:], out2[:, 33:])


def test_local_window_blocks_distant_past():
    q, k, v = _qkv(1, 128, 128, 2, 2, 16)
    out1 = attention(q, k, v, kind="local", window=16)
    # perturb tokens far outside the window of the last query
    k2 = k.at[:, :64].set(0.0)
    v2 = v.at[:, :64].set(0.0)
    out2 = attention(q, k2, v2, kind="local", window=16)
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], atol=1e-6)


def test_gqa_equals_repeated_kv():
    """GQA must equal full MHA with kv heads explicitly repeated."""
    q, k, v = _qkv(2, 64, 64, 8, 2, 16)
    out_gqa = attention(q, k, v)
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    out_full = attention(q, k_rep, v_rep)
    np.testing.assert_allclose(out_gqa, out_full, atol=1e-5, rtol=1e-5)


def test_kv_cache_decode_equals_full():
    """Prefill + single-token decode == full forward at that position."""
    B, S, nh, nkv, hd = 1, 33, 4, 2, 16
    q, k, v = _qkv(B, S, S, nh, nkv, hd)
    full = attention(q, k, v)

    cache = init_kv_cache(B, S, nkv, hd, jnp.float32)
    cache = update_kv_cache(cache, k[:, :S - 1], v[:, :S - 1], 0)
    cache = update_kv_cache(cache, k[:, S - 1:], v[:, S - 1:], S - 1)
    out = attention(q[:, S - 1:], cache["k"], cache["v"],
                    q_offset=S - 1, kv_len=S)
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=1e-5, rtol=1e-5)


def test_kv_len_masks_stale_cache():
    """Entries beyond kv_len (stale cache slots) must not contribute."""
    B, S = 1, 16
    q, k, v = _qkv(B, 1, S, 2, 2, 16)
    k_garbage = k.at[:, 8:].set(1e4)
    v_garbage = v.at[:, 8:].set(1e4)
    out1 = attention(q, k, v, q_offset=7, kv_len=8)
    out2 = attention(q, k_garbage, v_garbage, q_offset=7, kv_len=8)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


@given(st.integers(1, 4), st.integers(1, 8), st.integers(8, 64))
@settings(max_examples=10, deadline=None)
def test_softmax_rows_bounded(B, nh, S):
    """Output is a convex combination of values: max |out| <= max |v|."""
    q = jnp.asarray(RNG.normal(size=(B, S, nh, 8)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, nh, 8)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, nh, 8)), jnp.float32)
    out = attention(q, k, v)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4
