"""PSI + Bloom filter: unit and property tests (claim C1)."""
import hashlib

import numpy as np
import pytest
from repro.testing.hypo import given, settings, strategies as st

from repro.core.bloom import BloomFilter
from repro.core.psi import (GROUPS, PSIClient, PSIServer, hash_to_group,
                            psi_intersect)

GROUP = "modp512"  # fast test group; protocol identical to modp2048


# ---------------------------------------------------------------------------
# Bloom filter
# ---------------------------------------------------------------------------


@given(st.sets(st.binary(min_size=1, max_size=32), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_bloom_no_false_negatives(items):
    bf = BloomFilter.for_capacity(len(items), 1e-6)
    bf.add_all(items)
    for it in items:
        assert it in bf


@given(st.integers(min_value=1, max_value=500))
@settings(max_examples=20, deadline=None)
def test_bloom_false_positive_rate(n):
    bf = BloomFilter.for_capacity(n, 1e-4)
    members = [f"member-{i}".encode() for i in range(n)]
    bf.add_all(members)
    trials = 2000
    fp = sum(f"non-member-{i}".encode() in bf for i in range(trials))
    assert fp / trials < 1e-2  # orders of magnitude slack over target 1e-4


def test_bloom_serialization_roundtrip():
    bf = BloomFilter(1024, 5)
    bf.add(b"x")
    bf2 = BloomFilter.from_bytes(bf.to_bytes(), 1024, 5)
    assert b"x" in bf2 and b"y" not in bf2


def test_bloom_rejects_bad_params():
    with pytest.raises(ValueError):
        BloomFilter(0, 3)


# ---------------------------------------------------------------------------
# DDH group
# ---------------------------------------------------------------------------


def test_hash_to_group_is_quadratic_residue():
    for g in ("modp512", "modp2048"):
        p, q, nb = GROUPS[g]
        h = hash_to_group(b"subject-1", p, nb)
        # elements of QR_p have order dividing q: h^q == 1
        assert pow(h, q, p) == 1


def test_blinding_commutes():
    p, q, nb = GROUPS[GROUP]
    h = hash_to_group(b"abc", p, nb)
    a, b = 12345, 67891
    assert pow(pow(h, a, p), b, p) == pow(pow(h, b, p), a, p)


# ---------------------------------------------------------------------------
# PSI protocol
# ---------------------------------------------------------------------------


@given(st.sets(st.text(min_size=1, max_size=12), min_size=0, max_size=40),
       st.sets(st.text(min_size=1, max_size=12), min_size=0, max_size=40))
@settings(max_examples=20, deadline=None)
def test_psi_equals_set_intersection(xs, ys):
    xs, ys = sorted(xs), sorted(ys)
    inter, _ = psi_intersect(xs, ys, group=GROUP)
    assert sorted(inter) == sorted(set(xs) & set(ys))


def test_psi_server_learns_only_cardinality():
    """The server's view is blinded group elements — distinct from the raw
    hashes, and the client's exponent never leaves the client."""
    client = PSIClient(["a", "b"], GROUP)
    blinded = client.blind()
    p, q, nb = GROUPS[GROUP]
    raw = [hash_to_group(x.encode(), p, nb) for x in ["a", "b"]]
    assert all(b != r for b, r in zip(blinded, raw))


def test_psi_bloom_compression_smaller_than_raw():
    server_items = [f"y{i}" for i in range(500)]
    _, stats = psi_intersect(["y1", "zz"], server_items, group=GROUP,
                             mode="bloom")
    assert stats["bloom_bytes"] < stats["uncompressed_server_set_bytes"]


def test_psi_2048_group_roundtrip():
    inter, _ = psi_intersect(["a", "b", "c"], ["b", "c", "d"])
    assert sorted(inter) == ["b", "c"]


def test_short_and_full_exponents_agree():
    """Short-exponent DH (the hot-loop lever) computes the same
    intersection as full-width exponents."""
    from repro.core.psi import psi_intersect
    xs = [f"id-{i}" for i in range(40)]
    ys = [f"id-{i + 20}" for i in range(40)]
    short, _ = psi_intersect(xs, ys, group="modp512")
    full, _ = psi_intersect(xs, ys, group="modp512", exp_bits=None)
    assert short == full == [f"id-{i + 20}" for i in range(20)]


def test_client_blind_is_memoized_and_reusable_across_owners():
    """One client -> many owners: the blinded upload is computed once
    and every owner round still yields the right intersection."""
    from repro.core.psi import PSIClient, PSIServer
    xs = [f"id-{i}" for i in range(30)]
    client = PSIClient(xs, "modp512")
    b1 = client.blind()
    assert client.blind() is b1              # memoized, not re-blinded
    for shift in (5, 10):
        ys = [f"id-{i + shift}" for i in range(30)]
        server = PSIServer(ys, group="modp512")
        inter = client.intersect(*server.respond(b1))
        assert inter == [f"id-{i}" for i in range(shift, 30)]


def test_server_bloom_cached_across_rounds():
    from repro.core.psi import PSIClient, PSIServer
    ys = [f"id-{i}" for i in range(25)]
    server = PSIServer(ys, group="modp512")
    c1 = PSIClient([f"id-{i}" for i in range(10)], "modp512")
    _, bf1 = server.respond(c1.blind())
    _, bf2 = server.respond(c1.blind())
    assert bf1 is bf2                        # built once per session
