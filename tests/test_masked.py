"""Secure forward aggregation (``fit(aggregation="masked_sum")``).

Three layers of guarantees:

1. Ring algebra (``core/masking.py``): pairwise masks cancel exactly,
   quantization stays in the f32-exact band, mask streams are pure
   functions of (root, pair, tag).
2. Protocol bit-identity: masked split execution on every backend /
   schedule / microbatch count reproduces the *masked joint oracle*
   (``fit(mode="joint", aggregation="masked_sum")``) bitwise.
3. Composition: codecs still apply to the gradient leg, gradient
   defenses stay deterministic, misuse raises early.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core import masking
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties
from repro.testing.hypo import given, settings
from repro.testing.hypo import strategies as st

SUM_CFG = dataclasses.replace(MNIST_CFG, split=dataclasses.replace(
    MNIST_CFG.split, combine="sum"))


# ---------------------------------------------------------------------------
# ring algebra
# ---------------------------------------------------------------------------


@settings(max_examples=20)
@given(st.integers(min_value=2, max_value=6),
       st.integers(min_value=0, max_value=2 ** 31))
def test_pairwise_masks_cancel_exactly(n_owners, root):
    """sum_p mask_p == 0 mod 2^32, elementwise, for any owner count and
    root — the whole secure-aggregation correctness argument."""
    shape = (3, 5)
    total = np.zeros(shape, np.uint32)
    for p in range(n_owners):
        total = total + masking.pairwise_mask(root, p, n_owners, "s7",
                                              shape)
    assert not total.any()


def test_masks_differ_across_tags_owners_and_roots():
    shape = (4,)
    m = masking.pairwise_mask(1, 0, 2, "s1", shape)
    assert not np.array_equal(m, masking.pairwise_mask(1, 0, 2, "s2",
                                                       shape))
    assert not np.array_equal(m, masking.pairwise_mask(2, 0, 2, "s1",
                                                       shape))
    assert not np.array_equal(m, masking.pairwise_mask(1, 1, 2, "s1",
                                                       shape))
    # pure function: same inputs, bitwise same stream
    np.testing.assert_array_equal(
        m, masking.pairwise_mask(1, 0, 2, "s1", shape))


@settings(max_examples=20)
@given(st.floats(min_value=-200.0, max_value=200.0),
       st.floats(min_value=-200.0, max_value=200.0))
def test_quantize_round_trip_error_bounded(a, b):
    """The fixed-point lift loses at most half a quantum (2^-17) per
    element inside the clip band."""
    quant = masking.make_quant_program()
    x = np.array([[a, b]], np.float32)
    q = np.asarray(quant(x))
    assert q.dtype == np.int32
    back = q.astype(np.float64) / masking.SCALE
    assert np.max(np.abs(back - x.astype(np.float64))) <= 0.5 / \
        masking.SCALE + 1e-12


def test_quantize_clips_outliers():
    quant = masking.make_quant_program()
    q = np.asarray(quant(np.array([1e9, -1e9], np.float32)))
    np.testing.assert_array_equal(
        q, [int(masking.QCLIP), -int(masking.QCLIP)])


@settings(max_examples=15)
@given(st.integers(min_value=2, max_value=5),
       st.integers(min_value=0, max_value=10 ** 6))
def test_reconstruct_equals_unmasked_fold(n_owners, root):
    """Scientist-side fold of the masked payloads == the unmasked ring
    sum, bitwise — cancellation through the full encode/fold path."""
    rng = np.random.default_rng(root)
    quant = masking.make_quant_program()
    cuts = [rng.normal(size=(2, 3)).astype(np.float32) * 10
            for _ in range(n_owners)]
    qs = [np.asarray(quant(c)) for c in cuts]
    payloads = []
    for p in range(n_owners):
        agg = masking.MaskedAggregator(root, p, n_owners, quant)
        payloads.append(agg.encode(cuts[p], agg.step_tag(3)))
    np.testing.assert_array_equal(masking.reconstruct(payloads),
                                  masking.fold_quantized(qs))


def test_masked_payload_is_not_the_plain_quantization():
    """The wire element differs from the bare quantized cut — the mask
    actually does something."""
    quant = masking.make_quant_program()
    cut = np.ones((2, 2), np.float32)
    agg = masking.MaskedAggregator(0, 0, 2, quant)
    pl = agg.encode(cut, agg.step_tag(0))
    assert pl["mq"].dtype == np.uint32
    assert not np.array_equal(pl["mq"].view(np.int32),
                              np.asarray(quant(cut)))


def test_single_owner_masking_rejected():
    with pytest.raises(ValueError, match="2 owners"):
        masking.MaskedAggregator(0, 0, 1, masking.make_quant_program())


def test_warmup_tags_are_generation_scoped_steady_tags_are_not():
    quant = masking.make_quant_program()
    a0 = masking.MaskedAggregator(0, 0, 2, quant, generation=0)
    a1 = masking.MaskedAggregator(0, 0, 2, quant, generation=1)
    assert a0.warmup_tag(0) != a1.warmup_tag(0)
    assert a0.step_tag(5) == a1.step_tag(5)
    # so a respawned owner's replayed steady-state masks still cancel
    # against gen-0 survivors
    cut = np.zeros((2, 2), np.float32)
    b0 = masking.MaskedAggregator(0, 1, 2, quant, generation=0)
    np.testing.assert_array_equal(
        masking.reconstruct([a1.encode(cut, a1.step_tag(5)),
                             b0.encode(cut, b0.step_tag(5))]),
        np.zeros((2, 2), np.int32))


def test_mask_root_env_channel(monkeypatch):
    monkeypatch.delenv(masking.MASK_ENV, raising=False)
    assert masking.mask_root_from_env(17) == 17
    monkeypatch.setenv(masking.MASK_ENV, "99")
    assert masking.mask_root_from_env(17) == 99


# ---------------------------------------------------------------------------
# protocol bit-identity: masked split == masked joint oracle
# ---------------------------------------------------------------------------


def _run(mode, *, backend="queue", M=1, schedule="pipelined",
         compression=None, n=300, steps=4, **kw):
    sci, owners = feature_parties(*make_vertical_mnist_parties(
        n, seed=0, keep_frac=0.9))
    s = VerticalSession(sci, owners)
    s.resolve(group="modp512")
    s.build(SUM_CFG)
    fkw = dict(steps=steps, batch_size=64, verbose=False,
               aggregation="masked_sum", microbatches=M, mode=mode)
    if mode == "split":
        fkw.update(backend=backend, schedule=schedule,
                   compression=compression)
    fkw.update(kw)
    h = s.fit(**fkw)
    return s, h


def _leaves(s):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(s.params)]


_ORACLE: dict = {}


def _oracle(M):
    if M not in _ORACLE:
        s, h = _run("joint", M=M)
        _ORACLE[M] = (_leaves(s), [r["loss"] for r in h["train"]])
    return _ORACLE[M]


@pytest.mark.parametrize("backend", ["direct", "queue", "process"])
@pytest.mark.parametrize("M", [1, 2])
def test_masked_split_bit_identical_to_masked_joint_oracle(backend, M):
    """The acceptance property: pairwise-cancelling masks make split
    masked execution *bitwise* the unmasked (oracle) computation, per
    backend and microbatch count."""
    ref_leaves, ref_losses = _oracle(M)
    s, h = _run("split", backend=backend, M=M)
    assert [r["loss"] for r in h["train"]] == ref_losses
    for a, b in zip(_leaves(s), ref_leaves):
        np.testing.assert_array_equal(a, b)
    assert s.transport_stats["aggregation"] == "masked_sum"


def test_masked_sequential_schedule_bit_identical():
    ref_leaves, ref_losses = _oracle(1)
    s, h = _run("split", backend="direct", schedule="sequential")
    assert [r["loss"] for r in h["train"]] == ref_losses
    for a, b in zip(_leaves(s), ref_leaves):
        np.testing.assert_array_equal(a, b)


def test_masked_forward_costs_no_extra_wire_bytes():
    """uint32 ring elements are exactly the 4 bytes/element of the f32
    cuts they replace: masked and plain forward payload bytes match."""
    s_plain, _ = _run("split", backend="queue", aggregation=None)
    s_mask, _ = _run("split", backend="queue")
    for name in (o.name for o in s_mask.owners):
        assert (s_mask.transport_stats["per_owner"][name]
                ["cut_payload_bytes"]
                == s_plain.transport_stats["per_owner"][name]
                ["cut_payload_bytes"])


def test_masked_composes_with_codec_on_gradient_leg():
    """compression applies to cut gradients (the forward is ring-coded
    and bypasses it): fp16 halves gradient payload bytes and training
    still tracks the oracle within codec tolerance."""
    _, ref_losses = _oracle(1)
    s, h = _run("split", backend="queue", compression="fp16")
    base, _ = _run("split", backend="queue")
    for name in (o.name for o in s.owners):
        po, pb = (s.transport_stats["per_owner"][name],
                  base.transport_stats["per_owner"][name])
        assert po["grad_payload_bytes"] * 2 == pb["grad_payload_bytes"]
        assert po["cut_payload_bytes"] == pb["cut_payload_bytes"]
    for got, ref in zip((r["loss"] for r in h["train"]), ref_losses):
        assert got == pytest.approx(ref, rel=0.05)


def test_masked_requires_sum_combine_and_two_owners():
    sci, owners = feature_parties(*make_vertical_mnist_parties(
        60, seed=0))
    s = VerticalSession(sci, owners)
    s.resolve(group="modp512")
    s.build(MNIST_CFG)                       # combine="concat"
    with pytest.raises(ValueError, match="masked_sum"):
        s.fit(steps=1, batch_size=16, verbose=False,
              aggregation="masked_sum")
    with pytest.raises(ValueError, match="aggregation"):
        s.fit(steps=1, batch_size=16, verbose=False,
              aggregation="bogus")


def test_masked_metrics_match_plain_sum_within_quantization():
    """masked_sum is plain sum combine up to the 2^-16 fixed-point
    quantization: per-step losses track the float path closely."""
    sci, owners = feature_parties(*make_vertical_mnist_parties(
        300, seed=0, keep_frac=0.9))
    s = VerticalSession(sci, owners)
    s.resolve(group="modp512")
    s.build(SUM_CFG)
    h_plain = s.fit(steps=4, batch_size=64, verbose=False)
    _, h_mask = _run("joint")
    for a, b in zip(h_plain["train"], h_mask["train"]):
        assert a["loss"] == pytest.approx(b["loss"], abs=1e-3)
