"""Serving engine: queueing, waves, determinism vs the raw decode path,
backpressure (QueueFull), and degraded service (per-request errors)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.engine import QueueFull, ServingEngine
from repro.models.model import SplitModel


def _setup(batch_slots=2, ctx=32):
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=batch_slots,
                        ctx_len=ctx, max_new=6)
    return cfg, model, params, eng


def test_queue_drains_across_waves():
    cfg, model, params, eng = _setup(batch_slots=2)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab, 32)) for _ in range(5)]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    assert eng.stats["waves"] == 3            # 2 + 2 + 1
    assert all(len(out[r].generated) == 6 for r in rids)


def test_engine_matches_manual_decode():
    cfg, model, params, eng = _setup(batch_slots=1)
    rng = np.random.default_rng(1)
    ctx = rng.integers(0, cfg.vocab, 32).astype(np.int32)
    rid = eng.submit(ctx, max_new=4)
    out = eng.run()[rid]

    # manual greedy decode of the same request
    S, P = 32, cfg.split.n_owners
    caches = model.cache_init(1, S, n_new=5)
    ot = jnp.asarray(ctx.reshape(1, P, S // P).transpose(1, 0, 2))
    logits, caches = model.prefill(params, {"owner_tokens": ot}, caches)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(4):
        toks.append(int(tok[0, 0]))
        if t < 3:
            logits, caches = model.decode_step(params, caches, tok,
                                               S + t, S // P + t)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert out.generated == toks


def test_eos_stops_early():
    cfg, model, params, eng = _setup(batch_slots=1)
    rng = np.random.default_rng(2)
    ctx = rng.integers(0, cfg.vocab, 32)
    # pick the EOS as whatever greedy emits first -> length must be 1
    rid = eng.submit(ctx, max_new=6)
    first = eng.run()[rid].generated[0]
    eng2 = ServingEngine(model, params, batch_slots=1, ctx_len=32,
                         max_new=6, eos_token=first)
    rid2 = eng2.submit(ctx, max_new=6)
    assert eng2.run()[rid2].generated == [first]


def test_rejects_oversized_context():
    cfg, model, params, eng = _setup()
    with pytest.raises(ValueError):
        eng.submit(np.zeros(999, np.int32))


def test_queue_full_carries_backpressure_signal():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=1, ctx_len=32,
                        max_new=2, max_queue=2)
    rng = np.random.default_rng(0)
    eng.submit(rng.integers(0, cfg.vocab, 32))
    eng.submit(rng.integers(0, cfg.vocab, 32))
    with pytest.raises(QueueFull) as ei:
        eng.submit(rng.integers(0, cfg.vocab, 32))
    assert ei.value.queue_depth == 2
    assert ei.value.retry_after_s > 0.0
    assert eng.stats["rejected"] == 1
    # bounded blocking submit: gives up after the timeout with the
    # same structured rejection
    t0 = time.monotonic()
    with pytest.raises(QueueFull):
        eng.submit(rng.integers(0, cfg.vocab, 32), block=True,
                   timeout=0.1)
    assert 0.05 < time.monotonic() - t0 < 5.0
    assert eng.stats["rejected"] == 2


def test_blocking_submit_admits_when_queue_drains():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=1, ctx_len=32,
                        max_new=2, max_queue=1)
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(0, cfg.vocab, 32))

    def drain():
        time.sleep(0.2)
        eng._queue.pop(0)       # another thread serving the queue

    th = threading.Thread(target=drain)
    th.start()
    rid = eng.submit(rng.integers(0, cfg.vocab, 32), block=True,
                     timeout=10.0)
    th.join()
    assert isinstance(rid, int)
    assert eng.stats["rejected"] == 0


@pytest.mark.parametrize("scheduler", ["wave", "continuous"])
def test_degraded_service_per_request_errors(scheduler, monkeypatch):
    """A transport/runtime fault mid-schedule fails the affected
    requests with ``Result.error`` set instead of blowing up ``run`` —
    the engine object stays serviceable."""
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=2, ctx_len=32,
                        max_new=2, scheduler=scheduler)
    rng = np.random.default_rng(4)
    rids = [eng.submit(rng.integers(0, cfg.vocab, 32)) for _ in range(3)]
    if scheduler == "wave":
        monkeypatch.setattr(eng, "_run_wave",
                            lambda wave: (_ for _ in ()).throw(
                                RuntimeError("wire died")))
    else:
        monkeypatch.setattr(eng, "_continuous_loop",
                            lambda *a: (_ for _ in ()).throw(
                                RuntimeError("wire died")))
    out = eng.run()
    assert sorted(out) == sorted(rids)
    assert all(out[r].error and "wire died" in out[r].error for r in rids)
    assert eng.stats["failed_requests"] == 3
    assert any(e[0] == "degraded" and "wire died" in e[2]
               for e in eng.transcript)
    # the engine still serves fresh work afterwards
    monkeypatch.undo()
    rid = eng.submit(rng.integers(0, cfg.vocab, 32))
    ok = eng.run()
    assert ok[rid].error is None and len(ok[rid].generated) == 2
