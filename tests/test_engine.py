"""Serving engine: queueing, waves, determinism vs the raw decode path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.engine import ServingEngine
from repro.models.model import SplitModel


def _setup(batch_slots=2, ctx=32):
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=batch_slots,
                        ctx_len=ctx, max_new=6)
    return cfg, model, params, eng


def test_queue_drains_across_waves():
    cfg, model, params, eng = _setup(batch_slots=2)
    rng = np.random.default_rng(0)
    rids = [eng.submit(rng.integers(0, cfg.vocab, 32)) for _ in range(5)]
    out = eng.run()
    assert sorted(out) == sorted(rids)
    assert eng.stats["waves"] == 3            # 2 + 2 + 1
    assert all(len(out[r].generated) == 6 for r in rids)


def test_engine_matches_manual_decode():
    cfg, model, params, eng = _setup(batch_slots=1)
    rng = np.random.default_rng(1)
    ctx = rng.integers(0, cfg.vocab, 32).astype(np.int32)
    rid = eng.submit(ctx, max_new=4)
    out = eng.run()[rid]

    # manual greedy decode of the same request
    S, P = 32, cfg.split.n_owners
    caches = model.cache_init(1, S, n_new=5)
    ot = jnp.asarray(ctx.reshape(1, P, S // P).transpose(1, 0, 2))
    logits, caches = model.prefill(params, {"owner_tokens": ot}, caches)
    toks = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(4):
        toks.append(int(tok[0, 0]))
        if t < 3:
            logits, caches = model.decode_step(params, caches, tok,
                                               S + t, S // P + t)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert out.generated == toks


def test_eos_stops_early():
    cfg, model, params, eng = _setup(batch_slots=1)
    rng = np.random.default_rng(2)
    ctx = rng.integers(0, cfg.vocab, 32)
    # pick the EOS as whatever greedy emits first -> length must be 1
    rid = eng.submit(ctx, max_new=6)
    first = eng.run()[rid].generated[0]
    eng2 = ServingEngine(model, params, batch_slots=1, ctx_len=32,
                         max_new=6, eos_token=first)
    rid2 = eng2.submit(ctx, max_new=6)
    assert eng2.run()[rid2].generated == [first]


def test_rejects_oversized_context():
    cfg, model, params, eng = _setup()
    import pytest
    with pytest.raises(ValueError):
        eng.submit(np.zeros(999, np.int32))
