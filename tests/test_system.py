"""End-to-end behaviour tests for the PyVertical system: the full paper
pipeline (vertical split -> PSI resolution -> dual-headed SplitNN training)
and the large-model split-training/serving drivers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core import MLPSplitNN, make_split_train_step, resolve
from repro.core.splitnn import train_state_init
from repro.data import make_vertical_mnist_parties
from repro.optim import multi_segment, sgd


def test_full_paper_pipeline_end_to_end():
    """Figure 2: split data -> PSI linkage + ordering -> SplitNN training.
    Uses the fast 512-bit PSI group (same protocol as production 2048)."""
    sci, owners = make_vertical_mnist_parties(300, seed=0, keep_frac=0.85)
    s_al, o_al, stats = resolve(sci, owners, group="modp512")
    assert stats["global_intersection"] == len(s_al.ids) > 150

    model = MLPSplitNN(MNIST_CFG)
    params = model.init(jax.random.PRNGKey(0))
    opt = multi_segment({"heads": sgd(MNIST_CFG.split.owner_lr),
                         "trunk": sgd(MNIST_CFG.split.scientist_lr)})
    state = train_state_init(params, opt)
    step = make_split_train_step(model.loss_fn, opt, donate=False)

    xs = jnp.asarray(np.stack([o_al["owner0"].data, o_al["owner1"].data]))
    ys = jnp.asarray(s_al.data.astype(np.int32))
    first_loss = None
    for i in range(60):
        params, state, m = step(params, state,
                                {"x_slices": xs, "labels": ys}, i)
        if first_loss is None:
            first_loss = float(m["loss"])
    assert float(m["loss"]) < first_loss * 0.7, "training did not learn"


def test_train_launcher_loss_decreases():
    from repro.launch.train import main
    loss = main(["--arch", "llama3.2-3b", "--reduced", "--steps", "30",
                 "--batch", "4", "--seq", "64", "--log-every", "29"])
    assert loss < np.log(512) * 1.05  # moved below uniform entropy


def test_serve_launcher_generates():
    from repro.launch.serve import main
    gen = main(["--arch", "llama3.2-3b", "--reduced", "--batch", "2",
                "--ctx", "32", "--new", "5"])
    assert gen.shape == (2, 5)
    assert (gen >= 0).all()
