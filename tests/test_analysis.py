"""HLO-analysis parser: shape-byte parsing, collective detection, and
cross-pod replica-group logic (both explicit and iota forms)."""
import numpy as np

from repro.launch.analysis import (_iota_groups, collective_stats,
                                   shape_bytes)


def test_shape_bytes():
    assert shape_bytes("bf16[2048,16384]{1,0}") == 2048 * 16384 * 2
    assert shape_bytes("f32[16]{0}") == 64
    assert shape_bytes("(bf16[8,8]{1,0}, f32[4]{0})") == 128 + 16
    assert shape_bytes("pred[]") == 1  # scalar: one element


def test_collective_stats_counts_ops():
    hlo = """
  %add.1 = f32[8]{0} add(%a, %b)
  %all-reduce.5 = f32[128,256]{1,0} all-reduce(%x), channel_id=1, replica_groups={{0,1},{2,3}}
  %all-gather.2 = bf16[64,64]{1,0} all-gather(%y), channel_id=2, replica_groups={{0,1,2,3}}
"""
    s = collective_stats(hlo)
    assert s["n_ops"] == 2
    assert s["per_kind_bytes"]["all-reduce"] == 128 * 256 * 4
    assert s["per_kind_bytes"]["all-gather"] == 64 * 64 * 2


def test_cross_pod_detection_explicit_groups():
    hlo = ("  %all-reduce.1 = f32[4]{0} all-reduce(%x), channel_id=1, "
           "replica_groups={{0,1},{2,3}}\n"
           "  %all-reduce.2 = f32[4]{0} all-reduce(%y), channel_id=2, "
           "replica_groups={{0,2},{1,3}}\n")
    s = collective_stats(hlo, devices_per_pod=2)
    # first op stays within pods {0,1} and {2,3}; second crosses
    assert s["cross_pod_bytes"] == 16
    assert len(s["cross_pod_ops"]) == 1


def test_iota_groups_plain():
    g = _iota_groups([2, 4], [8], None)
    np.testing.assert_array_equal(g, [[0, 1, 2, 3], [4, 5, 6, 7]])


def test_iota_groups_transposed():
    # [4,2]<=[2,4]T(1,0): ids arranged column-major over a (2,4) grid
    g = _iota_groups([4, 2], [2, 4], [1, 0])
    np.testing.assert_array_equal(g, [[0, 4], [1, 5], [2, 6], [3, 7]])


def test_cross_pod_detection_iota():
    # groups of 2 pairing device i with i+4 across a 4-per-pod boundary
    hlo = ("  %all-gather.9 = f32[8]{0} all-gather(%x), channel_id=3, "
           "replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}\n")
    s = collective_stats(hlo, devices_per_pod=4)
    assert s["cross_pod_bytes"] == 32
    # same op within one pod: groups [0..3],[4..7]
    hlo2 = ("  %all-gather.9 = f32[8]{0} all-gather(%x), channel_id=3, "
            "replica_groups=[2,4]<=[8], dimensions={0}\n")
    s2 = collective_stats(hlo2, devices_per_pod=4)
    assert s2["cross_pod_bytes"] == 0


def test_async_pairs_counted_once():
    hlo = ("  %all-gather-start.1 = f32[8]{0} all-gather-start(%x), "
           "channel_id=1, replica_groups={{0,1}}\n"
           "  %all-gather-done.1 = f32[8]{0} all-gather-done("
           "%all-gather-start.1)\n")
    s = collective_stats(hlo)
    assert s["n_ops"] == 1
