"""The scalable PSI engine: chunked/parallel rounds must be bit-identical
to the serial path, degrade gracefully without gmpy2 or fork, and keep
the in-flight working set bounded (ISSUE 4 tentpole)."""
import importlib.util
import sys

import numpy as np
from repro.testing.hypo import given, settings, strategies as st

from repro.core import modexp
from repro.core.bloom import BloomFilter, ShardedBloom
from repro.core.modexp import ModexpPool, pack_ints, unpack_ints
from repro.core.psi import PSIClient, PSIServer, psi_intersect, psi_round

GROUP = "modp512"  # fast test group; protocol identical to modp2048


def _reset(client, server):
    """Drop per-session caches so a re-run recomputes every leg with the
    SAME secrets — what bit-identity must survive."""
    client.reset_session()
    server.reset_session()


# ---------------------------------------------------------------------------
# Serial == chunked == parallel (bit-identical)
# ---------------------------------------------------------------------------


@given(st.lists(st.text(min_size=1, max_size=10), min_size=0, max_size=60),
       st.lists(st.text(min_size=1, max_size=10), min_size=0, max_size=60),
       st.integers(1, 17))
@settings(max_examples=12, deadline=None)
def test_chunked_round_bit_identical_to_serial(xs, ys, chunk):
    """Random uneven sets (duplicates allowed): every chunk size yields
    the exact same intersection list — same elements, same order, same
    duplicate multiplicity — as the one-chunk serial round."""
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ref, _ = psi_round(client, server, chunk_size=max(len(xs), 1))
    _reset(client, server)
    got, stats = psi_round(client, server, chunk_size=chunk)
    assert got == ref
    assert sorted(set(got)) == sorted(set(xs) & set(ys))
    assert stats["n_chunks"] == max(1, -(-len(xs) // chunk))


def test_parallel_round_bit_identical_to_serial():
    xs = [f"id-{i}" for i in range(400)] + ["dup"] * 3
    ys = [f"id-{i + 150}" for i in range(400)] + ["dup"]
    client = PSIClient(xs, GROUP)
    server = PSIServer(ys, group=GROUP)
    ref, _ = psi_round(client, server, chunk_size=64)
    _reset(client, server)
    with ModexpPool(2) as pool:
        got, stats = psi_round(client, server, pool=pool, chunk_size=64)
    assert got == ref
    assert got.count("dup") == 3                 # client-side multiplicity
    if stats["parallelism"]:                     # host allowed fork
        assert stats["parallelism"] == 2


def test_empty_intersection_and_empty_sets():
    for xs, ys in ([["a", "b"], ["c", "d"]], [[], ["a"]], [["a"], []],
                   [[], []]):
        for par in (0, 2):
            inter, _ = psi_intersect(xs, ys, group=GROUP, chunk_size=1,
                                     parallelism=par)
            assert inter == []


def test_memoized_blind_survives_engine_switch():
    """The packed blinded set computed by the serial engine is reused
    verbatim by the parallel engine (one session, many owners)."""
    client = PSIClient([f"id-{i}" for i in range(50)], GROUP)
    s1 = PSIServer([f"id-{i + 10}" for i in range(50)], group=GROUP)
    i1, st1 = psi_round(client, s1, chunk_size=16)
    blob = client._blinded_packed
    with ModexpPool(2) as pool:
        s2 = PSIServer([f"id-{i + 20}" for i in range(50)], group=GROUP)
        i2, st2 = psi_round(client, s2, pool=pool, chunk_size=16)
    assert client._blinded_packed is blob        # never recomputed
    assert not st1["blind_cached"] and st2["blind_cached"]
    assert i2 == [f"id-{i}" for i in range(20, 50)]


# ---------------------------------------------------------------------------
# Protocol variants
# ---------------------------------------------------------------------------


@given(st.lists(st.text(min_size=1, max_size=8), min_size=0, max_size=40),
       st.lists(st.text(min_size=1, max_size=8), min_size=0, max_size=40))
@settings(max_examples=10, deadline=None)
def test_noinv_and_bloom_modes_agree(xs, ys):
    """Both protocol variants (inverse-free double-blinded comparison vs
    Bloom-compressed unblinding) recover the same intersection, with the
    same client-order + duplicate semantics."""
    noinv, s1 = psi_intersect(xs, ys, group=GROUP, mode="noinv",
                              chunk_size=7)
    bloom, s2 = psi_intersect(xs, ys, group=GROUP, mode="bloom",
                              chunk_size=7)
    assert noinv == bloom
    assert s1["mode"] == "noinv" and s2["mode"] == "bloom"


def test_noinv_trades_wire_for_compute():
    """The variant table's claim: bloom mode compresses the server set
    ~12x; noinv ships it raw but runs no full-width exponent."""
    xs = [f"a{i}" for i in range(300)]
    ys = [f"a{i + 100}" for i in range(300)]
    _, sn = psi_intersect(xs, ys, group=GROUP, mode="noinv")
    _, sb = psi_intersect(xs, ys, group=GROUP, mode="bloom")
    assert sn["server_set_bytes"] == sn["uncompressed_server_set_bytes"]
    assert sb["bloom_bytes"] * 8 < sb["uncompressed_server_set_bytes"]
    assert sn["server_response_bytes"] > sb["server_response_bytes"]


def test_noinv_client_through_bloom_compat_surface():
    """A noinv-mode client driven through the legacy blind/respond/
    intersect API lazily inverts its exponent and still succeeds."""
    client = PSIClient(["a", "b", "c"], GROUP)          # default: noinv
    server = PSIServer(["b", "c", "d"], group=GROUP)
    double, bf = server.respond(client.blind())
    assert client.intersect(double, bf) == ["b", "c"]


# ---------------------------------------------------------------------------
# Graceful degradation
# ---------------------------------------------------------------------------


def test_gmpy2_absent_fallback(monkeypatch):
    """With gmpy2 unimportable, the backend is the builtin pow and the
    whole protocol still computes the same integers."""
    monkeypatch.setitem(sys.modules, "gmpy2", None)  # import -> ImportError
    spec = importlib.util.find_spec("repro.core.modexp")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.HAVE_GMPY2 is False
    assert mod.powmod(12345, 678, 1009) == pow(12345, 678, 1009)
    out = mod.pow_chunk((pack_ints([7, 11], 8), 3, 1000003, 8))
    assert unpack_ints(out, 8) == [pow(7, 3, 1000003),
                                   pow(11, 3, 1000003)]


def test_backend_matches_builtin_pow():
    """Whatever backend is live (gmpy2 or builtin), it agrees with pow."""
    p = 2 ** 127 - 1
    for base, exp in [(3, 65537), (p - 2, p - 2), (1, 0)]:
        assert modexp.powmod(base, exp, p) == pow(base, exp, p)


def test_pool_fork_failure_degrades_to_serial(monkeypatch):
    import concurrent.futures as cf

    def boom(*a, **k):
        raise OSError("no fork for you")

    monkeypatch.setattr(cf, "ProcessPoolExecutor", boom)
    pool = ModexpPool(4)
    assert not pool.is_parallel
    assert "no fork for you" in pool.fallback_reason
    inter, stats = psi_intersect(["a", "b", "c"], ["b", "c", "d"],
                                 group=GROUP, pool=pool)
    assert inter == ["b", "c"] and stats["parallelism"] == 0


def test_imap_bounded_lookahead():
    """The pool never pulls more than ``inflight`` tasks ahead of the
    consumer — the property that bounds peak memory for 1e6-ID streams."""
    pool = ModexpPool(0)                         # serial: lookahead 1
    pulled, consumed = [], []

    def tasks():
        for i in range(20):
            pulled.append(i)
            yield (pack_ints([i + 2], 8), 3, 1000003, 8)

    for out in pool.imap(modexp.pow_chunk, tasks()):
        consumed.append(out)
        assert len(pulled) - len(consumed) <= max(pool.inflight, 1)
    assert len(consumed) == 20


def test_round_reports_bounded_inflight():
    xs = [f"x{i}" for i in range(1000)]
    client = PSIClient(xs, GROUP)
    server = PSIServer(xs[::2], group=GROUP)
    _, stats = psi_round(client, server, chunk_size=128)
    assert stats["peak_inflight_elements"] <= 128 * ModexpPool(0).inflight
    assert stats["peak_inflight_elements"] < len(xs)


# ---------------------------------------------------------------------------
# Sharded bloom
# ---------------------------------------------------------------------------


@given(st.sets(st.binary(min_size=1, max_size=24), min_size=1, max_size=300),
       st.integers(1, 5))
@settings(max_examples=15, deadline=None)
def test_sharded_bloom_no_false_negatives(items, shards):
    items = sorted(items)
    bf = ShardedBloom.for_capacity(len(items), 1e-6, n_shards=shards)
    bf.add_batch(items)
    assert bf.query_batch(items).all()
    for it in items[:10]:
        assert it in bf                          # scalar path agrees


def test_sharded_bloom_parallel_build_merge_equals_serial():
    items = [f"m{i}".encode() for i in range(500)]
    whole = ShardedBloom.for_capacity(500, 1e-6, n_shards=4)
    whole.add_batch(items)
    a = ShardedBloom.for_capacity(500, 1e-6, n_shards=4)
    b = ShardedBloom.for_capacity(500, 1e-6, n_shards=4)
    a.add_batch(items[:250])
    b.add_batch(items[250:])
    merged = a.merge(b)
    for s1, s2 in zip(whole.shards, merged.shards):
        np.testing.assert_array_equal(s1.bits, s2.bits)


def test_sharded_bloom_frames_bound_message_size():
    bf = ShardedBloom.for_capacity(200_000, 1e-9)
    frames = bf.shard_frames()
    assert len(frames) == bf.n_shards > 1
    assert sum(len(f) for f in frames) == bf.nbytes()
    assert max(len(f) for f in frames) < 300 * 1024   # streamable frames


def test_bloom_scalar_and_batch_paths_agree():
    bf = BloomFilter.for_capacity(64, 1e-6)
    items = [f"i{i}".encode() for i in range(64)]
    bf.add_batch(items[:32])
    for it in items[32:]:
        bf.add(it)
    batch = bf.query_batch(items)
    assert batch.all()
    assert all(it in bf for it in items)


# ---------------------------------------------------------------------------
# resolve() surfaces
# ---------------------------------------------------------------------------


def test_resolution_parallel_matches_serial():
    from repro.core.resolution import VerticalDataset, resolve
    rng = np.random.default_rng(0)
    ids = [f"s{i}" for i in range(120)]
    sci = VerticalDataset(ids, rng.integers(0, 9, 120))
    owners = {f"o{k}": VerticalDataset(
        [ids[i] for i in rng.permutation(120)[:90]],
        rng.normal(size=(90, 3)).astype(np.float32)) for k in range(3)}
    ser = resolve(sci, owners, group=GROUP)
    par = resolve(sci, owners, group=GROUP, parallelism=2, chunk_size=17)
    assert ser[0].ids == par[0].ids
    assert ser[2]["global_intersection"] == par[2]["global_intersection"]
    for name in owners:
        assert ser[1][name].ids == par[1][name].ids


def test_session_resolve_parallel_matches_serial():
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties

    def build():
        sci, owners = make_vertical_mnist_parties(240, seed=3,
                                                  keep_frac=0.8)
        return VerticalSession(*feature_parties(sci, owners))

    s_ser, s_par = build(), build()
    st_ser = s_ser.resolve(group=GROUP)
    st_par = s_par.resolve(group=GROUP, parallelism=2, chunk_size=37)
    assert s_ser.scientist.ids == s_par.scientist.ids
    assert (st_ser["global_intersection"]
            == st_par["global_intersection"])
    kinds = {m["kind"] for m in s_par.transcript}
    assert {"psi_blind_chunk", "psi_double_chunk",
            "psi_server_set_chunk"} <= kinds     # default mode: noinv


def test_session_resolve_reuses_blind_across_owners():
    from repro.data import make_vertical_mnist_parties
    from repro.federation import VerticalSession, feature_parties
    sci, owners = make_vertical_mnist_parties(150, seed=1, n_owners=2)
    session = VerticalSession(*feature_parties(sci, owners))
    stats = session.resolve(group=GROUP, chunk_size=32)
    cached = [r["blind_cached"] for r in stats["rounds"]]
    assert cached == [False, True]               # paid once, reused after
