"""Continuous-batching serving: scheduler bit-identity, slot refill,
repeat-entity cut cache, session multiplexing, admission control, and
the per-run stats/latency contracts (ISSUE 7)."""
import queue as queue_mod
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.federation import batching
from repro.federation.transport import ScopedEndpoint, channel_pair
from repro.launch.engine import (CutCache, QueueFull, ServingEngine,
                                 ServingService)
from repro.models.model import SplitModel

TRANSPORTS = [None, "direct", "queue", "process"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(setup, **kw):
    cfg, model, params = setup
    kw.setdefault("batch_slots", 2)
    kw.setdefault("ctx_len", 32)
    kw.setdefault("max_new", 6)
    return ServingEngine(model, params, **kw)


def _contexts(setup, n, seed=0, length=32):
    cfg = setup[0]
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, length) for _ in range(n)]


# ---------------------------------------------------------------- edge cases


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_queue_longer_than_slots_refills(setup, transport):
    """5 requests through 2 slots: continuous batching must refill freed
    slots (not wave-drain) and still return every request."""
    eng = _engine(setup, scheduler="continuous", transport=transport)
    mixed = [2, 6, 3, 6, 4]
    rids = [eng.submit(c, max_new=m)
            for c, m in zip(_contexts(setup, 5), mixed)]
    out = eng.run()
    eng.close()
    assert sorted(out) == sorted(rids)
    assert [len(out[r].generated) for r in rids] == mixed
    assert eng.stats["slot_refills"] >= 3
    assert eng.stats["requests"] == 5
    # continuous ticks track total tokens / slots, not 3 waves x max_new
    assert eng.stats["ticks"] < 3 * 6


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_eos_on_first_decoded_token(setup, transport):
    """A request whose very first greedy token is EOS finishes at length
    1 without a decode step, and its slot refills immediately."""
    (ctx,) = _contexts(setup, 1, seed=3)
    probe = _engine(setup, scheduler="continuous")
    rid = probe.submit(ctx)
    first = probe.run()[rid].generated[0]
    eng = _engine(setup, scheduler="continuous", transport=transport,
                  eos_token=first)
    rids = [eng.submit(ctx, max_new=6) for _ in range(3)]
    out = eng.run()
    eng.close()
    assert all(out[r].generated == [first] for r in rids)
    assert eng.stats["slot_refills"] >= 1


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_submit_after_run(setup, transport):
    """The engine is a service, not a one-shot: new submissions after a
    drained run() are served by the next run()."""
    eng = _engine(setup, scheduler="continuous", transport=transport)
    c1, c2 = _contexts(setup, 2, seed=4)
    r1 = eng.submit(c1, max_new=3)
    out1 = eng.run()
    r2 = eng.submit(c2, max_new=3)
    out2 = eng.run()
    eng.close()
    assert list(out1) == [r1] and list(out2) == [r2]
    assert len(out2[r2].generated) == 3


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_context_exactly_ctx_len(setup, transport):
    """A context of exactly ctx_len is admitted (no padding left)."""
    eng = _engine(setup, scheduler="continuous", transport=transport)
    (ctx,) = _contexts(setup, 1, seed=5, length=32)
    assert len(ctx) == eng.S
    rid = eng.submit(ctx, max_new=2)
    out = eng.run()
    eng.close()
    assert len(out[rid].generated) == 2
    with pytest.raises(ValueError):
        eng.submit(np.zeros(33, np.int32))


@pytest.mark.parametrize("transport", TRANSPORTS)
def test_empty_queue_run(setup, transport):
    """run() with nothing queued is a no-op returning {} (no compile,
    no wire traffic)."""
    eng = _engine(setup, scheduler="continuous", transport=transport)
    assert eng.run() == {}
    assert eng.stats["ticks"] == 0
    assert eng.stats["cut_messages"] == 0
    eng.close()


# ------------------------------------------------------- scheduler identity


@pytest.mark.parametrize("transport", [None, "queue"])
def test_continuous_matches_wave_bitwise(setup, transport):
    """Greedy decode property: the same request set generates
    bit-identical tokens under wave and continuous scheduling, mixed
    max_new, more requests than slots."""
    mixed = [2, 6, 1, 5, 6, 3]
    ctxs = _contexts(setup, 6, seed=6)

    def run(sched):
        eng = _engine(setup, scheduler=sched, transport=transport)
        rids = [eng.submit(c, max_new=m) for c, m in zip(ctxs, mixed)]
        out = eng.run()
        eng.close()
        return [out[r].generated for r in rids]

    assert run("wave") == run("continuous")


@pytest.mark.slow
def test_continuous_queue_matches_process(setup):
    """Backend property: continuous scheduling generates identical
    tokens and identical measured cut bytes over the thread-backed queue
    and the OS-pipe process transports."""
    mixed = [2, 5, 3]
    ctxs = _contexts(setup, 3, seed=7)

    def run(tr):
        eng = _engine(setup, scheduler="continuous", transport=tr)
        rids = [eng.submit(c, max_new=m) for c, m in zip(ctxs, mixed)]
        out = eng.run()
        stats = dict(eng.stats)
        eng.close()
        return [out[r].generated for r in rids], stats

    gq, sq = run("queue")
    gp, sp = run("process")
    assert gq == gp
    assert sq["cut_wire_bytes"] == sp["cut_wire_bytes"]
    assert sq["cut_messages"] == sp["cut_messages"]


# -------------------------------------------------------------- stats fixes


def test_per_request_latency(setup):
    """Satellite: latency is submit->finish per request, not the wave's
    wall time — a 1-token request in the same wave as a 6-token request
    must report strictly less latency."""
    for sched in ("wave", "continuous"):
        eng = _engine(setup, scheduler=sched)
        ctxs = _contexts(setup, 2, seed=8)
        r_short = eng.submit(ctxs[0], max_new=1)
        r_long = eng.submit(ctxs[1], max_new=6)
        out = eng.run()
        assert 0.0 < out[r_short].latency_s < out[r_long].latency_s


def test_cut_stats_are_per_engine_deltas(setup):
    """Satellite regression: stats accumulate per-engine deltas and
    match the channel's by_kind totals exactly — two runs double the
    first run's traffic instead of overwriting with cumulative totals."""
    eng = _engine(setup, scheduler="continuous", transport="queue")
    ctxs = _contexts(setup, 2, seed=9)
    for c in ctxs:
        eng.submit(c, max_new=3)
    eng.run()
    first = (eng.stats["cut_payload_bytes"], eng.stats["cut_wire_bytes"],
             eng.stats["cut_messages"])
    assert first[0] > 0
    for c in ctxs:
        eng.submit(c, max_new=3)
    eng.run()
    assert eng.stats["cut_payload_bytes"] == 2 * first[0]
    assert eng.stats["cut_wire_bytes"] == 2 * first[1]
    assert eng.stats["cut_messages"] == 2 * first[2]
    bk = eng._ep_sci.recv_stats["by_kind"]
    total = sum(bk.get(k, {}).get("payload_bytes", 0)
                for k in ("cut_activations", "cut_prefill"))
    assert eng.stats["cut_payload_bytes"] == total
    eng.close()


def test_wave_stats_delta_regression(setup):
    """The original overwrite bug, pinned on the wave path too: N waves
    of identical traffic report N x one wave's bytes."""
    eng = _engine(setup, batch_slots=1, scheduler="wave",
                  transport="queue")
    ctxs = _contexts(setup, 2, seed=10)
    eng.submit(ctxs[0], max_new=2)
    eng.run()
    one = eng.stats["cut_payload_bytes"]
    eng.submit(ctxs[0], max_new=2)
    eng.run()
    assert eng.stats["cut_payload_bytes"] == 2 * one
    eng.close()


# ---------------------------------------------------------------- cut cache


def test_repeat_entity_zero_upload(setup):
    """Acceptance: a returning entity's request ships zero cut-upload
    bytes and recomputes nothing owner-side — the admission control
    frame is the only wire traffic, and the cache hit is transcripted."""
    eng = _engine(setup, scheduler="continuous", transport="queue",
                  cut_cache=True)
    (ctx,) = _contexts(setup, 1, seed=11)
    r1 = eng.submit(ctx, max_new=4)
    out1 = eng.run()
    pc, pb, pm = (eng.stats["prefill_calls"], eng.stats["cut_payload_bytes"],
                  eng.stats["cut_messages"])
    r2 = eng.submit(ctx, max_new=1)
    out2 = eng.run()
    eng.close()
    assert eng.stats["prefill_calls"] == pc          # zero head recompute
    assert eng.stats["cut_payload_bytes"] == pb      # zero upload bytes
    assert eng.stats["cut_messages"] == pm
    assert eng.stats["cut_cache_hits"] == 1
    assert any(e[0] == "cut_cache_hit" and e[1] == r2
               for e in eng.transcript)
    # and the cached-path token is bitwise the fresh-path token
    assert out2[r2].generated[0] == out1[r1].generated[0]


def test_cache_hit_preserves_bit_identity(setup):
    """A cache-hit continuation decodes bitwise like a fresh request:
    full generations match between a cache-hitting engine and a cold
    wave engine."""
    (ctx,) = _contexts(setup, 1, seed=12)
    eng = _engine(setup, scheduler="continuous", transport="queue",
                  cut_cache=True)
    r1 = eng.submit(ctx, max_new=5)
    first = eng.run()[r1].generated
    r2 = eng.submit(ctx, max_new=5)          # repeat entity: cache hit
    second = eng.run()[r2].generated
    eng.close()
    assert eng.stats["cut_cache_hits"] == 1
    assert second == first


def test_cut_cache_lru_eviction():
    cache = CutCache(max_entries=2)
    for t in ("a", "b", "c"):
        cache.put(t, {"v": t})
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.get("a") is None            # evicted (oldest)
    assert cache.get("c")["v"] == "c"
    assert (cache.hits, cache.misses) == (1, 1)


def test_context_tag_content_addressing():
    a = batching.pad_context_row(np.arange(5), 8)
    b = batching.pad_context_row(np.arange(5), 8)
    c = batching.pad_context_row(np.arange(1, 6), 8)
    assert batching.context_tag(a) == batching.context_tag(b)
    assert batching.context_tag(a) != batching.context_tag(c)


# ------------------------------------------------------ admission control


def test_bounded_queue_backpressure(setup):
    eng = _engine(setup, scheduler="continuous", max_queue=2)
    ctxs = _contexts(setup, 3, seed=13)
    eng.submit(ctxs[0])
    eng.submit(ctxs[1])
    with pytest.raises(QueueFull):
        eng.submit(ctxs[2])
    assert eng.stats["rejected"] == 1
    assert eng.stats["submitted"] == 2
    assert eng.stats["peak_queue_depth"] == 2
    eng.run()                                # drains; capacity returns
    eng.submit(ctxs[2], max_new=1)
    assert eng.stats["submitted"] == 3


# --------------------------------------------------- session multiplexing


def test_scoped_endpoint_stats_filtering():
    a, b = channel_pair("owners", "scientist", backend="queue")
    s0a, s1a = ScopedEndpoint(a, "s0:"), ScopedEndpoint(a, "s1:")
    s0b, s1b = ScopedEndpoint(b, "s0:"), ScopedEndpoint(b, "s1:")
    s0a.send("cut", {"x": np.zeros(4, np.float32)})
    s1a.send("cut", {"x": np.zeros(8, np.float32)})
    s1a.send("grad", {"x": np.zeros(2, np.float32)})
    # interleaved kinds resolve to the right scope, stash absorbing
    assert s1b.recv_kind("grad").payload["x"].nbytes == 8
    assert s0b.recv_kind("cut").payload["x"].nbytes == 16
    assert s1b.recv_kind("cut").payload["x"].nbytes == 32
    assert s0a.sent_stats["by_kind"]["cut"]["payload_bytes"] == 16
    assert s1a.sent_stats["by_kind"]["cut"]["payload_bytes"] == 32
    assert s0a.sent_stats["messages"] == 1
    assert s1a.sent_stats["messages"] == 2
    assert "s0:cut" in a.sent_stats["by_kind"]       # raw view keeps scope


@pytest.mark.slow
def test_multiplexed_sessions_concurrent(setup):
    """Two engine sessions on threads over ONE shared queue channel
    generate exactly what dedicated-channel engines generate, and each
    session's stats see only its own frames."""
    cfg, model, params = setup
    svc = ServingService(model, params, transport="queue", batch_slots=2,
                         ctx_len=32, max_new=6)
    s1, s2 = svc.session(), svc.session()
    ca = _contexts(setup, 3, seed=14)
    cb = _contexts(setup, 3, seed=15)
    res = {}

    def drive(s, cs, key):
        rids = [s.submit(c, max_new=4) for c in cs]
        out = s.run()
        res[key] = [out[r].generated for r in rids]

    t1 = threading.Thread(target=drive, args=(s1, ca, "a"))
    t2 = threading.Thread(target=drive, args=(s2, cb, "b"))
    t1.start(); t2.start()
    t1.join(180); t2.join(180)
    assert not t1.is_alive() and not t2.is_alive()

    def ref(cs):
        eng = _engine(setup, scheduler="continuous", transport="queue")
        rids = [eng.submit(c, max_new=4) for c in cs]
        out = eng.run()
        eng.close()
        return [out[r].generated for r in rids], dict(eng.stats)

    ra, sa = ref(ca)
    rb, _ = ref(cb)
    assert res["a"] == ra and res["b"] == rb
    # per-session accounting == a dedicated engine's accounting
    assert s1.stats["cut_payload_bytes"] == sa["cut_payload_bytes"]
    assert s1.stats["cut_messages"] == sa["cut_messages"]
    # the shared channel saw both sessions' scoped kinds
    kinds = set(svc.channel_stats["by_kind"])
    assert any(k.startswith("s0:") for k in kinds)
    assert any(k.startswith("s1:") for k in kinds)
    svc.close()


def test_service_shared_cut_cache(setup):
    """The cut cache is service-wide: an entity seen by session A is a
    cache hit when it returns through session B."""
    cfg, model, params = setup
    svc = ServingService(model, params, transport="queue", batch_slots=2,
                         ctx_len=32, max_new=6)
    (ctx,) = _contexts(setup, 1, seed=16)
    s1 = svc.session()
    r1 = s1.submit(ctx, max_new=3)
    g1 = s1.run()[r1].generated
    s2 = svc.session()
    r2 = s2.submit(ctx, max_new=3)
    g2 = s2.run()[r2].generated
    assert s2.stats["cut_cache_hits"] == 1
    # zero context-upload bytes: no cut_prefill frames in session B's
    # scoped traffic (decode-tick ships are generation, not upload)
    assert "cut_prefill" not in s2._ep_sci.recv_stats["by_kind"]
    assert g2 == g1
    svc.close()


def test_recv_kind_timeout_raises():
    a, b = channel_pair("x", "y", backend="queue")
    with pytest.raises(queue_mod.Empty):
        b.recv_kind("never", timeout=0.15)
