"""Secure forward aggregation — pairwise-cancelling cut-layer masks.

Cai et al. (PAPERS.md, "Secure Forward Aggregation", 2207.00165) observe
that a sum-combine scientist never needs per-owner head outputs: each
owner can ship ``head_out + mask`` where the masks cancel across the
owner set, so the scientist reconstructs exactly ``sum_p head_out_p``
and nothing else.  Floating-point addition is not exact, so cancellation
happens in an integer ring instead:

1. **Fixed-point lift.**  Each owner quantizes its cut activation to
   ``q = clip(round(x * 2^SCALE_BITS))`` as int32 (``quantize()``, a
   jitted program shared with the joint oracle).  Every |q| stays below
   2^24, so the float round is exact and P-owner sums fit int32 with
   headroom.
2. **Ring masking.**  For every owner pair (p, q), p < q, a shared seed
   derives a uniform uint32 stream; p adds it and q subtracts it mod
   2^32 (``pairwise_mask``).  Summed over ALL owners the masks are
   exactly zero in the ring, so the scientist's fold
   (``reconstruct()``) recovers the true integer sum **bitwise** —
   masked split execution is bit-identical to the unmasked joint
   oracle running the same quantize→sum→dequantize combine.
3. **Dequantize + straight-through backward.**  The trunk consumes
   ``z = sum_q.astype(f32) * 2^-SCALE_BITS``; the cut gradient is
   ``dL/dz`` for every owner (the sum-combine broadcast), so the
   backward is the plain sum combine's backward and masks never touch
   gradients.

Per-message masks are a pure function of ``(root seed, pair, tag)`` —
no stream state — so a respawned owner (PR 8 supervised recovery) at
any generation re-derives the masks of the steps it replays, and all
owners agree without coordination.  The root seed travels over the
**env channel** (``REPRO_MASK_SEED``, inherited by spawned workers the
same way the chaos plan rides ``REPRO_CHAOS_PARTY``) — the simulation
stand-in for the out-of-band owner-to-owner key agreement of Cai et
al.; the scientist's code path never derives a mask.

Threat model: an eavesdropper (or honest-but-curious scientist) who
records the wire sees, per owner, uniformly-random ring elements —
``tests/attacks`` demonstrates that inversion and distance-correlation
attacks collapse to chance on masked transcripts.
"""
from __future__ import annotations

import hashlib
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

#: env channel for the shared mask root seed (spawned owner workers
#: inherit the parent's environment, like the chaos plan)
MASK_ENV = "REPRO_MASK_SEED"

#: fixed-point scale: 2^-16 resolution, values clipped to +-256 — the
#: f32-exact integer range (|q| <= 2^24), with int32 headroom for sums
#: across up to ~2^7 owners
SCALE_BITS = 16
SCALE = float(2 ** SCALE_BITS)
QCLIP = float(2 ** 24)

#: ring element width on the wire (uint32) — same 4 bytes/element as
#: the f32 activations it replaces: masking costs zero forward bytes
RING_BYTES = 4


def mask_root_from_env(default: int) -> int:
    """The session-wide mask root: the env channel's value when set
    (a deployment would put the pairwise-agreed secret here), else
    ``default`` (the session derives it from its init seed)."""
    v = os.environ.get(MASK_ENV, "")
    return int(v) if v else int(default)


def make_quant_program():
    """The jitted fixed-point lift ``f32 (B, k) -> int32``: round to
    2^-16 resolution, clipped to the f32-exact band.  One compiled
    program serves the owners AND the joint oracle — bit-identity of
    masked split execution starts here."""
    import jax
    import jax.numpy as jnp

    def quant(x):
        q = jnp.round(x.astype(jnp.float32) * SCALE)
        return jnp.clip(q, -QCLIP, QCLIP).astype(jnp.int32)

    return jax.jit(quant)


def dequantize(zsum):
    """In-program inverse lift: int32 ring sum -> f32 trunk input.
    ``2^-SCALE_BITS`` is a power of two, so the scaling is exact
    wherever the int fits f32."""
    import jax.numpy as jnp
    return zsum.astype(jnp.float32) * (1.0 / SCALE)


def _pair_key(root: int, lo: int, hi: int, tag: str) -> int:
    h = hashlib.sha256(f"{root}|{lo}|{hi}|{tag}".encode()).digest()
    return int.from_bytes(h[:16], "little")


def pairwise_mask(root: int, owner: int, n_owners: int, tag: str,
                  shape) -> np.ndarray:
    """Owner ``owner``'s uint32 mask for message ``tag``: the sum over
    the pairwise streams it shares with every peer, + for the lower
    index and - for the higher, so ``sum_p pairwise_mask(p) == 0`` mod
    2^32 element-wise.  Pure function of ``(root, pair, tag)`` —
    deterministic across processes and replay."""
    m = np.zeros(shape, np.uint32)
    for q in range(n_owners):
        if q == owner:
            continue
        lo, hi = (owner, q) if owner < q else (q, owner)
        rng = np.random.Generator(
            np.random.Philox(key=_pair_key(root, lo, hi, tag)))
        r = rng.integers(0, 2 ** 32, size=shape,
                         dtype=np.uint64).astype(np.uint32)
        m = m + r if owner == lo else m - r
    return m


class MaskedAggregator:
    """Owner-side secure-aggregation encoder: quantize the cut chunk,
    add this owner's pairwise-cancelling ring mask, ship uint32.

    ``generation`` scopes the *warmup* tags: a respawned worker
    (generation n+1) re-warms solo against the scientist — its masked
    warmup cuts are never unmasked, but the tag keeps the stream
    distinct from the generation it replaced.  Steady-state tags are
    the global chunk seq, generation-agnostic, so survivors (still
    generation 0) and the respawn derive identical masks for replayed
    steps and cancellation always holds."""

    def __init__(self, root: int, owner_index: int, n_owners: int,
                 quant_program, *, generation: int = 0):
        if n_owners < 2:
            raise ValueError(
                "masked_sum needs >= 2 owners: a single owner's masked "
                "payload would be its bare quantized activation")
        self.root = int(root)
        self.owner_index = int(owner_index)
        self.n_owners = int(n_owners)
        self.generation = int(generation)
        self._quant = quant_program

    def warmup_tag(self, m: int) -> str:
        return f"w{m}g{self.generation}"

    @staticmethod
    def step_tag(seq: int) -> str:
        return f"s{seq}"

    def encode(self, cut, tag: str) -> Dict[str, np.ndarray]:
        q = np.asarray(self._quant(cut))
        mask = pairwise_mask(self.root, self.owner_index, self.n_owners,
                             tag, q.shape)
        # uint32 arithmetic wraps mod 2^32 — the ring addition
        return {"mq": q.view(np.uint32) + mask}


def fold_quantized(qs: Sequence[np.ndarray]) -> np.ndarray:
    """Ring-sum UNMASKED int32 quantized cuts (the joint oracle's
    combine): mod-2^32 addition in owner order, viewed back as int32.
    Integer addition is associative, so this equals the masked wire
    fold bitwise once the masks cancel."""
    acc: Optional[np.ndarray] = None
    for q in qs:
        u = np.asarray(q).view(np.uint32)
        acc = u.astype(np.uint32, copy=True) if acc is None else acc + u
    assert acc is not None, "fold_quantized needs >= 1 owner"
    return acc.view(np.int32)


def reconstruct(payloads: List[Dict[str, np.ndarray]]) -> np.ndarray:
    """Scientist-side combine: fold every owner's masked uint32 payload
    mod 2^32.  The pairwise masks sum to zero in the ring, so the
    result IS the unmasked integer sum — without any per-owner
    activation ever being recoverable from the frames."""
    acc: Optional[np.ndarray] = None
    for pl in payloads:
        mq = np.asarray(pl["mq"])
        if mq.dtype != np.uint32:
            mq = mq.view(np.uint32)
        acc = mq.astype(np.uint32, copy=True) if acc is None else acc + mq
    assert acc is not None, "reconstruct needs >= 1 owner payload"
    return acc.view(np.int32)
