"""Diffie–Hellman Private Set Intersection, streamed and parallel.

Both parties hash into the subgroup QR_p of quadratic residues of a
safe-prime MODP group (p = 2q + 1, RFC 3526 §3 for the 2048-bit group)
via H(x) = sha256^*(x)^2 mod p.  The client (the data scientist) holds
X and secret α; a server (a data owner) holds Y and secret β.  Two
protocol variants share the same first two legs:

  * client -> server:  A_i = H(x_i)^α                (blinded, chunked)
  * server -> client:  B_i = A_i^β = H(x_i)^{αβ}     (double-blinded,
                       ordered, chunked)

``mode="noinv"`` (default) — classic ECDH-PSI, compared in the
*double-blinded domain*: the server also streams its own blinded set
{ H(y_j)^β } (deduplicated and secret-shuffled, so Y's row order and
multiplicities stay private), the client lifts it with its short α to
T_j = H(y_j)^{αβ} and matches { B_i } against { T_j } exactly
(vectorized 64-bit prefilter + full-width confirm).  No modular inverse
exists anywhere, so **every leg of every round is a short
exponentiation**, and there are no false positives.  Download cost: the
server's set crosses uncompressed (nb bytes/element).

``mode="bloom"`` — Angelou et al. 2020 (the PSI library PyVertical
ships): the server's set crosses as a
:class:`~repro.core.bloom.ShardedBloom` over { H(y_j)^β } (~12x
compressed, false positives bounded by ``fp_rate``), and the client
recovers H(x_i)^β = B_i^{α^{-1} mod q} to probe it.  The inverse of a
short exponent is full-width, so exactly one client leg per session
must pay full width — this engine puts it on the **blind** leg (sample
short γ, blind with α = γ^{-1} mod q): the blinded set is memoized and
reused verbatim against every owner, so the full-width leg is paid once
per session and the per-owner hot loop stays short.

Either way only the client learns the intersection; the server learns
only |X|.

Scaling engineering — the per-item cost is one modexp per protocol leg,
so the engine is built around the batch structure
(:mod:`repro.core.modexp` supplies the gmpy2-or-pure-Python backend, the
packed big-int buffers, and the worker pool):

  * **Streaming chunked rounds** — ``psi_round`` pipelines
    blind -> exchange -> match in ``chunk_size`` chunks with bounded
    lookahead (the transport layer's microbatch idiom): a million-ID
    round never materializes one giant batch of boxed ints.  At-rest
    data is packed bytes (``nb`` bytes/element); big-int objects exist
    only inside the in-flight chunks.
  * **Worker-pool modexp** — every chunk kernel (hash+blind fused,
    double-blind, lift/unblind) can run on ``ModexpPool`` workers while
    the parent streams Bloom adds / membership matches.
    ``parallelism=0`` runs the identical kernels in-process: the
    parallel engine is bit-identical to the serial path by construction
    (property-tested).
  * **Short exponents per group** — ``SHORT_BITS`` (RFC 7919 §5.2
    2x-security-level rule; a modexp costs one squaring per exponent
    *bit*).
  * **Sharded Bloom intersection** (bloom mode) — per-shard frames
    bound message sizes, shards OR-merge for parallel builds, and
    membership probes are vectorized per chunk.
"""
from __future__ import annotations

import hashlib
import secrets
from typing import (Callable, Dict, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.bloom import ShardedBloom
from repro.core.modexp import (ModexpPool, hash_to_group as _hash_to_group,
                               hashpow_chunk, pow_chunk)

# RFC 3526, 2048-bit MODP group: p is a safe prime (p = 2q + 1).
P_HEX = (
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74"
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437"
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED"
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05"
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB"
    "9ED529077096966D670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B"
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9DE2BCBF695581718"
    "3995497CEA956AE515D2261898FA051015728E5A8AACAA68FFFFFFFFFFFFFFFF"
)
PRIME = int(P_HEX, 16)
Q = (PRIME - 1) // 2

# 512-bit safe prime (locally generated, Miller-Rabin verified).  NOT for
# production use — selectable via group="modp512" to keep CI/test/demo
# wall-time sane on hosts where a 2048-bit modexp costs ~30 ms.
P512 = int(
    "fb8def3a572e8dc20670083d0a2a21dd4499d394148beb09ecd2f93a018018d0"
    "af9a57a96a9172dc5baba339cccd0f6fccb7fdc53fb67c330afe160326d4cd17", 16)

GROUPS = {
    "modp2048": (PRIME, (PRIME - 1) // 2, 256),
    "modp512": (P512, (P512 - 1) // 2, 64),
}

# Short-exponent width (bits), per group.  The rule is twice the group's
# classical security level (RFC 7919 §5.2): modp2048 offers ~112 bits, so
# 256-bit exponents leave margin; the 512-bit toy group offers at most
# ~60 bits against NFS, so 128-bit exponents already exceed the 2x rule —
# wider ones would just burn squarings a demo group can't justify.
SHORT_EXP_BITS = 256
SHORT_BITS = {"modp2048": 256, "modp512": 128}

#: sentinel — "the group's own short-exponent width"
AUTO = "auto"


def _resolve_exp_bits(exp_bits, group: str) -> Optional[int]:
    return SHORT_BITS[group] if exp_bits == AUTO else exp_bits

#: streaming granularity — elements per pipeline chunk
DEFAULT_CHUNK = 4096

#: protocol variants (see module docstring):
#:   "noinv" — classic ECDH-PSI: compare in the double-blinded domain.
#:             Every leg is a short exponentiation (no modular inverse
#:             anywhere), intersections are exact (no Bloom false
#:             positives), but the server's response carries its own
#:             blinded set uncompressed (~2x the download of "bloom").
#:   "bloom" — Angelou et al. (the library PyVertical ships): the server
#:             set crosses the wire as a sharded Bloom filter (~12x
#:             compressed), which forces the client to unblind via
#:             α^{-1} — one full-width-exponent leg per session.
DEFAULT_MODE = "noinv"

#: all protocol variants.  "hidden" is the membership-hiding variant:
#: noinv machinery, but the *owner* performs the match (the double-blind
#: leg never returns to the client) and replies with a padded keep-set
#: of client row positions — the scientist learns an aligned row order,
#: never which raw IDs matched (see ``_round_hidden``).
MODES = ("noinv", "bloom", "hidden")

#: membership-hiding pad quantum: the keep-set is padded with
#: deterministic decoy positions up to a multiple of this, so the frame
#: length quantizes away ±1 membership differences (invariant 12)
HIDDEN_PAD = 32

#: Knuth multiplicative hash constant — maps a decoy keep-position to a
#: deterministic pseudo-row so decoy map entries are byte-uniform with
#: member entries (and bit-stable across backends/sessions)
_DECOY_MULT = 2654435761


def blind_tag(blinded_packed: bytes) -> bytes:
    """16-byte content tag of a packed blinded set.  Derived from
    already-blinded group elements, so it reveals nothing the blob
    itself doesn't; equal blobs get equal tags, which is what lets a
    peer skip a byte-identical retransmission (and what addresses the
    delta protocol's base-state check)."""
    return hashlib.sha256(blinded_packed).digest()[:16]


def decoy_row(position: int, n_rows: int) -> int:
    """The deterministic pseudo-row a hidden-mode decoy position maps
    to.  Pure data-determined arithmetic: bit-stable across backends."""
    return (position * _DECOY_MULT) % max(1, n_rows)


def hash_to_group(item: bytes, prime: int = PRIME, nbytes: int = 256) -> int:
    """H(x) = (sha256-derived integer mod p)^2 — lands in QR_p (order q).
    (Implementation lives in :mod:`repro.core.modexp` so fork workers can
    fuse hashing with the blind exponentiation.)"""
    return _hash_to_group(item, prime, nbytes)


def _sample_exponent(q: int, exp_bits: Optional[int] = SHORT_EXP_BITS) -> int:
    """A secret exponent in [2, q).  ``exp_bits`` bounds its width for
    short-exponent DH (None = full-width uniform)."""
    if exp_bits is None or exp_bits >= q.bit_length() - 1:
        return secrets.randbelow(q - 2) + 2
    # top bit forced so the exponent has exactly exp_bits bits
    return secrets.randbits(exp_bits - 1) | (1 << (exp_bits - 1))


def _enc(x: int, nbytes: int = 256) -> bytes:
    return x.to_bytes(nbytes, "big")


def _chunk_slices(total: int, size: int) -> Iterator[Tuple[int, int]]:
    for i in range(0, total, size):
        yield i, min(i + size, total)


class PSIClient:
    """The data scientist's side.  One client object per session: its
    blinded set is computed once (packed) and reused across every owner
    round (the secret is per-session, so re-blinding per owner would buy
    nothing but modexps).

    Exponent orientation depends on the protocol mode:

      * ``noinv`` — α itself is short; no inverse is ever needed (the
        comparison happens in the double-blinded domain), so every leg
        of every round is a short exponentiation.
      * ``bloom`` — the short secret is the **unblind** exponent γ; the
        blind exponent is α = γ^{-1} mod q (full-width, paid once per
        session inside the memoized ``blind_packed``).  Every per-owner
        leg the client runs afterwards is short."""

    def __init__(self, items: Sequence[str], group: str = "modp2048",
                 exp_bits=AUTO, mode: str = DEFAULT_MODE):
        if mode not in MODES:
            raise ValueError(f"unknown PSI mode {mode!r}")
        self.items = items
        self.group = group
        self.mode = mode
        self.exp_bits = exp_bits = _resolve_exp_bits(exp_bits, group)
        self._p, self._q, self._nb = GROUPS[group]
        if mode == "bloom":
            # γ short; α = γ^{-1}: the full-width leg lands on the
            # memoized blind, the per-round unblind stays short
            self._unblind_exp = _sample_exponent(self._q, exp_bits)
            self._blind_exp = pow(self._unblind_exp, -1, self._q)
        else:
            self._blind_exp = _sample_exponent(self._q, exp_bits)
            self._unblind_exp = None            # lazily inverted if the
            #                                     bloom-compat surface asks
        self._blinded_packed: Optional[bytes] = None
        self._blinded: Optional[List[int]] = None
        #: cumulative modular exponentiations submitted by this client
        #: (one per set element per leg) — the delta gate's cost metric
        self.ops = 0
        # delta-resolution state: ``_base_*`` snapshot the last state a
        # peer may hold cached; ``_delta`` is the base -> current diff
        self._delta: Optional[dict] = None
        self._base_items: Optional[List[str]] = None
        self._base_packed: Optional[bytes] = None
        #: per-peer cached round artifacts (written only on round
        #: success by the wire driver) — keyed by owner name
        self.round_cache: Dict[str, dict] = {}

    # -- blinding ----------------------------------------------------------
    def blind_packed(self, pool: Optional[ModexpPool] = None,
                     chunk_size: int = DEFAULT_CHUNK) -> bytes:
        """The packed blinded set A_i = H(x_i)^α — computed once per
        session (hash fused with the exponentiation in the chunk kernel),
        then reused against every owner."""
        if self._blinded_packed is None:
            pool = pool or ModexpPool(0)
            items, p, nb, a = self.items, self._p, self._nb, self._blind_exp
            self.ops += len(items)
            parts = pool.imap(
                hashpow_chunk,
                ((list(items[lo:hi]), a, p, nb)
                 for lo, hi in _chunk_slices(len(items), chunk_size)))
            self._blinded_packed = b"".join(parts)
        return self._blinded_packed

    def blind(self) -> List[int]:
        """Compat surface: the blinded set as ints (memoized)."""
        if self._blinded is None:
            from repro.core.modexp import unpack_ints
            self._blinded = unpack_ints(self.blind_packed(), self._nb)
        return self._blinded

    def reset_session(self) -> None:
        """Drop the memoized blinded set (keeping the secrets) — the
        'fresh round, same exponents' reset benchmarks and bit-identity
        tests rely on."""
        self._blinded_packed = None
        self._blinded = None
        self._delta = None
        self._base_items = None
        self._base_packed = None
        self.round_cache.clear()

    # -- delta resolution --------------------------------------------------
    def update_items(self, new_items: Sequence[str],
                     pool: Optional[ModexpPool] = None,
                     chunk_size: int = DEFAULT_CHUNK) -> None:
        """Replace the client's item set with ``new_items``, splicing the
        memoized blinded set in O(Δ) modexp (only genuinely *new* items
        are hash+blinded) and recording a base -> current diff the wire
        driver ships as a ``psi_delta_chunk`` (removal tombstones +
        appended additions) instead of a full re-upload.

        Multiset semantics; the retained items keep their base positional
        order (additions append), so the recorded removal positions index
        into the base upload a peer holds cached.  The base snapshot is
        rebased lazily: consecutive updates before the next round compose
        into one diff against the same base.  When nothing was blinded
        yet, when no items survive (100% churn), or when the diff would
        outweigh a full upload, the delta is dropped and the next round
        falls back to the full protocol."""
        from collections import Counter
        new = list(new_items)
        nb = self._nb
        if list(self.items) == new:
            return
        if self._blinded_packed is None:
            self.items = new
            self._delta = None
            return
        if self._delta is None:
            # rebase: current state is what peers may have cached
            self._base_items = list(self.items)
            self._base_packed = self._blinded_packed
        base_items, base_packed = self._base_items, self._base_packed

        # multiset diff base -> new: keep the first new-count occurrences
        # of every base item (positional order), append the surplus
        new_counts = Counter(new)
        quota = dict(new_counts)
        retained: List[int] = []
        removed: List[int] = []
        for i, it in enumerate(base_items):
            if quota.get(it, 0) > 0:
                quota[it] -= 1
                retained.append(i)
            else:
                removed.append(i)
        surplus = {k: v for k, v in quota.items() if v > 0}
        added: List[str] = []
        for it in new:
            if surplus.get(it, 0) > 0:
                surplus[it] -= 1
                added.append(it)

        added_packed = b""
        if added:
            pool = pool or ModexpPool(0)
            p, a = self._p, self._blind_exp
            self.ops += len(added)
            added_packed = b"".join(pool.imap(
                hashpow_chunk,
                ((added[lo:hi], a, p, nb)
                 for lo, hi in _chunk_slices(len(added), chunk_size))))

        import numpy as np
        rows = np.frombuffer(base_packed, np.uint8).reshape(-1, nb)
        kept = rows[retained].tobytes() if retained else b""
        self._blinded_packed = kept + added_packed
        self._blinded = None
        self.items = [base_items[i] for i in retained] + added

        delta_bytes = len(added_packed) + 8 * len(removed)
        worthwhile = (retained
                      and delta_bytes < len(self._blinded_packed)
                      and (removed or added))
        if not (removed or added):
            self._delta = None          # empty delta: tags already equal
        elif worthwhile:
            self._delta = {
                "base_tag": blind_tag(base_packed),
                "tag": blind_tag(self._blinded_packed),
                "retained": retained,
                "removed": removed,
                "added_packed": added_packed,
            }
        else:                           # 100% churn / diff >= full upload
            self._delta = None

    def rebase_delta(self) -> None:
        """Forget the delta base (typically after every peer has seen
        the current upload): the next ``update_items`` diffs against the
        state as of this call, keeping composed diffs bounded."""
        self._delta = None
        self._base_items = None
        self._base_packed = None

    # -- unblind + membership (bloom-mode legs) ----------------------------
    @property
    def unblind_exp(self) -> int:
        """α^{-1} mod q — short by construction in ``bloom`` mode,
        lazily inverted (full-width) when a ``noinv`` client is driven
        through the bloom-compat surface."""
        if self._unblind_exp is None:
            self._unblind_exp = pow(self._blind_exp, -1, self._q)
        return self._unblind_exp

    def _match_packed(self, unblinded: bytes, bloom, lo: int) -> List[str]:
        nb = self._nb
        els = [unblinded[i:i + nb] for i in range(0, len(unblinded), nb)]
        hits = bloom.query_batch(els)
        return [self.items[lo + j] for j in range(len(els)) if hits[j]]

    # -- per-chunk leg hooks (shared with the wire engine) -----------------
    #
    # ``federation/psi_transport.py`` runs the protocol one transport
    # Message per chunk.  Its client legs submit the same ``pow_chunk``
    # task shape the in-process rounds below do (exp/prime/width from
    # this object), and finish through these match methods — the two
    # engines share their per-chunk compute, so bit-identity is by
    # construction.

    def match_bloom_chunk(self, unblinded: bytes, bloom,
                          base: int) -> List[str]:
        """bloom leg: probe one unblinded chunk (client items starting at
        ``base``) against the server's ShardedBloom."""
        return self._match_packed(unblinded, bloom, base)

    def match_double_blinded(self, d_blob: bytes,
                             t_blob: bytes) -> List[str]:
        """noinv finish: exact membership of the double-blinded client
        set { D_i } in the lifted server set { T_j } — client order,
        duplicates preserved, no false positives."""
        import numpy as np
        hits = _exact_membership(d_blob, t_blob, self._nb)
        return [self.items[i] for i in np.nonzero(hits)[0]]

    def intersect(self, double_blinded: Sequence[int],
                  server_bloom) -> List[str]:
        """Compat surface: recover the intersection from an un-chunked
        bloom-variant server response."""
        from repro.core.modexp import pack_ints
        packed = pack_ints(list(double_blinded), self._nb)
        unb = pow_chunk((packed, self.unblind_exp, self._p, self._nb))
        return self._match_packed(unb, server_bloom, 0)


class PSIServer:
    """A data owner's side.  β is short; both server legs (double-blind,
    Bloom build) are short exponentiations.  The Bloom over the β-blinded
    own set is built once per session (sharded, streamed) and reused
    across rounds with the same client."""

    def __init__(self, items: Sequence[str], fp_rate: float = 1e-9,
                 group: str = "modp2048", exp_bits=AUTO,
                 beta: Optional[int] = None):
        self.items = items
        self.fp_rate = fp_rate
        self.group = group
        self._p, self._q, self._nb = GROUPS[group]
        # ``beta`` re-injects an existing session secret — a respawned
        # owner worker must reproduce byte-identical response legs, or
        # every client-side content-tag cache would miss
        self._beta = (beta if beta is not None else
                      _sample_exponent(self._q,
                                       _resolve_exp_bits(exp_bits, group)))
        self._bloom: Optional[ShardedBloom] = None
        self._own_packed: Optional[bytes] = None
        #: shuffled-position -> own row index, retained alongside
        #: ``_own_packed`` (hidden mode matches on the owner's side and
        #: must map a matched shuffled element back to its data row)
        self._own_rows: Optional[List[int]] = None
        # per-item blinded elements (H(y)^β), kept so owner-side churn
        # re-blinds only genuinely new items (O(Δ) modexp)
        self._own_elems: Dict[str, bytes] = {}
        #: cumulative modular exponentiations performed by this server
        self.ops = 0

    def build_bloom(self, pool: Optional[ModexpPool] = None,
                    chunk_size: int = DEFAULT_CHUNK) -> ShardedBloom:
        """ShardedBloom{ H(y_j)^β } — worker chunks hash+exponentiate,
        the parent streams vectorized shard adds."""
        if self._bloom is None:
            pool = pool or ModexpPool(0)
            items, p, nb, b = self.items, self._p, self._nb, self._beta
            self.ops += len(items)
            bf = ShardedBloom.for_capacity(len(items), self.fp_rate)
            for packed in pool.imap(
                    hashpow_chunk,
                    ((list(items[lo:hi]), b, p, nb)
                     for lo, hi in _chunk_slices(len(items), chunk_size))):
                bf.add_batch([packed[i:i + nb]
                              for i in range(0, len(packed), nb)])
            self._bloom = bf
        return self._bloom

    def reset_session(self) -> None:
        """Drop the memoized response-side state (keeping β) — see
        :meth:`PSIClient.reset_session`."""
        self._bloom = None
        self._own_packed = None
        self._own_rows = None
        self._own_elems = {}

    def update_items(self, new_items: Sequence[str]) -> None:
        """Replace the owner's item set.  The per-item blinded elements
        are kept, so re-deriving the response leg costs O(Δ) modexp
        (only new items are blinded); the packed own set, its shuffle,
        and the bloom are rebuilt lazily — their content tags change,
        which is what invalidates any peer-side response-leg cache."""
        new = list(new_items)
        if list(self.items) == new:
            return
        self.items = new
        self._bloom = None
        self._own_packed = None
        self._own_rows = None
        if len(self._own_elems) > 2 * max(1, len(new)):
            keep = set(new)
            self._own_elems = {k: v for k, v in self._own_elems.items()
                               if k in keep}

    def own_blinded_packed(self, pool: Optional[ModexpPool] = None,
                           chunk_size: int = DEFAULT_CHUNK) -> bytes:
        """The packed β-blinded own set { H(y_j)^β } — the uncompressed
        server response of the ``noinv`` variant.  Memoized (at-rest
        packed bytes) and reused across rounds with the same client.

        Deduplicated and secret-shuffled before it ever leaves: row
        order and duplicate multiplicity in Y are NOT part of what the
        protocol reveals (standard ECDH-PSI practice — a client could
        otherwise locate each matched record's position in the owner's
        dataset).  The intersection is order-invariant, so the shuffle
        never affects results."""
        if self._own_packed is None:
            import numpy as np
            pool = pool or ModexpPool(0)
            items = list(dict.fromkeys(self.items))
            p, nb, b = self._p, self._nb, self._beta
            missing = [it for it in items if it not in self._own_elems]
            if missing:
                self.ops += len(missing)
                packed = b"".join(pool.imap(
                    hashpow_chunk,
                    ((missing[lo:hi], b, p, nb)
                     for lo, hi in _chunk_slices(len(missing),
                                                 chunk_size))))
                for k, it in enumerate(missing):
                    self._own_elems[it] = packed[k * nb:(k + 1) * nb]
            first_row: Dict[str, int] = {}
            for r, it in enumerate(self.items):
                first_row.setdefault(it, r)
            # secret shuffle, derived from β + the item set: unknowable
            # without the secret (the client still can't locate rows),
            # but *stable* across memoization drops and worker respawns
            # — the response leg's content tag must not change unless
            # the data does
            h = hashlib.sha256(b"psi-own-shuffle")
            h.update(_enc(self._beta, self._nb))
            for it in items:
                h.update(it.encode() if isinstance(it, str) else it)
            rng = np.random.default_rng(int.from_bytes(h.digest(), "big"))
            perm = rng.permutation(len(items))
            self._own_packed = b"".join(self._own_elems[items[j]]
                                        for j in perm)
            self._own_rows = [first_row[items[j]] for j in perm]
        return self._own_packed

    def server_leg_tag(self, mode: str,
                       pool: Optional[ModexpPool] = None,
                       chunk_size: int = DEFAULT_CHUNK) -> bytes:
        """Content tag of the response leg a client of ``mode`` would
        receive (packed own set, or the bloom's shard frames) — what the
        wire protocol's response-leg cache is keyed by."""
        if mode == "bloom":
            return self.build_bloom(pool, chunk_size).content_tag()
        return blind_tag(self.own_blinded_packed(pool, chunk_size))

    def hidden_match(self, d_blob: bytes, t_blob: bytes,
                     pad: int = HIDDEN_PAD) -> Tuple[List[int], List[int]]:
        """Owner-side membership-hiding finish: match the double-blinded
        client set { D_i } (client order) against the lifted own set
        { T_j } (shuffled order), then hide *which* kept positions
        matched.  Returns ``(keep, rows)``:

          * ``keep`` — sorted client positions, the true members padded
            with decoys (the smallest unmatched positions) up to a
            multiple of ``pad``, so a captured frame's length quantizes
            away ±1 membership differences;
          * ``rows`` — for each kept position, the owner data row to
            align (true row for members via the retained shuffle
            permutation; a deterministic pseudo-row for decoys).  Member
            and decoy entries are byte-uniform int64s.

        Everything is data-determined (set membership, smallest-position
        decoys, arithmetic pseudo-rows), so the result is bit-stable
        across backends and repeat rounds."""
        import numpy as np
        nb = self._nb
        assert self._own_rows is not None, \
            "own_blinded_packed must run before hidden_match"
        hits = _exact_membership(d_blob, t_blob, nb)
        t_pos = {t_blob[j * nb:(j + 1) * nb]: j
                 for j in range(len(t_blob) // nb)}
        row_of: Dict[int, int] = {}
        for i in np.nonzero(hits)[0]:
            i = int(i)
            row_of[i] = self._own_rows[t_pos[d_blob[i * nb:(i + 1) * nb]]]
        n_cli = len(d_blob) // nb
        members = sorted(row_of)
        target = min(n_cli, -(-max(len(members), 1) // pad) * pad)
        keep = list(members)
        member_set = set(members)
        for i in range(n_cli):
            if len(keep) >= target:
                break
            if i not in member_set:
                keep.append(i)
        keep.sort()
        n_rows = len(self.items)
        rows = [row_of.get(i, decoy_row(i, n_rows)) for i in keep]
        return keep, rows

    def respond_chunk(self, packed: bytes) -> bytes:
        """One packed blinded chunk -> its double-blinded response,
        B_i = A_i^β (order preserved) — the per-chunk server kernel the
        wire engine (``federation/psi_transport``) calls per Message."""
        self.ops += len(packed) // self._nb
        return pow_chunk((packed, self._beta, self._p, self._nb))

    def respond_chunks(self, blinded_packed: bytes,
                       pool: Optional[ModexpPool] = None,
                       chunk_size: int = DEFAULT_CHUNK
                       ) -> Iterator[Tuple[int, bytes]]:
        """Stream (base_index, double-blinded packed chunk) — B_i = A_i^β
        in client order, chunked."""
        pool = pool or ModexpPool(0)
        p, nb, b = self._p, self._nb, self._beta
        self.ops += len(blinded_packed) // nb
        nbytes = chunk_size * nb
        offsets = range(0, len(blinded_packed), nbytes)
        for off, packed in zip(
                offsets,
                pool.imap(pow_chunk,
                          ((blinded_packed[o:o + nbytes], b, p, nb)
                           for o in offsets))):
            yield off // nb, packed

    def respond(self, blinded: Sequence[int]):
        """Compat surface: (double-blinded client set [ordered], bloom)."""
        from repro.core.modexp import pack_ints, unpack_ints
        packed = pack_ints(list(blinded), self._nb)
        double = unpack_ints(
            pow_chunk((packed, self._beta, self._p, self._nb)), self._nb)
        return double, self.build_bloom()


# ---------------------------------------------------------------------------
# The streaming round
# ---------------------------------------------------------------------------


def _keys64(blob: bytes, nb: int) -> "np.ndarray":
    """64-bit prefilter keys: the leading 8 bytes of each packed group
    element (≈ uniform — elements are random mod a ~2^(8·nb) prime)."""
    import numpy as np
    a = np.frombuffer(blob, np.uint8).reshape(-1, nb)[:, :8]
    # native-endian uint64 — np.isin rejects explicit byte-order dtypes
    return a.copy().view(">u8").ravel().astype(np.uint64)


def _exact_membership(d_blob: bytes, t_blob: bytes, nb: int):
    """Per-element: is d_i ∈ {t_j}?  Vectorized 64-bit prefilter, then
    an exact full-width confirm on the (intersection-sized) candidate
    set — no false positives, duplicates preserved."""
    import numpy as np
    dk, tk = _keys64(d_blob, nb), _keys64(t_blob, nb)
    cand = np.isin(dk, tk)
    if not cand.any():
        return cand
    t_sel = np.isin(tk, dk[cand])
    t_set = {t_blob[j * nb:(j + 1) * nb] for j in np.nonzero(t_sel)[0]}
    out = np.zeros(len(dk), bool)
    for i in np.nonzero(cand)[0]:
        out[i] = d_blob[i * nb:(i + 1) * nb] in t_set
    return out


def _common_stats(client, server, pool, chunk_size) -> dict:
    return {
        "chunk_size": chunk_size,
        "n_chunks": max(1, -(-len(client.items) // chunk_size)),
        "peak_inflight_elements": min(len(client.items),
                                      chunk_size * pool.inflight),
        "parallelism": pool.parallelism if pool.is_parallel else 0,
        "uncompressed_server_set_bytes": client._nb * len(server.items),
    }


def _round_bloom(client, server, pool, chunk_size, emit):
    """Angelou et al.: compressed server response, full-width unblind."""
    nb = client._nb
    blind_cached = client._blinded_packed is not None
    bloom_cached = server._bloom is not None

    # server set -> sharded bloom (β leg), streamed
    bloom = server.build_bloom(pool, chunk_size)
    for frame in bloom.shard_frames():
        emit("psi_bloom_shard", len(frame))

    # client set -> blinded upload (α leg), memoized across owners
    blinded = client.blind_packed(pool, chunk_size)
    for lo, hi in _chunk_slices(len(client.items), chunk_size):
        emit("psi_blind_chunk", (hi - lo) * nb)

    # double-blind (β) -> unblind (γ) -> shard probes, pipelined
    inter: List[str] = []
    client.ops += len(blinded) // nb
    unblind_exp, p = client.unblind_exp, client._p
    double_chunks = server.respond_chunks(blinded, pool, chunk_size)
    offsets: List[int] = []

    def _tapped():
        for lo, packed in double_chunks:
            emit("psi_double_chunk", len(packed))
            offsets.append(lo)
            yield (packed, unblind_exp, p, nb)

    for unb in pool.imap(pow_chunk, _tapped()):
        inter.extend(client._match_packed(unb, bloom, offsets.pop(0)))

    stats = {
        "mode": "bloom",
        "client_upload_bytes": len(blinded),
        "server_response_bytes": len(blinded) + bloom.nbytes(),
        "bloom_bytes": bloom.nbytes(),
        "bloom_shards": bloom.n_shards,
        "blind_cached": blind_cached,
        "server_cached": bloom_cached,
        **_common_stats(client, server, pool, chunk_size),
    }
    return inter, stats


def _round_noinv(client, server, pool, chunk_size, emit):
    """Classic ECDH-PSI: compare in the double-blinded domain — every
    leg short, intersections exact, server set uncompressed."""
    nb, p = client._nb, client._p
    blind_cached = client._blinded_packed is not None
    own_cached = server._own_packed is not None

    # client set -> blinded upload (short α leg), memoized across owners
    blinded = client.blind_packed(pool, chunk_size)
    for lo, hi in _chunk_slices(len(client.items), chunk_size):
        emit("psi_blind_chunk", (hi - lo) * nb)

    # server's β-blinded own set (memoized) streams to the client, which
    # lifts it into the double-blinded domain: T_j = (H(y_j)^β)^α
    own = server.own_blinded_packed(pool, chunk_size)
    cb = chunk_size * nb
    client.ops += len(own) // nb

    def _own_tasks():
        for o in range(0, len(own), cb):
            emit("psi_server_set_chunk", len(own[o:o + cb]))
            yield (own[o:o + cb], client._blind_exp, p, nb)

    t_blob = b"".join(pool.imap(pow_chunk, _own_tasks()))

    # double-blind response D_i = A_i^β, streamed in client order
    d_parts: List[bytes] = []
    for _lo, packed in server.respond_chunks(blinded, pool, chunk_size):
        emit("psi_double_chunk", len(packed))
        d_parts.append(packed)
    d_blob = b"".join(d_parts)

    inter = client.match_double_blinded(d_blob, t_blob)
    stats = {
        "mode": "noinv",
        "client_upload_bytes": len(blinded),
        "server_response_bytes": len(d_blob) + len(own),
        "server_set_bytes": len(own),
        "blind_cached": blind_cached,
        "server_cached": own_cached,
        **_common_stats(client, server, pool, chunk_size),
    }
    return inter, stats


def _round_hidden(client, server, pool, chunk_size, emit):
    """Membership-hiding variant: the first three legs are noinv's, but
    the lifted server set returns to the *owner* (``psi_lift_chunk``)
    and the double-blind products never leave it — the owner matches,
    pads the keep-set with deterministic decoys (``hidden_match``), and
    replies only with padded (position, row) pairs.  The client learns
    an aligned row order; neither a wire observer nor the scientist
    learns which positions are true members."""
    nb, p = client._nb, client._p
    blind_cached = client._blinded_packed is not None
    own_cached = server._own_packed is not None

    blinded = client.blind_packed(pool, chunk_size)
    for lo, hi in _chunk_slices(len(client.items), chunk_size):
        emit("psi_blind_chunk", (hi - lo) * nb)

    own = server.own_blinded_packed(pool, chunk_size)
    cb = chunk_size * nb
    client.ops += len(own) // nb

    def _own_tasks():
        for o in range(0, len(own), cb):
            emit("psi_server_set_chunk", len(own[o:o + cb]))
            yield (own[o:o + cb], client._blind_exp, p, nb)

    t_blob = b"".join(pool.imap(pow_chunk, _own_tasks()))
    for o in range(0, len(t_blob), cb):
        emit("psi_lift_chunk", len(t_blob[o:o + cb]))

    # D_i = A_i^β stays on the owner's side (never emitted)
    d_blob = b"".join(packed for _lo, packed in
                      server.respond_chunks(blinded, pool, chunk_size))
    keep, rows = server.hidden_match(d_blob, t_blob)
    emit("psi_keep_mask", 16 * len(keep))

    stats = {
        "mode": "hidden",
        "client_upload_bytes": len(blinded) + len(t_blob),
        "server_response_bytes": len(own) + 16 * len(keep),
        "server_set_bytes": len(own),
        "hidden_rows": rows,
        "hidden_kept": len(keep),
        "blind_cached": blind_cached,
        "server_cached": own_cached,
        **_common_stats(client, server, pool, chunk_size),
    }
    return keep, stats


def psi_round(client: PSIClient, server: PSIServer, *,
              pool: Optional[ModexpPool] = None,
              chunk_size: int = DEFAULT_CHUNK,
              on_message: Optional[Callable] = None
              ) -> Tuple[List[str], dict]:
    """One full PSI round between existing party objects, streamed in
    ``chunk_size`` chunks through ``pool`` (serial when ``None``).

    The protocol variant is the client's ``mode`` (``noinv``/``bloom``,
    see ``DEFAULT_MODE``).  Stage pipeline either way (bounded lookahead
    at every arrow, so peak big-int memory is O(chunk_size · inflight)
    regardless of |X| and |Y|):

        client blind chunks  ->  server double-blind chunks
        server set chunks    ->  client lift/unblind + match chunks

    ``on_message(kind, n_bytes)`` observes every simulated wire message
    (``psi_blind_chunk`` / ``psi_double_chunk`` / ``psi_server_set_chunk``
    / ``psi_bloom_shard``) — the session uses it for transcript
    accounting.  Results are bit-identical across ``pool`` settings:
    chunk order is preserved and every kernel computes exact modular
    arithmetic.
    """
    if client.group != server.group:
        raise ValueError(f"group mismatch: client {client.group!r} "
                         f"!= server {server.group!r}")
    pool = pool or ModexpPool(0)
    emit = on_message or (lambda kind, n_bytes: None)
    if client.mode == "bloom":
        return _round_bloom(client, server, pool, chunk_size, emit)
    if client.mode == "hidden":
        return _round_hidden(client, server, pool, chunk_size, emit)
    return _round_noinv(client, server, pool, chunk_size, emit)


def psi_intersect(client_items: Sequence[str], server_items: Sequence[str],
                  fp_rate: float = 1e-9, group: str = "modp2048",
                  exp_bits=AUTO, *,
                  mode: str = DEFAULT_MODE,
                  chunk_size: int = DEFAULT_CHUNK,
                  parallelism: int = 0,
                  pool: Optional[ModexpPool] = None):
    """One full PSI round from raw item lists.  Returns
    (intersection_as_client_sees_it, stats).  ``parallelism`` > 0 forks
    that many modexp workers (ignored when an explicit ``pool`` is
    passed); the result is bit-identical to the serial engine."""
    client = PSIClient(client_items, group, exp_bits, mode)
    server = PSIServer(server_items, fp_rate, group, exp_bits)
    if pool is not None:
        return psi_round(client, server, pool=pool, chunk_size=chunk_size)
    with ModexpPool(parallelism) as own:
        return psi_round(client, server, pool=own, chunk_size=chunk_size)
