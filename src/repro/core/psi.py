"""Diffie–Hellman Private Set Intersection with Bloom-filter compression.

The protocol of Angelou et al. 2020 (the PSI library PyVertical uses),
re-implemented over the 2048-bit MODP group (RFC 3526 §3):

  * safe prime p = 2q + 1; all elements live in the subgroup QR_p of
    quadratic residues (prime order q), via H(x) = sha256^*(x)^2 mod p.
  * client (the data scientist) holds X, secret α; server (a data owner)
    holds Y, secret β.
  * client -> server:  A_i = H(x_i)^α                (blinded)
  * server -> client:  B_i = A_i^β = H(x_i)^{αβ}     (double-blinded, ordered)
                       BF  = BloomFilter{ H(y_j)^β } (compressed server set)
  * client: H(x_i)^β = B_i^{α^{-1} mod q}; x_i in the intersection iff
    H(x_i)^β ∈ BF.

Only the client learns the intersection; the server learns only |X|.
False positives are bounded by the Bloom parameters (default 1e-9 — the
asymmetric regime of the paper: small client set, large compressed server
response).

Hot-loop engineering (the per-item cost is one 2048-bit modexp per
protocol leg, so the batch structure is where the time goes):

  * **Short exponents** — α and β are sampled as 256-bit exponents
    (short-exponent Diffie–Hellman; secure under the discrete-log
    short-exponent assumption, the standard practice RFC 7919 §5.2
    codifies).  A modexp costs one squaring per exponent *bit*, so the
    blind / double-blind / Bloom legs drop ~8x in a 2048-bit group.
    The client's unblinding exponent α^{-1} mod q is full-width
    regardless — it dominates the remaining client time.
  * **Hash hoisting** — ``H(x_i)`` over a party's set is computed once
    and cached on the object, not once per round: the scientist's set is
    re-used verbatim against every owner.
  * **Blinded-set reuse** — ``blind()`` memoizes.  A client whose secret
    is per-session can upload the SAME blinded set to every owner
    (``VerticalSession.resolve`` does), amortizing the whole client leg
    across owners.  True fixed-base windowed precomputation does not
    apply here — every exponentiation has a fresh base ``H(x_i)`` — so
    shared-exponent + caching is the batching lever that actually
    exists.
"""
from __future__ import annotations

import hashlib
import secrets
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.bloom import BloomFilter

# RFC 3526, 2048-bit MODP group: p is a safe prime (p = 2q + 1).
P_HEX = (
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74"
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437"
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED"
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05"
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB"
    "9ED529077096966D670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B"
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9DE2BCBF695581718"
    "3995497CEA956AE515D2261898FA051015728E5A8AACAA68FFFFFFFFFFFFFFFF"
)
PRIME = int(P_HEX, 16)
Q = (PRIME - 1) // 2

# 512-bit safe prime (locally generated, Miller-Rabin verified).  NOT for
# production use — selectable via group="modp512" to keep CI/test/demo
# wall-time sane on hosts where a 2048-bit modexp costs ~30 ms.
P512 = int(
    "fb8def3a572e8dc20670083d0a2a21dd4499d394148beb09ecd2f93a018018d0"
    "af9a57a96a9172dc5baba339cccd0f6fccb7fdc53fb67c330afe160326d4cd17", 16)

GROUPS = {
    "modp2048": (PRIME, (PRIME - 1) // 2, 256),
    "modp512": (P512, (P512 - 1) // 2, 64),
}

# Short-exponent width (bits).  112-bit classical security needs ~224-bit
# exponents (twice the security level); 256 leaves margin.
SHORT_EXP_BITS = 256


def _sample_exponent(q: int, exp_bits: Optional[int] = SHORT_EXP_BITS) -> int:
    """A secret exponent in [2, q).  ``exp_bits`` bounds its width for
    short-exponent DH (None = full-width uniform)."""
    if exp_bits is None or exp_bits >= q.bit_length() - 1:
        return secrets.randbelow(q - 2) + 2
    # top bit forced so the exponent has exactly exp_bits bits
    return secrets.randbits(exp_bits - 1) | (1 << (exp_bits - 1))


def hash_to_group(item: bytes, prime: int = PRIME, nbytes: int = 256) -> int:
    """H(x) = (sha256-derived integer mod p)^2 — lands in QR_p (order q)."""
    h = b""
    ctr = 0
    while len(h) < nbytes + 16:  # modulus size + slack for uniformity
        h += hashlib.sha256(item + ctr.to_bytes(4, "big")).digest()
        ctr += 1
    v = int.from_bytes(h, "big") % prime
    return pow(v, 2, prime)


def _enc(x: int, nbytes: int = 256) -> bytes:
    return x.to_bytes(nbytes, "big")


@dataclass
class PSIClient:
    """The data scientist's side.  One client object per session: its
    hashed and blinded sets are computed once and reused across every
    owner round (the secret is per-session, so re-blinding per owner
    would buy nothing but modexps)."""

    items: Sequence[str]
    group: str = "modp2048"
    exp_bits: Optional[int] = SHORT_EXP_BITS
    _alpha: int = field(default=0, repr=False)

    def __post_init__(self):
        self._p, self._q, self._nb = GROUPS[self.group]
        self._alpha = _sample_exponent(self._q, self.exp_bits)
        # full-width unblinding exponent, computed once per session
        self._alpha_inv = pow(self._alpha, -1, self._q)
        self._hashed: Optional[List[int]] = None
        self._blinded: Optional[List[int]] = None

    def blind(self) -> List[int]:
        if self._blinded is None:
            if self._hashed is None:
                self._hashed = [
                    hash_to_group(x.encode(), self._p, self._nb)
                    for x in self.items]
            a = self._alpha
            self._blinded = [pow(h, a, self._p) for h in self._hashed]
        return self._blinded

    def intersect(self, double_blinded: Sequence[int],
                  server_bloom: BloomFilter) -> List[str]:
        """Recover the intersection from the server's response."""
        a_inv, p, nb = self._alpha_inv, self._p, self._nb
        out = []
        for x, db in zip(self.items, double_blinded):
            unblinded = pow(db, a_inv, p)   # = H(x)^beta
            if _enc(unblinded, nb) in server_bloom:
                out.append(x)
        return out


@dataclass
class PSIServer:
    """A data owner's side."""

    items: Sequence[str]
    fp_rate: float = 1e-9
    group: str = "modp2048"
    exp_bits: Optional[int] = SHORT_EXP_BITS
    _beta: int = field(default=0, repr=False)

    def __post_init__(self):
        self._p, self._q, self._nb = GROUPS[self.group]
        self._beta = _sample_exponent(self._q, self.exp_bits)
        self._bloom: Optional[BloomFilter] = None

    def _own_bloom(self) -> BloomFilter:
        """Bloom over the β-blinded own set — computed once, reusable
        across rounds with the same client (β is per-session)."""
        if self._bloom is None:
            b, p, nb = self._beta, self._p, self._nb
            bf = BloomFilter.for_capacity(len(self.items), self.fp_rate)
            for y in self.items:
                bf.add(_enc(pow(hash_to_group(y.encode(), p, nb), b, p),
                            nb))
            self._bloom = bf
        return self._bloom

    def respond(self, blinded: Sequence[int]):
        """Returns (double-blinded client set [ordered], bloom of own set)."""
        b, p = self._beta, self._p
        double = [pow(a, b, p) for a in blinded]
        return double, self._own_bloom()


def psi_intersect(client_items: Sequence[str], server_items: Sequence[str],
                  fp_rate: float = 1e-9, group: str = "modp2048",
                  exp_bits: Optional[int] = SHORT_EXP_BITS):
    """One full PSI round.  Returns (intersection_as_client_sees_it, stats)."""
    client = PSIClient(client_items, group, exp_bits)
    server = PSIServer(server_items, fp_rate, group, exp_bits)
    blinded = client.blind()
    double, bf = server.respond(blinded)
    inter = client.intersect(double, bf)
    nb = GROUPS[group][2]
    stats = {
        "client_upload_bytes": nb * len(blinded),
        "server_response_bytes": nb * len(double) + bf.nbytes(),
        "bloom_bytes": bf.nbytes(),
        "uncompressed_server_set_bytes": nb * len(server_items),
    }
    return inter, stats
