"""The multi-headed SplitNN engine.

Two layers live here:

1. ``MLPSplitNN`` — the paper's exact Appendix-B model (dual-headed MLP for
   vertically-partitioned MNIST: 392 -> 64 ReLU heads, concat -> 500 -> 10
   trunk).  Used by the paper-repro experiment and the gradient-equivalence
   property tests.

2. ``make_split_train_step`` — the generic training step shared by the MLP
   and the large ``SplitModel`` architectures: joint forward through
   heads + combine + trunk, single backward pass (autodiff carries the
   cut-layer gradient back to the owners — the paper's protocol, expressed
   as program structure), then *per-segment* optimizer updates
   (owners lr != scientist lr).

``cut_layer_traffic`` accounts the bytes that cross party (pod) boundaries
per step — claim C4: only cut activations (fwd) and cut gradients (bwd)
ever leave an owner.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pyvertical_mnist import MLPSplitConfig
from repro.optim import apply_updates


# ---------------------------------------------------------------------------
# The paper's MLP SplitNN (Appendix B)
# ---------------------------------------------------------------------------


class MLPSplitNN:
    def __init__(self, cfg: MLPSplitConfig):
        self.cfg = cfg
        self.P = cfg.split.n_owners
        self.splits = (tuple(getattr(cfg, "feature_splits", None) or ())
                       or (cfg.n_features // self.P,) * self.P)
        if len(self.splits) != self.P or sum(self.splits) != cfg.n_features:
            raise ValueError(f"feature_splits {self.splits} inconsistent")
        self.symmetric = len(set(self.splits)) == 1
        self.f_p = self.splits[0]                  # 392 per owner (paper)
        self.k = cfg.head_layers[-1]               # 64
        if cfg.split.combine == "concat":
            self.trunk_in = self.P * self.k        # 128
        else:
            self.trunk_in = self.k

    def _mlp_init(self, key, dims):
        params = []
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (a, b), jnp.float32) * np.sqrt(2.0 / a)
            params.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
        return params

    def init(self, key):
        kh, kt = jax.random.split(key)
        if self.symmetric:
            head_dims = (self.f_p,) + self.cfg.head_layers
            heads = jax.vmap(lambda k: self._mlp_init(k, head_dims))(
                jax.random.split(kh, self.P))
        else:
            # imbalanced vertical datasets (paper §5.1): per-owner input
            # widths -> list of asymmetric head segments
            heads = [self._mlp_init(k, (f,) + self.cfg.head_layers)
                     for k, f in zip(jax.random.split(kh, self.P),
                                     self.splits)]
        trunk = self._mlp_init(kt, (self.trunk_in,) + self.cfg.trunk_layers)
        return {"heads": heads, "trunk": trunk}

    @staticmethod
    def _mlp_apply(params, x, final_linear=True):
        for i, layer in enumerate(params):
            x = x @ layer["w"] + layer["b"]
            if i < len(params) - 1 or not final_linear:
                x = jax.nn.relu(x)
        return x

    def heads_forward(self, heads, x_slices):
        """x_slices: (P, B, f_p) stacked — or a list of (B, f_i) slices for
        imbalanced owners.  The paper's head: Linear(392->64) + ReLU."""
        if self.symmetric and not isinstance(x_slices, (list, tuple)):
            return jax.vmap(
                lambda hp, x: jax.nn.relu(self._mlp_apply(hp, x)))(
                    heads, x_slices)
        return jnp.stack([jax.nn.relu(self._mlp_apply(hp, x))
                          for hp, x in zip(heads, x_slices)])

    def combine(self, cut, rng=None):
        sp = self.cfg.split
        if sp.cut_noise_std > 0.0 and rng is not None:
            cut = cut + sp.cut_noise_std * jax.random.normal(
                rng, cut.shape, cut.dtype)
        if sp.combine == "concat":
            P, B, k = cut.shape
            return cut.transpose(1, 0, 2).reshape(B, P * k)
        if sp.combine == "sum":
            return cut.sum(0)
        if sp.combine == "mean":
            return cut.mean(0)
        if sp.combine == "max":
            return cut.max(0)
        raise ValueError(sp.combine)

    def forward(self, params, x_slices, rng=None):
        cut = self.heads_forward(params["heads"], x_slices)
        z = self.combine(cut, rng)
        return self._mlp_apply(params["trunk"], z)   # logits (B, 10)

    @staticmethod
    def _nll_metrics(logits, labels):
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
        acc = jnp.mean(jnp.argmax(logits, -1) == labels)
        return loss, {"loss": loss, "accuracy": acc}

    def loss_fn(self, params, batch, rng=None):
        cut = self.heads_forward(params["heads"], batch["x_slices"])
        z = self.combine(cut, rng)
        logits = self._mlp_apply(params["trunk"], z)
        loss, metrics = self._nll_metrics(logits, batch["labels"])
        w = float(self.cfg.split.nopeek_weight)
        if w > 0.0:
            # NoPeek (core/privacy.py): per-owner dcor(raw slice, cut)
            # joins the training objective; metrics["loss"] stays the
            # bare NLL so trails are comparable across weights.
            from repro.core.privacy import (distance_correlation,
                                            nopeek_penalty)
            xs = batch["x_slices"]
            if isinstance(xs, (list, tuple)):
                pen = w * sum(distance_correlation(x, c)
                              for x, c in zip(xs, cut))
            else:
                pen = nopeek_penalty(xs, cut, w)
            return loss + pen, metrics
        return loss, metrics


# ---------------------------------------------------------------------------
# Generic split training step
# ---------------------------------------------------------------------------


def make_split_train_step(loss_fn: Callable, optimizer,
                          donate: bool = True) -> Callable:
    """Build the jitted SplitNN train step.

    ``loss_fn(params, batch, rng) -> (loss, metrics)``.
    ``optimizer``: a ``multi_segment`` optimizer — heads and trunk get their
    own update rules, mirroring the paper's independent per-party updates.
    """

    def step(params, opt_state, batch, step_idx, rng=None):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, rng=rng)
        updates, opt_state = optimizer.update(grads, opt_state, params,
                                              step_idx)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def train_state_init(params, optimizer):
    return optimizer.init(params)


# ---------------------------------------------------------------------------
# Per-segment programs (true split execution over a transport)
# ---------------------------------------------------------------------------
#
# The joint step above is one autodiff program — the gradient-equivalence
# oracle.  Split execution runs the same math as *separate* programs per
# party: each owner jits its own head forward and an explicit-VJP head
# backward (input: the cut gradient received over the channel); the
# scientist jits one trunk step producing metrics, trunk grads, and the
# cut gradients it ships back.  Chain rule guarantees the composition is
# the joint program exactly (tested bit-for-bit in tests/test_transport).


def make_mlp_head_programs(model: MLPSplitNN, nopeek_weight: float = 0.0):
    """Owner-side segment programs for one MLP head.

    ``head_fwd(head_params, x) -> cut``; ``head_bwd(head_params, x,
    cut_grad) -> head_grads`` (recompute-forward explicit VJP — the head
    is cheap, so no residuals cross the step boundary).

    ``nopeek_weight > 0`` adds the NoPeek distance-correlation penalty's
    gradient to the backward: the penalty is OWNER-LOCAL (dcor between
    this owner's raw slice and its cut), so no extra term ever crosses
    the wire — the received cut gradient seeds the task loss exactly as
    before.  The weight is baked at trace time: weight==0 traces to the
    identical jaxpr as before, keeping the bit-for-bit split-vs-joint
    equivalence contract untouched for undefended runs."""
    w = float(nopeek_weight)

    def head_apply(hp, x):
        return jax.nn.relu(model._mlp_apply(hp, x))

    def head_bwd(hp, x, g):
        _, vjp = jax.vjp(lambda p: head_apply(p, x), hp)
        grads = vjp(g)[0]
        if w > 0.0:
            from repro.core.privacy import distance_correlation
            pen = jax.grad(
                lambda p: w * distance_correlation(x, head_apply(p, x)))(hp)
            grads = jax.tree.map(jnp.add, grads, pen)
        return grads

    return jax.jit(head_apply), jax.jit(head_bwd)


def make_mlp_trunk_program(model: MLPSplitNN):
    """Scientist-side segment program: combine + trunk + loss, forward
    and backward.  ``trunk_step(trunk_params, cut (P, B, k), labels) ->
    (metrics, trunk_grads, cut_grads (P, B, k))``."""

    def trunk_step(tp, cut, labels):
        def f(tp_, cut_):
            z = model.combine(cut_)
            logits = model._mlp_apply(tp_, z)
            return model._nll_metrics(logits, labels)

        (_, metrics), (tg, cg) = jax.value_and_grad(
            f, argnums=(0, 1), has_aux=True)(tp, cut)
        return metrics, tg, cg

    return jax.jit(trunk_step)


def make_mlp_trunk_microbatch_programs(model: MLPSplitNN):
    """Per-microbatch scientist programs for GPipe-style pipelining.

    The batch is split into M row chunks; every chunk's loss is seeded
    ``sum / denom`` with ``denom`` = the FULL batch size, so the per-row
    cotangents are exactly the full-batch mean's and grads accumulate
    across microbatches by plain f32 addition in chunk order.  Metrics
    accumulate the same way (``loss`` = NLL sum / B, ``accuracy`` =
    correct count / B per chunk).

    Two programs because they sit on opposite sides of the wire window:

      ``cutgrad(tp, cuts (P-tuple of (bm, k)), labels (bm,), denom,
          inv_micro) -> (cut_grad_tuple, metric_parts)``
          — the latency-critical path; runs the moment a chunk's cut
          activations arrive so its gradient chunk can ship back
          immediately.  Takes/returns per-owner tuples: the stack and
          the per-owner split both happen inside the compiled program,
          so the dispatch loop does no host-side reshaping.
      ``weightgrad(tp, cuts, labels, denom, inv_micro) ->
          trunk_grad_tree``
          — recompute-based trunk weight gradients, executed while the
          cut gradients fly and the owners step (hidden by the wire).

    With one microbatch (the whole batch as a single chunk) this
    decomposition is bitwise-identical to the fused
    ``make_mlp_trunk_program`` step — verified by the split-vs-joint
    property tests.  ACROSS chunk sizes the math is not bitwise-stable
    (XLA reduction order differs with row count), so the equivalence
    oracle for microbatched runs is the microbatched joint loop in
    ``VerticalSession`` — built from these same programs — not
    ``make_split_train_step``.
    """

    def chunk_loss(tp, cuts, labels, denom):
        z = model.combine(jnp.stack(cuts))
        logits = model._mlp_apply(tp, z)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.sum(jnp.take_along_axis(logp, labels[:, None], 1)) \
            / denom
        acc = jnp.sum(jnp.argmax(logits, -1) == labels) / denom
        return loss, {"loss": loss, "accuracy": acc}

    # inv_micro is part of the uniform adapter signature (the LM trunk
    # weights its aux loss by it); the MLP loss has no per-chunk term
    def cutgrad(tp, cuts, labels, denom, inv_micro):
        (_, parts), cg = jax.value_and_grad(
            lambda c: chunk_loss(tp, c, labels, denom),
            has_aux=True)(tuple(cuts))
        return cg, parts

    def weightgrad(tp, cuts, labels, denom, inv_micro):
        return jax.grad(
            lambda p: chunk_loss(p, tuple(cuts), labels, denom)[0])(tp)

    return jax.jit(cutgrad), jax.jit(weightgrad)


# ---------------------------------------------------------------------------
# Secure forward aggregation (masked-sum combine, Cai et al. 2207.00165)
# ---------------------------------------------------------------------------
#
# The scientist-side programs for ``fit(aggregation="masked_sum")``:
# they consume the int32 RING SUM of the owners' quantized cuts (masked
# on the wire — ``core/masking.py``; the masks cancel in the fold, so
# the sum is bitwise the unmasked oracle's), dequantize in-program, and
# run the trunk.  The cut gradient is ``dL/dz`` — the sum combine's
# broadcast (straight-through across the fixed-point lift) — shipped
# identically to every owner.  Same denom-seeded microbatch semantics
# as the plain programs.


def make_mlp_masked_trunk_program(model: MLPSplitNN):
    """Fused masked-sum scientist step (sequential schedule):
    ``trunk_step(tp, zsum (B, k) int32, labels) ->
    (metrics, trunk_grads, z_grad (B, k))``."""
    from repro.core import masking

    def trunk_step(tp, zsum, labels):
        z = masking.dequantize(zsum)

        def f(tp_, z_):
            logits = model._mlp_apply(tp_, z_)
            return model._nll_metrics(logits, labels)

        (_, metrics), (tg, zg) = jax.value_and_grad(
            f, argnums=(0, 1), has_aux=True)(tp, z)
        return metrics, tg, zg

    return jax.jit(trunk_step)


def make_mlp_masked_trunk_microbatch_programs(model: MLPSplitNN):
    """Per-microbatch masked-sum scientist programs — the masked
    analogue of ``make_mlp_trunk_microbatch_programs`` (same sum/denom
    seeding; ``cuts`` replaced by the chunk's int32 ring sum)."""
    from repro.core import masking

    def chunk_loss(tp, z, labels, denom):
        logits = model._mlp_apply(tp, z)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.sum(jnp.take_along_axis(logp, labels[:, None], 1)) \
            / denom
        acc = jnp.sum(jnp.argmax(logits, -1) == labels) / denom
        return loss, {"loss": loss, "accuracy": acc}

    def cutgrad(tp, zsum, labels, denom, inv_micro):
        z = masking.dequantize(zsum)
        (_, parts), zg = jax.value_and_grad(
            lambda z_: chunk_loss(tp, z_, labels, denom),
            has_aux=True)(z)
        return zg, parts

    def weightgrad(tp, zsum, labels, denom, inv_micro):
        z = masking.dequantize(zsum)
        return jax.grad(
            lambda p: chunk_loss(p, z, labels, denom)[0])(tp)

    return jax.jit(cutgrad), jax.jit(weightgrad)


# ---------------------------------------------------------------------------
# Communication accounting (claim C4)
# ---------------------------------------------------------------------------


def cut_layer_traffic(n_owners: int, batch: int, tokens_per_owner: int,
                      cut_dim: int, bytes_per_el: int = 2) -> Dict[str, int]:
    """Bytes crossing each owner<->scientist boundary per training step.

    forward: the cut activation (B, S_p, k); backward: its gradient.
    This is the ONLY cross-party traffic in SplitNN (raw data and head
    params never move) — and the quantity the multi-pod roofline's
    cross-pod collective term measures.
    """
    one_way = batch * tokens_per_owner * cut_dim * bytes_per_el
    return {
        "per_owner_forward_bytes": one_way,
        "per_owner_backward_bytes": one_way,
        "total_per_step_bytes": 2 * one_way * n_owners,
    }
