"""The paper's §3.1 data-resolution protocol for 2+ data owners.

The data scientist runs PSI *independently* with each data owner (as the
PSI client, so only the scientist learns each pairwise intersection),
computes the global intersection, and broadcasts it.  Data owners never
communicate and never learn of each other.  Each party then discards
non-shared rows and sorts by ID so element n of every vertical dataset
corresponds to the same data subject.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np

from repro.core.psi import GROUPS, PSIClient, PSIServer


@dataclass
class VerticalDataset:
    """One party's vertically-partitioned data: rows keyed by unique IDs."""

    ids: List[str]
    data: np.ndarray          # (n_rows, ...) — features, labels, or tokens

    def __post_init__(self):
        if len(self.ids) != len(self.data):
            raise ValueError("ids/data length mismatch")
        if len(set(self.ids)) != len(self.ids):
            raise ValueError("IDs must be unique")

    def filter_and_sort(self, keep_ids: Sequence[str]) -> "VerticalDataset":
        """Discard non-shared rows; sort by ID (the paper's alignment)."""
        keep = set(keep_ids)
        order = sorted(i for i, d in enumerate(self.ids) if d in keep)
        order.sort(key=lambda i: self.ids[i])
        return VerticalDataset([self.ids[i] for i in order],
                               self.data[order])


def resolve(scientist: VerticalDataset,
            owners: Dict[str, VerticalDataset],
            fp_rate: float = 1e-9, group: str = "modp2048"):
    """Run the full protocol.  Returns (aligned_scientist,
    {owner: aligned_dataset}, stats).

    After resolution every returned dataset has identical ``ids`` in
    identical order — the invariant SplitNN training relies on.
    """
    pairwise = {}
    stats = {"rounds": [], "global_intersection": 0}
    nb = GROUPS[group][2]
    for name, ds in owners.items():
        client = PSIClient(scientist.ids, group)   # scientist is the client
        server = PSIServer(ds.ids, fp_rate, group)  # each owner is a server
        blinded = client.blind()
        double, bf = server.respond(blinded)
        inter = client.intersect(double, bf)
        pairwise[name] = set(inter)
        stats["rounds"].append({
            "owner": name,
            "intersection_size": len(inter),
            "client_upload_bytes": nb * len(blinded),
            "server_response_bytes": nb * len(double) + bf.nbytes(),
        })

    global_ids = set(scientist.ids)
    for s in pairwise.values():
        global_ids &= s
    stats["global_intersection"] = len(global_ids)

    aligned_scientist = scientist.filter_and_sort(global_ids)
    aligned_owners = {name: ds.filter_and_sort(global_ids)
                      for name, ds in owners.items()}

    # invariant: identical ID order everywhere
    for name, ds in aligned_owners.items():
        assert ds.ids == aligned_scientist.ids, f"misaligned owner {name}"
    return aligned_scientist, aligned_owners, stats
