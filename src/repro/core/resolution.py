"""The paper's §3.1 data-resolution protocol for 2+ data owners.

The data scientist runs PSI *independently* with each data owner (as the
PSI client, so only the scientist learns each pairwise intersection),
computes the global intersection, and broadcasts it.  Data owners never
communicate and never learn of each other.  Each party then discards
non-shared rows and sorts by ID so element n of every vertical dataset
corresponds to the same data subject.

Scaling: one :class:`~repro.core.psi.PSIClient` serves every owner round
— its blinded upload is computed once (the only full-width-exponent leg
of the session) and reused verbatim, so the marginal cost of each
additional owner is three short-exponent chunk streams.  ``parallelism``
forks that many modexp workers shared across all rounds; ``chunk_size``
bounds the in-flight big-int working set (million-ID sets stream, they
never materialize as one batch).  Results are bit-identical for every
(parallelism, chunk_size) setting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.modexp import ModexpPool
from repro.core.psi import (DEFAULT_CHUNK, DEFAULT_MODE, PSIClient,
                            PSIServer, psi_round)


@dataclass
class VerticalDataset:
    """One party's vertically-partitioned data: rows keyed by unique IDs."""

    ids: List[str]
    data: np.ndarray          # (n_rows, ...) — features, labels, or tokens

    def __post_init__(self):
        if len(self.ids) != len(self.data):
            raise ValueError("ids/data length mismatch")
        if len(set(self.ids)) != len(self.ids):
            raise ValueError("IDs must be unique")

    def filter_and_sort(self, keep_ids: Sequence[str]) -> "VerticalDataset":
        """Discard non-shared rows; sort by ID (the paper's alignment)."""
        keep = set(keep_ids)
        order = sorted(i for i, d in enumerate(self.ids) if d in keep)
        order.sort(key=lambda i: self.ids[i])
        return VerticalDataset([self.ids[i] for i in order],
                               self.data[order])


def resolve(scientist: VerticalDataset,
            owners: Dict[str, VerticalDataset],
            fp_rate: float = 1e-9, group: str = "modp2048", *,
            mode: str = DEFAULT_MODE,
            chunk_size: int = DEFAULT_CHUNK,
            parallelism: int = 0,
            pool: Optional[ModexpPool] = None):
    """Run the full protocol.  Returns (aligned_scientist,
    {owner: aligned_dataset}, stats).

    After resolution every returned dataset has identical ``ids`` in
    identical order — the invariant SplitNN training relies on.
    ``parallelism``/``chunk_size`` tune the PSI engine (see module
    docstring); the default is the serial in-process engine.
    """
    own_pool = pool is None
    pool = pool or ModexpPool(parallelism)
    try:
        client = PSIClient(scientist.ids, group,
                           mode=mode)              # ONE client, all owners
        pairwise = {}
        stats = {"rounds": [], "global_intersection": 0,
                 "mode": mode, "parallelism": pool.parallelism,
                 "chunk_size": chunk_size}
        for name, ds in owners.items():
            server = PSIServer(ds.ids, fp_rate, group)
            inter, rstats = psi_round(client, server, pool=pool,
                                      chunk_size=chunk_size)
            # effective engine parallelism (0 on fork-fallback hosts)
            stats["parallelism"] = rstats["parallelism"]
            pairwise[name] = set(inter)
            stats["rounds"].append({
                "owner": name,
                "intersection_size": len(inter),
                **{k: rstats[k] for k in
                   ("client_upload_bytes", "server_response_bytes",
                    "n_chunks", "blind_cached")},
                **({"bloom_bytes": rstats["bloom_bytes"],
                    "bloom_shards": rstats["bloom_shards"]}
                   if mode == "bloom" else
                   {"server_set_bytes": rstats["server_set_bytes"]}),
            })
    finally:
        if own_pool:
            pool.close()

    global_ids = set(scientist.ids)
    for s in pairwise.values():
        global_ids &= s
    stats["global_intersection"] = len(global_ids)

    aligned_scientist = scientist.filter_and_sort(global_ids)
    aligned_owners = {name: ds.filter_and_sort(global_ids)
                      for name, ds in owners.items()}

    # invariant: identical ID order everywhere
    for name, ds in aligned_owners.items():
        assert ds.ids == aligned_scientist.ids, f"misaligned owner {name}"
    return aligned_scientist, aligned_owners, stats
