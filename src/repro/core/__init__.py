# The paper's primary contribution: multi-headed SplitNN + PSI entity
# resolution, as a composable JAX system.
#
# The SplitNN surface is lazily re-exported (PEP 562): importing the PSI
# stack (``repro.core.psi`` / ``bloom`` / ``modexp`` / ``resolution``)
# must NOT pull in jax — entity resolution runs in light parent and
# worker processes (benchmarks, ModexpPool workers) where a ~300 MB XLA
# image would dominate the measured footprint and make forking unsafe.
from repro.core.psi import psi_intersect, PSIClient, PSIServer  # noqa
from repro.core.bloom import BloomFilter, ShardedBloom  # noqa: F401
from repro.core.resolution import VerticalDataset, resolve  # noqa: F401

_SPLITNN = ("MLPSplitNN", "make_split_train_step", "cut_layer_traffic",
            "train_state_init")


def __getattr__(name):
    if name in _SPLITNN:
        from repro.core import splitnn
        return getattr(splitnn, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SPLITNN))
