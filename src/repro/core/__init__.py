# The paper's primary contribution: multi-headed SplitNN + PSI entity
# resolution, as a composable JAX system.
from repro.core.splitnn import (MLPSplitNN, make_split_train_step,  # noqa
                                cut_layer_traffic, train_state_init)
from repro.core.psi import psi_intersect, PSIClient, PSIServer  # noqa: F401
from repro.core.bloom import BloomFilter  # noqa: F401
from repro.core.resolution import VerticalDataset, resolve  # noqa: F401
