"""Batch modular exponentiation — the PSI engine's compute backend.

Every leg of the DH-PSI protocol is "one modexp per element", so this is
where a million-ID resolution spends its time.  Three layers:

  * **Scalar backend** — ``powmod`` uses gmpy2's ``powmod`` when the
    module is importable (3-10x faster than CPython's ``pow`` on 2048-bit
    operands) and falls back to the builtin otherwise.  Both produce the
    same integers, so the choice is invisible above this module
    (``HAVE_GMPY2`` records which one is live; tested either way).
  * **Packed chunk kernels** — ``pow_chunk`` / ``hashpow_chunk`` operate
    on *packed* buffers (``nb`` big-endian bytes per element, the PSI
    wire encoding).  Packed bytes are the at-rest representation
    everywhere in the streaming engine: a million 512-bit elements is a
    64 MB ``bytes`` blob instead of ~100 MB of boxed Python ints, and it
    crosses process boundaries as one cheap pickle.
  * **ModexpPool** — a fork-based worker pool with a bounded-lookahead
    ``imap``.  ``parallelism=0`` (the default everywhere) runs the same
    kernels in-process; results are identical integers either way, which
    is what makes the parallel engine bit-identical to the serial path
    by construction.  Pool creation is lazy and failure-tolerant: hosts
    where ``fork`` is unavailable silently degrade to serial.

``hash_to_group`` lives here (re-exported by ``repro.core.psi``) so the
worker kernels can hash+blind in one task — the parent process never
touches per-item hashing on the hot path.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

try:                                    # pragma: no cover - host-dependent
    from gmpy2 import powmod as _powmod
    HAVE_GMPY2 = True
except ImportError:
    _powmod = pow
    HAVE_GMPY2 = False


def powmod(base: int, exp: int, mod: int) -> int:
    """``base ** exp % mod`` via the fastest available backend."""
    return int(_powmod(base, exp, mod))


def hash_to_group(item: bytes, prime: int, nbytes: int = 256) -> int:
    """H(x) = (sha256-derived integer mod p)^2 — lands in QR_p (order q)."""
    h = b""
    ctr = 0
    while len(h) < nbytes + 16:  # modulus size + slack for uniformity
        h += hashlib.sha256(item + ctr.to_bytes(4, "big")).digest()
        ctr += 1
    v = int.from_bytes(h, "big") % prime
    return int(_powmod(v, 2, prime))


# ---------------------------------------------------------------------------
# Packed big-int buffers
# ---------------------------------------------------------------------------


def pack_ints(xs: Sequence[int], nb: int) -> bytes:
    """Fixed-width big-endian packing — the PSI wire encoding."""
    return b"".join(x.to_bytes(nb, "big") for x in xs)


def unpack_ints(blob: bytes, nb: int) -> List[int]:
    f = int.from_bytes
    return [f(blob[i:i + nb], "big") for i in range(0, len(blob), nb)]


# ---------------------------------------------------------------------------
# Chunk kernels (top-level so fork workers can import them by reference)
# ---------------------------------------------------------------------------


def pow_chunk(task: Tuple[bytes, int, int, int]) -> bytes:
    """packed elements -> packed ``el^exp mod p`` (same order)."""
    blob, exp, p, nb = task
    f = int.from_bytes
    out = bytearray(len(blob))
    for i in range(0, len(blob), nb):
        out[i:i + nb] = int(
            _powmod(f(blob[i:i + nb], "big"), exp, p)).to_bytes(nb, "big")
    return bytes(out)


def hashpow_chunk(task: Tuple[Sequence[str], int, int, int]) -> bytes:
    """item strings -> packed ``H(item)^exp mod p`` (hash fused with the
    exponentiation so the parent never hashes on the hot path)."""
    items, exp, p, nb = task
    out = bytearray(len(items) * nb)
    for i, it in enumerate(items):
        h = hash_to_group(it.encode(), p, nb)
        out[i * nb:(i + 1) * nb] = int(_powmod(h, exp, p)).to_bytes(nb,
                                                                    "big")
    return bytes(out)


# ---------------------------------------------------------------------------
# Worker pool
# ---------------------------------------------------------------------------


class ModexpPool:
    """Bounded-lookahead map over chunk kernels, optionally fork-parallel.

    ``parallelism=0`` (or ``None``) is the serial reference: kernels run
    in-process, lazily, one task ahead of the consumer.  ``parallelism=N``
    forks N workers and keeps up to ``inflight`` chunk tasks outstanding
    — the consumer (bloom adds, buffer appends, membership checks) runs
    in the parent while workers exponentiate, which is the blind ->
    exchange -> unblind overlap the transport layer's pipelined schedule
    uses for cut tensors.  If the host cannot fork (sandboxes, exotic
    platforms) the pool degrades to serial and records why in
    ``fallback_reason``.
    """

    def __init__(self, parallelism: Optional[int] = None,
                 inflight: Optional[int] = None):
        self.parallelism = int(parallelism or 0)
        self.inflight = (int(inflight) if inflight
                         else max(2 * self.parallelism, 2))
        self._executor = None
        self._tried = False
        self.fallback_reason: Optional[str] = None

    # -- lifecycle ---------------------------------------------------------
    def _ensure_executor(self):
        if self._tried or self.parallelism <= 0:
            return self._executor
        self._tried = True
        try:
            import sys
            import multiprocessing as mp
            from concurrent.futures import ProcessPoolExecutor
            # fork is the cheap path, but only from a light parent:
            # forking a process with live XLA/threading state (jax
            # loaded) risks deadlocked workers, and each worker would
            # inherit a ~300 MB COW image.  spawn re-imports only this
            # module's (numpy-light) dependency chain.
            method = ("spawn" if "jax" in sys.modules
                      or "fork" not in mp.get_all_start_methods()
                      else "fork")
            ctx = mp.get_context(method)
            self._executor = ProcessPoolExecutor(
                max_workers=self.parallelism, mp_context=ctx)
            # probe: surface broken-fork hosts now, not mid-protocol
            self._executor.submit(pow_chunk,
                                  (b"\x02", 3, 251, 1)).result(timeout=60)
        except Exception as e:              # noqa: BLE001 — any failure
            self.fallback_reason = f"{type(e).__name__}: {e}"
            if self._executor is not None:
                self._executor.shutdown(wait=False)
            self._executor = None
        return self._executor

    @property
    def is_parallel(self) -> bool:
        return self._ensure_executor() is not None

    def close(self):
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- the one primitive -------------------------------------------------
    def imap(self, kernel, tasks: Iterable[tuple]) -> Iterator[bytes]:
        """Yield ``kernel(task)`` for each task **in task order**, with at
        most ``self.inflight`` tasks submitted ahead of the consumer.
        Tasks are pulled from the (possibly lazy) iterable only as
        lookahead permits, so chained ``imap`` stages form a streaming
        pipeline with bounded peak memory."""
        ex = self._ensure_executor()
        it = iter(tasks)
        if ex is None:
            for task in it:
                yield kernel(task)
            return
        from collections import deque
        pending: deque = deque()
        try:
            for task in it:
                pending.append(ex.submit(kernel, task))
                if len(pending) >= self.inflight:
                    yield pending.popleft().result()
            while pending:
                yield pending.popleft().result()
        finally:
            for f in pending:
                f.cancel()
