"""Vertical partitioning of datasets across data owners.

The paper's MNIST experiment splits each image into a left and a right
half; generally, each data owner holds a disjoint vertical slice of every
data subject's features.  For sequence models the slice is a contiguous
sequence segment (DESIGN.md §2); for the VLM/audio archs the slice is a
modality.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def partition_features(x: np.ndarray, n_owners: int) -> List[np.ndarray]:
    """Split feature columns (axis -1) into n contiguous owner slices.
    The paper's MNIST split (left/right halves) is
    ``partition_features(images.reshape(n, 28, 28), 2)`` on axis -1 —
    equivalently on the flattened 784 vector split at 392."""
    if x.shape[-1] % n_owners:
        raise ValueError(f"features {x.shape[-1]} not divisible by {n_owners}")
    return list(np.split(x, n_owners, axis=-1))


def partition_sequence(tokens: np.ndarray, n_owners: int) -> List[np.ndarray]:
    """Split the sequence dim (axis 1) into contiguous owner slices."""
    if tokens.shape[1] % n_owners:
        raise ValueError(f"seq {tokens.shape[1]} not divisible by {n_owners}")
    return list(np.split(tokens, n_owners, axis=1))


def unpartition(slices: List[np.ndarray], axis: int = -1) -> np.ndarray:
    """Inverse of the partitioners (property-tested)."""
    return np.concatenate(slices, axis=axis)


def make_ids(n: int, prefix: str = "subject") -> List[str]:
    return [f"{prefix}-{i:08d}" for i in range(n)]


def scatter_to_owners(ids: List[str], slices: List[np.ndarray],
                      rng: np.random.Generator,
                      keep_frac: float = 0.9) -> List[Tuple[List[str], np.ndarray]]:
    """Simulate real-world silos: each owner independently holds a random
    subset of the subjects (so PSI has actual work to do) and stores rows
    in its own random order."""
    out = []
    n = len(ids)
    for sl in slices:
        keep = rng.random(n) < keep_frac
        idx = np.flatnonzero(keep)
        rng.shuffle(idx)
        out.append(([ids[i] for i in idx], sl[idx]))
    return out
