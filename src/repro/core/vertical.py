"""Vertical partitioning of datasets across data owners.

The paper's MNIST experiment splits each image into a left and a right
half; generally, each data owner holds a disjoint vertical slice of every
data subject's features.  For sequence models the slice is a contiguous
sequence segment (DESIGN.md §2); for the VLM/audio archs the slice is a
modality.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple, Union

import numpy as np

Owners = Union[int, Sequence[int]]


def _split_points(width: int, owners: Owners, what: str) -> np.ndarray:
    """Resolve an owner spec (count, or explicit per-owner sizes for
    imbalanced vertical datasets — paper §5.1, ``MLPSplitNN.
    feature_splits``) to the interior split offsets for ``np.split``."""
    if isinstance(owners, (int, np.integer)):
        if width % owners:
            raise ValueError(
                f"{what} {width} not divisible by {owners} owners; pass "
                f"explicit per-owner sizes instead")
        sizes: Sequence[int] = (width // owners,) * int(owners)
    else:
        sizes = tuple(int(s) for s in owners)
        if not sizes or any(s <= 0 for s in sizes):
            raise ValueError(f"owner sizes must be positive: {sizes}")
        if sum(sizes) != width:
            raise ValueError(
                f"owner sizes {sizes} sum to {sum(sizes)} != {what} {width}")
    return np.cumsum(sizes)[:-1]


def partition_features(x: np.ndarray, owners: Owners) -> List[np.ndarray]:
    """Split feature columns (axis -1) into contiguous owner slices.
    ``owners``: an owner count (equal widths) or explicit per-owner
    widths summing to the feature dim.  The paper's MNIST split
    (left/right halves) is ``partition_features(images.reshape(n, 28,
    28), 2)`` on axis -1 — equivalently on the flattened 784 vector
    split at 392."""
    return list(np.split(x, _split_points(x.shape[-1], owners, "features"),
                         axis=-1))


def partition_sequence(tokens: np.ndarray, owners: Owners
                       ) -> List[np.ndarray]:
    """Split the sequence dim (axis 1) into contiguous owner slices.
    ``owners``: a count or explicit per-owner slice lengths."""
    return list(np.split(tokens, _split_points(tokens.shape[1], owners,
                                               "seq"), axis=1))


def unpartition(slices: List[np.ndarray], axis: int = -1) -> np.ndarray:
    """Inverse of the partitioners (property-tested)."""
    return np.concatenate(slices, axis=axis)


def make_ids(n: int, prefix: str = "subject") -> List[str]:
    return [f"{prefix}-{i:08d}" for i in range(n)]


def scatter_to_owners(ids: List[str], slices: List[np.ndarray],
                      rng: np.random.Generator,
                      keep_frac: float = 0.9) -> List[Tuple[List[str], np.ndarray]]:
    """Simulate real-world silos: each owner independently holds a random
    subset of the subjects (so PSI has actual work to do) and stores rows
    in its own random order."""
    out = []
    n = len(ids)
    for sl in slices:
        keep = rng.random(n) < keep_frac
        idx = np.flatnonzero(keep)
        rng.shuffle(idx)
        out.append(([ids[i] for i in idx], sl[idx]))
    return out
