"""Beyond-paper privacy hooks (the paper's §5.1 future-work items).

* Gaussian noise on cut-layer activations (Titcombe et al. 2021 — basic
  defence against model-inversion on the intermediate representation).
  Wired into ``SplitConfig.cut_noise_std``.
* NoPeek-style distance-correlation regularizer: penalize statistical
  dependence between an owner's raw inputs and its cut activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pairwise_dist(x):
    """Euclidean distance matrix of rows of x: (B, F) -> (B, B), fp32."""
    x = x.reshape(x.shape[0], -1).astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 1e-12))


def _center(d):
    return (d - jnp.mean(d, 0, keepdims=True) - jnp.mean(d, 1, keepdims=True)
            + jnp.mean(d))


def distance_correlation(x, z) -> jnp.ndarray:
    """Székely distance correlation between batches x (B, ...) and z (B, ...).

    0 = independent; 1 = strongly dependent.  Used both as the NoPeek
    regularizer and as a leakage *metric* in the privacy benchmark."""
    a = _center(_pairwise_dist(x))
    b = _center(_pairwise_dist(z))
    dcov = jnp.sqrt(jnp.maximum(jnp.mean(a * b), 0.0))
    dvar_x = jnp.sqrt(jnp.maximum(jnp.mean(a * a), 0.0))
    dvar_z = jnp.sqrt(jnp.maximum(jnp.mean(b * b), 0.0))
    return dcov / jnp.maximum(jnp.sqrt(dvar_x * dvar_z), 1e-9)


def nopeek_penalty(raw_inputs, cut_activations, weight: float):
    """NoPeek loss term: weight * dcor(raw, cut) per owner, summed."""
    if weight <= 0.0:
        return jnp.zeros((), jnp.float32)
    if raw_inputs.ndim == cut_activations.ndim:  # stacked owner dim
        per_owner = jax.vmap(distance_correlation)(raw_inputs,
                                                   cut_activations)
        return weight * jnp.sum(per_owner)
    return weight * distance_correlation(raw_inputs, cut_activations)


def gaussian_cut_noise(rng, cut, std: float):
    if std <= 0.0:
        return cut
    return cut + std * jax.random.normal(rng, cut.shape, cut.dtype)
