"""Beyond-paper privacy hooks (the paper's §5.1 future-work items).

* Gaussian noise on cut-layer activations (Titcombe et al. 2021 — basic
  defence against model-inversion on the intermediate representation).
  Wired into ``SplitConfig.cut_noise_std``; split mode applies it
  OWNER-side before the cut ships, so the defence is on the wire.
* NoPeek-style distance-correlation regularizer: penalize statistical
  dependence between an owner's raw inputs and its cut activations.
* Gradient-side label-leakage defences (Li et al. 2021, "Label Leakage
  and Protection"): per-example cut-gradient *norms* leak labels under
  class imbalance, and signs/directions leak more.  ``SplitConfig.
  grad_norm_mode`` ("unit" equalizes per-example norms, "sign" ships
  only signs at a common magnitude) and ``SplitConfig.grad_noise_std``
  obfuscate the cut gradients the scientist ships back.  Both are
  deterministic in ``(seed, seq, owner)`` so PR 8 supervised replay
  stays bit-identical with defences enabled.

``tests/attacks`` runs real attacks against captured transcripts and
asserts each defence strictly reduces the attacker's leakage.
"""
from __future__ import annotations

import hashlib

import jax
import jax.numpy as jnp
import numpy as np


def _pairwise_dist(x):
    """Euclidean distance matrix of rows of x: (B, F) -> (B, B), fp32."""
    x = x.reshape(x.shape[0], -1).astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 1e-12))


def _center(d):
    return (d - jnp.mean(d, 0, keepdims=True) - jnp.mean(d, 1, keepdims=True)
            + jnp.mean(d))


def distance_correlation(x, z) -> jnp.ndarray:
    """Székely distance correlation between batches x (B, ...) and z (B, ...).

    0 = independent; 1 = strongly dependent.  Used both as the NoPeek
    regularizer and as a leakage *metric* in the privacy benchmark."""
    a = _center(_pairwise_dist(x))
    b = _center(_pairwise_dist(z))
    dcov = jnp.sqrt(jnp.maximum(jnp.mean(a * b), 0.0))
    dvar_x = jnp.sqrt(jnp.maximum(jnp.mean(a * a), 0.0))
    dvar_z = jnp.sqrt(jnp.maximum(jnp.mean(b * b), 0.0))
    return dcov / jnp.maximum(jnp.sqrt(dvar_x * dvar_z), 1e-9)


def nopeek_penalty(raw_inputs, cut_activations, weight: float):
    """NoPeek loss term: weight * dcor(raw, cut) per owner, summed."""
    if weight <= 0.0:
        return jnp.zeros((), jnp.float32)
    if raw_inputs.ndim == cut_activations.ndim:  # stacked owner dim
        per_owner = jax.vmap(distance_correlation)(raw_inputs,
                                                   cut_activations)
        return weight * jnp.sum(per_owner)
    return weight * distance_correlation(raw_inputs, cut_activations)


def gaussian_cut_noise(rng, cut, std: float):
    if std <= 0.0:
        return cut
    return cut + std * jax.random.normal(rng, cut.shape, cut.dtype)


# ---------------------------------------------------------------------------
# Wire defenses (deterministic host-side transforms on shipped tensors)
# ---------------------------------------------------------------------------


def _wire_rng(seed: int, tag: str) -> np.random.Generator:
    """Philox stream keyed on sha256(seed|tag): deterministic across
    processes and replay — the same chunk re-shipped after a PR 8
    rollback gets bitwise the same noise."""
    h = hashlib.sha256(f"{seed}|{tag}".encode()).digest()
    return np.random.Generator(
        np.random.Philox(key=int.from_bytes(h[:16], "little")))


def deterministic_cut_noise(cut, std: float, seed: int,
                            tag: str) -> np.ndarray:
    """Owner-side Titcombe noise on a cut chunk about to ship (host
    numpy: the owner's wire path already has the array on host)."""
    cut = np.asarray(cut, np.float32)
    if std <= 0.0:
        return cut
    noise = _wire_rng(seed, tag).standard_normal(
        cut.shape).astype(np.float32)
    return cut + np.float32(std) * noise


def obfuscate_cut_gradient(g, *, noise_std: float = 0.0,
                           norm_mode: str = "none", seed: int = 0,
                           tag: str = "") -> np.ndarray:
    """Scientist-side defence on one cut-gradient chunk (B, k) before
    it ships (Li et al. norm attack + direction attacks):

    * ``norm_mode="unit"`` rescales every example's gradient to the
      batch-median norm — the per-example norm carries zero bits.
    * ``norm_mode="sign"`` ships ``sign(g)`` at one common magnitude
      (the mean |g|) — norms AND fine-grained directions collapse.
    * ``noise_std`` adds deterministic Gaussian noise (keyed on
      ``(seed, tag)``) on top of either mode.

    Pure in its inputs, so supervised replay re-derives identical
    defended gradients."""
    g = np.asarray(g, np.float32)
    if norm_mode not in ("none", "unit", "sign"):
        raise ValueError(f"unknown grad_norm_mode {norm_mode!r}")
    if norm_mode == "unit":
        norms = np.linalg.norm(g.reshape(g.shape[0], -1), axis=1)
        target = np.float32(np.median(norms))
        scale = target / np.maximum(norms, 1e-12)
        g = g * scale.reshape((-1,) + (1,) * (g.ndim - 1)).astype(
            np.float32)
    elif norm_mode == "sign":
        g = np.sign(g).astype(np.float32) * np.float32(
            np.mean(np.abs(g)))
    if noise_std > 0.0:
        noise = _wire_rng(seed, tag).standard_normal(
            g.shape).astype(np.float32)
        g = g + np.float32(noise_std) * noise
    return g


def label_inference_auc(grad_norms, labels) -> float:
    """The norm attack's score: AUC of per-example cut-gradient norms
    predicting the (binary) label — 0.5 = chance, 1.0 = full leak.
    Shared by the attack harness and the privacy benchmark."""
    norms = np.asarray(grad_norms, np.float64)
    y = np.asarray(labels).astype(bool)
    pos, neg = norms[y], norms[~y]
    if not len(pos) or not len(neg):
        return 0.5
    # Mann-Whitney U statistic, ties counted half
    greater = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return float((greater + 0.5 * ties) / (len(pos) * len(neg)))
