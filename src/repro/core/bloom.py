"""Bloom filters used to compress the PSI server's response
(Angelou et al. 2020: DDH-PSI with Bloom-filter compression).

Two layers:

  * :class:`BloomFilter` — numpy bitset with **double hashing**
    (Kirsch-Mitzenmacher): one sha256 digest per item yields ``h1, h2``
    and the k probe indices are ``(h1 + i*h2) mod m``.  The asymptotic
    false-positive rate matches k independent hashes, but an add/query
    costs ONE digest instead of k (~30 at fp 1e-9), and the batch paths
    (``add_batch`` / ``query_batch``) vectorize the bit arithmetic in
    numpy — the per-element cost drops from ~65 us to a few us.
  * :class:`ShardedBloom` — S independent :class:`BloomFilter` shards;
    each item routes to one shard by its digest.  This is the million-ID
    shape: shards are built per-chunk and OR-merged (``merge``) so a
    parallel build never serializes on one bitset, each shard is an
    independently shippable wire frame (``shard_frames``) with bounded
    message size, and a membership probe touches one small shard's bits
    instead of a filter-sized working set.

No false negatives ever; false positives bounded by the sizing in
``for_capacity`` (m = -n ln fp / ln^2 2, k = m/n ln 2).
"""
from __future__ import annotations

import hashlib
import math
from typing import Iterable, List, Sequence, Tuple

import numpy as np

_MASK64 = (1 << 64) - 1


def _digest_arrays(items: Sequence[bytes]) -> Tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """One sha256 per item -> (h1, h2, route) uint64 arrays.  h2 is forced
    odd so the double-hash probe sequence spans the whole bitset for any
    m; ``route`` (independent digest bytes) picks the shard."""
    n = len(items)
    h1 = np.empty(n, np.uint64)
    h2 = np.empty(n, np.uint64)
    rt = np.empty(n, np.uint64)
    f = int.from_bytes
    for i, it in enumerate(items):
        d = hashlib.sha256(it).digest()
        h1[i] = f(d[0:8], "big")
        h2[i] = f(d[8:16], "big") | 1
        rt[i] = f(d[16:24], "big")
    return h1, h2, rt


class BloomFilter:
    def __init__(self, n_bits: int, n_hashes: int):
        if n_bits <= 0 or n_hashes <= 0:
            raise ValueError("n_bits and n_hashes must be positive")
        self.m = int(n_bits)
        self.k = int(n_hashes)
        self.bits = np.zeros((self.m + 7) // 8, dtype=np.uint8)

    @classmethod
    def for_capacity(cls, n_items: int, fp_rate: float = 1e-6):
        """Size the filter for ``n_items`` at the target false-positive rate."""
        n_items = max(n_items, 1)
        m = int(-n_items * math.log(max(fp_rate, 1e-12)) / (math.log(2) ** 2))
        k = max(1, round(m / n_items * math.log(2)))
        return cls(max(m, 8), k)

    # -- probe index derivation (shared scalar/batch) ----------------------
    def _indices(self, item: bytes):
        d = hashlib.sha256(item).digest()
        h1 = int.from_bytes(d[0:8], "big")
        h2 = int.from_bytes(d[8:16], "big") | 1
        for i in range(self.k):
            # enhanced double hashing (Dillinger-Manolios): the cubic
            # term keeps the k probes well-spread even when h2 shares a
            # factor with a small composite m — plain h1 + i*h2 then
            # cycles through m/gcd(h2, m) slots and the real fp rate
            # blows past the sizing target on tiny filters
            yield ((h1 + i * h2 + (i * i * i - i) // 6) & _MASK64) % self.m

    def _probe_matrix(self, h1: np.ndarray, h2: np.ndarray) -> np.ndarray:
        """(B, k) probe indices — uint64 wraparound matches the scalar
        path's explicit ``& MASK64`` (enhanced double hashing, same
        closed form as ``_indices``)."""
        i = np.arange(self.k, dtype=np.uint64)
        off = (i * i * i - i) // np.uint64(6)
        return ((h1[:, None] + i[None, :] * h2[:, None] + off[None, :])
                % np.uint64(self.m))

    # -- scalar API --------------------------------------------------------
    def add(self, item: bytes):
        for idx in self._indices(item):
            self.bits[idx >> 3] |= 1 << (idx & 7)

    def add_all(self, items: Iterable[bytes]):
        """Streaming add: consumes any iterable in bounded batches (the
        vectorized win without materializing the whole input or an
        O(n·k) probe matrix)."""
        batch: List[bytes] = []
        for it in items:
            batch.append(it)
            if len(batch) >= 65_536:
                self.add_batch(batch)
                batch = []
        self.add_batch(batch)

    def __contains__(self, item: bytes) -> bool:
        return all(self.bits[i >> 3] >> (i & 7) & 1 for i in self._indices(item))

    # -- vectorized batch API ---------------------------------------------
    def add_batch(self, items: Sequence[bytes]) -> None:
        if not items:
            return
        h1, h2, _ = _digest_arrays(items)
        self._add_hashed(h1, h2)

    def _add_hashed(self, h1: np.ndarray, h2: np.ndarray) -> None:
        idx = self._probe_matrix(h1, h2).ravel()
        np.bitwise_or.at(self.bits, (idx >> np.uint64(3)).astype(np.int64),
                         np.left_shift(np.uint8(1),
                                       (idx & np.uint64(7)).astype(np.uint8)))

    def query_batch(self, items: Sequence[bytes]) -> np.ndarray:
        if not items:
            return np.zeros(0, bool)
        h1, h2, _ = _digest_arrays(items)
        return self._query_hashed(h1, h2)

    def _query_hashed(self, h1: np.ndarray, h2: np.ndarray) -> np.ndarray:
        idx = self._probe_matrix(h1, h2)
        bit = (self.bits[(idx >> np.uint64(3)).astype(np.int64)]
               >> (idx & np.uint64(7)).astype(np.uint8)) & 1
        return bit.all(axis=1)

    # -- wire --------------------------------------------------------------
    def nbytes(self) -> int:
        """Wire size — what the PSI server actually transmits."""
        return self.bits.nbytes

    def to_bytes(self) -> bytes:
        return self.bits.tobytes()

    @classmethod
    def from_bytes(cls, data: bytes, n_bits: int, n_hashes: int):
        bf = cls(n_bits, n_hashes)
        bf.bits = np.frombuffer(data, dtype=np.uint8).copy()
        return bf

    def merge(self, other: "BloomFilter") -> "BloomFilter":
        """OR-merge a same-shaped filter in place (parallel builds)."""
        if (self.m, self.k) != (other.m, other.k):
            raise ValueError("cannot merge differently-shaped filters")
        np.bitwise_or(self.bits, other.bits, out=self.bits)
        return self


class ShardedBloom:
    """S independent shards, routed by digest — the scalable server set.

    ``shard_capacity`` bounds the per-shard item count the sizing assumes;
    the default keeps each shard's bitmap around 256 KiB at fp 1e-9, a
    sane streaming frame.  Membership semantics are identical to one big
    filter (same fp target); the shard layout is deterministic in the
    item bytes, so serial and parallel builds produce identical bits.
    """

    DEFAULT_SHARD_CAPACITY = 65_536

    def __init__(self, shards: List[BloomFilter]):
        if not shards:
            raise ValueError("need at least one shard")
        self.shards = shards

    @classmethod
    def for_capacity(cls, n_items: int, fp_rate: float = 1e-6,
                     n_shards: int = 0,
                     shard_capacity: int = DEFAULT_SHARD_CAPACITY):
        n_items = max(n_items, 1)
        s = int(n_shards) or max(1, math.ceil(n_items / shard_capacity))
        per = math.ceil(n_items / s)
        return cls([BloomFilter.for_capacity(per, fp_rate)
                    for _ in range(s)])

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def _route(self, rt: np.ndarray) -> np.ndarray:
        return (rt % np.uint64(len(self.shards))).astype(np.int64)

    # -- batch API (the engine's path) ------------------------------------
    def add_batch(self, items: Sequence[bytes]) -> None:
        if not items:
            return
        h1, h2, rt = _digest_arrays(items)
        which = self._route(rt)
        for s in np.unique(which):
            sel = which == s
            self.shards[s]._add_hashed(h1[sel], h2[sel])

    def query_batch(self, items: Sequence[bytes]) -> np.ndarray:
        if not items:
            return np.zeros(0, bool)
        h1, h2, rt = _digest_arrays(items)
        which = self._route(rt)
        out = np.zeros(len(items), bool)
        for s in np.unique(which):
            sel = which == s
            out[sel] = self.shards[s]._query_hashed(h1[sel], h2[sel])
        return out

    # -- scalar compat ----------------------------------------------------
    def add(self, item: bytes) -> None:
        self.add_batch([item])

    def __contains__(self, item: bytes) -> bool:
        return bool(self.query_batch([item])[0])

    # -- wire --------------------------------------------------------------
    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self.shards)

    def shard_frames(self) -> List[bytes]:
        """Per-shard wire frames — each independently shippable, so a
        million-ID response streams as bounded messages instead of one
        multi-MB blob."""
        return [s.to_bytes() for s in self.shards]

    def content_tag(self) -> bytes:
        """16-byte content tag over the shard frames + geometry — equal
        filters get equal tags, which is what lets a client skip
        re-downloading a response leg it already holds (the PSI
        ``server_tag`` handshake)."""
        h = hashlib.sha256()
        h.update(f"{self.n_shards}:{self.shards[0].m}:"
                 f"{self.shards[0].k}".encode())
        for frame in self.shard_frames():
            h.update(frame)
        return h.digest()[:16]

    def merge(self, other: "ShardedBloom") -> "ShardedBloom":
        if self.n_shards != other.n_shards:
            raise ValueError("shard count mismatch")
        for a, b in zip(self.shards, other.shards):
            a.merge(b)
        return self
