"""Bloom filter used to compress the PSI server's response
(Angelou et al. 2020: DDH-PSI with Bloom-filter compression).

numpy bitset, k independent hashes derived from sha256(elem || i).
No false negatives; false-positive rate ~ (1 - e^{-kn/m})^k.
"""
from __future__ import annotations

import hashlib
import math
from typing import Iterable

import numpy as np


class BloomFilter:
    def __init__(self, n_bits: int, n_hashes: int):
        if n_bits <= 0 or n_hashes <= 0:
            raise ValueError("n_bits and n_hashes must be positive")
        self.m = int(n_bits)
        self.k = int(n_hashes)
        self.bits = np.zeros((self.m + 7) // 8, dtype=np.uint8)

    @classmethod
    def for_capacity(cls, n_items: int, fp_rate: float = 1e-6):
        """Size the filter for ``n_items`` at the target false-positive rate."""
        n_items = max(n_items, 1)
        m = int(-n_items * math.log(max(fp_rate, 1e-12)) / (math.log(2) ** 2))
        k = max(1, round(m / n_items * math.log(2)))
        return cls(max(m, 8), k)

    def _indices(self, item: bytes):
        for i in range(self.k):
            h = hashlib.sha256(item + i.to_bytes(4, "big")).digest()
            yield int.from_bytes(h[:8], "big") % self.m

    def add(self, item: bytes):
        for idx in self._indices(item):
            self.bits[idx >> 3] |= 1 << (idx & 7)

    def add_all(self, items: Iterable[bytes]):
        for it in items:
            self.add(it)

    def __contains__(self, item: bytes) -> bool:
        return all(self.bits[i >> 3] >> (i & 7) & 1 for i in self._indices(item))

    def nbytes(self) -> int:
        """Wire size — what the PSI server actually transmits."""
        return self.bits.nbytes

    def to_bytes(self) -> bytes:
        return self.bits.tobytes()

    @classmethod
    def from_bytes(cls, data: bytes, n_bits: int, n_hashes: int):
        bf = cls(n_bits, n_hashes)
        bf.bits = np.frombuffer(data, dtype=np.uint8).copy()
        return bf
