from repro.testing.hypo import (HAVE_HYPOTHESIS, given,  # noqa: F401
                                settings, strategies)
