"""Property-testing shim: real ``hypothesis`` when installed, else a
deterministic random-sampling fallback.

Tests import ``given / settings / strategies`` from here instead of from
``hypothesis`` directly.  On machines with hypothesis installed (it is
listed in ``requirements-dev.txt``) this module is a pure re-export and
behavior is identical.  Offline CI images that lack it get a minimal
mini-implementation of the strategy surface the repo's tests use
(``integers, floats, booleans, text, binary, sampled_from, just, lists,
sets, tuples``): each test runs ``max_examples`` random examples drawn
from a per-test deterministic seed (crc32 of the test's qualname), and a
failing example is re-raised with the generated arguments attached.  No
shrinking — the first falsifying example is reported verbatim.
"""
from __future__ import annotations

try:  # pragma: no cover - depends on environment
    from hypothesis import HealthCheck, assume, given, settings  # noqa
    from hypothesis import strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import random
    import string
    import zlib

    class _Unsatisfied(Exception):
        pass

    def assume(condition):
        if not condition:
            raise _Unsatisfied
        return True

    class HealthCheck:  # attribute sink: settings(suppress_health_check=..)
        all = staticmethod(lambda: ())
        too_slow = data_too_large = filter_too_much = None

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred, _tries: int = 100):
            def draw(rng):
                for _ in range(_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise _Unsatisfied
            return _Strategy(draw)

    class strategies:
        """The subset of ``hypothesis.strategies`` the repo's tests use."""

        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2 ** 31) if min_value is None else min_value
            hi = 2 ** 31 if max_value is None else max_value
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def floats(min_value=None, max_value=None, **_kw):
            lo = -1e9 if min_value is None else min_value
            hi = 1e9 if max_value is None else max_value
            return _Strategy(lambda rng: rng.uniform(lo, hi))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def text(alphabet=None, min_size=0, max_size=20):
            chars = (list(alphabet) if alphabet is not None
                     else list(string.ascii_letters + string.digits +
                               string.punctuation + " "))
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return "".join(rng.choice(chars) for _ in range(n))
            return _Strategy(draw)

        @staticmethod
        def binary(min_size=0, max_size=20):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return bytes(rng.randrange(256) for _ in range(n))
            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=20, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elements.example_from(rng) for _ in range(n)]
                out, seen = [], set()
                for _ in range(20 * n + 100):
                    v = elements.example_from(rng)
                    if v not in seen:
                        seen.add(v)
                        out.append(v)
                    if len(out) == n:
                        break
                return out
            return _Strategy(draw)

        @staticmethod
        def sets(elements, min_size=0, max_size=20):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = set()
                for _ in range(20 * n + 100):
                    out.add(elements.example_from(rng))
                    if len(out) == n:
                        break
                return out
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(
                lambda rng: tuple(e.example_from(rng) for e in elems))

    def settings(max_examples=100, deadline=None, **_kw):
        """Record ``max_examples``; works above or below ``@given``."""
        def deco(fn):
            fn._shim_settings = {"max_examples": max_examples}
            return fn
        return deco

    import inspect

    def given(*strats, **kwstrats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = (getattr(wrapper, "_shim_settings", None)
                       or getattr(fn, "_shim_settings", None) or {})
                n = cfg.get("max_examples", 100)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                ran = 0
                for i in range(n * 5):
                    if ran >= n:
                        break
                    try:
                        vals = [s.example_from(rng) for s in strats]
                        kws = {k: s.example_from(rng)
                               for k, s in kwstrats.items()}
                    except _Unsatisfied:
                        continue
                    try:
                        fn(*args, *vals, **{**kwargs, **kws})
                        ran += 1
                    except _Unsatisfied:
                        continue
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{ran}: args={vals!r} "
                            f"kwargs={kws!r}") from e
            # strategies supply every parameter: hide the original
            # signature so pytest doesn't mistake params for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
