"""Per-segment checkpointing.

In VFL each party persists ONLY its own model segment (owners must never
see each other's or the scientist's weights).  ``save_split`` writes one
npz per party: heads/owner{i}.npz + trunk.npz; ``save``/``restore`` are the
generic single-tree primitives (flattened path -> array)."""
from __future__ import annotations

import os
import re
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Any = {}
    for path, arr in flat.items():
        keys = path.split("/")
        node = root
        for i, k in enumerate(keys):
            last = i == len(keys) - 1
            if last:
                node[k] = arr
            else:
                node = node.setdefault(k, {})
    def fix(node):
        if isinstance(node, dict) and node and all(
                re.fullmatch(r"#\d+", k) for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node
    return fix(root)


def save(path: str, tree):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(jax.device_get(tree)))


def restore(path: str):
    with np.load(path, allow_pickle=False) as z:
        return _unflatten({k: z[k] for k in z.files})


def save_split(ckpt_dir: str, params, step: int = 0):
    """One file per party: owners keep their head, scientist the trunk."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    heads = jax.device_get(params["heads"])
    n_owners = jax.tree.leaves(heads)[0].shape[0]
    for p in range(n_owners):
        owner_tree = jax.tree.map(lambda a: a[p], heads)
        save(os.path.join(d, f"owner{p}.npz"), owner_tree)
    save(os.path.join(d, "trunk.npz"), params["trunk"])
    return d


def restore_split(step_dir: str):
    """Reassemble {"heads": stacked, "trunk": ...} from per-party files."""
    owners = sorted(f for f in os.listdir(step_dir)
                    if f.startswith("owner"))
    head_trees = [restore(os.path.join(step_dir, f)) for f in owners]
    heads = jax.tree.map(lambda *a: np.stack(a), *head_trees)
    trunk = restore(os.path.join(step_dir, "trunk.npz"))
    return {"heads": heads, "trunk": trunk}
