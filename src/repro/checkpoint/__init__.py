from repro.checkpoint.checkpoint import save, restore, save_split, restore_split  # noqa
