"""mixtral-8x7b — 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

32 layers, d_model 4096, 32 heads (kv=8), expert hidden 14336,
vocab 32000.  All layers use a 4096-token sliding window (native
sub-quadratic long-context story).
"""
from repro.configs.base import ArchConfig, MoEConfig, SplitConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    mlp="swiglu",
    swa_window=4096,
    block_pattern=("attn:local",),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14336),
    long_context="native",
    long_context_window=4096,
    split=SplitConfig(n_owners=2, cut_layer=8),
)
