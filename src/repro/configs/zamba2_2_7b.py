"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54 layers, d_model 2560, 32 heads (kv=32), d_ff 10240, vocab 32000,
ssm_state 64.  The repeating unit is 5 Mamba2 blocks followed by one
shared-parameter attention block (the zamba2 "shared transformer block"
applied periodically): 9 units x 6 blocks = 54 layers.
"""
from repro.configs.base import ArchConfig, SSMConfig, SplitConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    mlp="gelu",
    rope="rope",
    block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2",
                   "shared_attn"),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
    long_context="native",
    split=SplitConfig(n_owners=2, cut_layer=2),
)
