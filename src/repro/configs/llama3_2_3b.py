"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-1B family card].

28 layers, d_model 3072, 24 heads (kv=8), d_ff 8192, vocab 128256.
SwiGLU, RMSNorm, rope theta 500k, tied embeddings.
"""
from repro.configs.base import ArchConfig, SplitConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    mlp="swiglu",
    rope_theta=500000.0,
    tie_embeddings=True,
    long_context="swa",
    long_context_window=8192,
    split=SplitConfig(n_owners=2, cut_layer=7),
)
