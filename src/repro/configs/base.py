"""Configuration dataclasses for architectures, shapes and the SplitNN.

Every assigned architecture is expressed as an ``ArchConfig``; the four
assigned input shapes are ``ShapeConfig``s; the PyVertical split itself
(how many data owners, where the cut layer sits, how the scientist combines
head outputs) is a ``SplitConfig``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    n_experts: int
    top_k: int
    d_expert: int              # hidden dim of a single routed expert
    n_shared: int = 0          # always-on shared experts (DeepSeekMoE)
    d_shared: int = 0          # hidden dim of the shared expert(s)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # §Perf lever: dispatch tokens within G groups aligned to the data
    # axis (group-local capacity) instead of one global scatter — removes
    # the cross-shard all-reduce of the dispatch buffer.  1 = paper-era
    # global dispatch (baseline).
    dispatch_groups: int = 1


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block configuration."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64         # SSD head dim (P in the SSD paper)
    n_groups: int = 1
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (sLSTM + mLSTM)."""

    m_proj_factor: float = 2.0    # mLSTM up-projection factor
    s_proj_factor: float = 4.0 / 3.0  # sLSTM FFN projection factor
    conv_width: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class SplitConfig:
    """The PyVertical multi-headed SplitNN configuration.

    ``n_owners`` data owners each hold a vertical slice of the features of
    the same data subjects.  Each owner runs ``cut_layer`` blocks (its
    *head segment*) locally; the data scientist combines head outputs at the
    cut layer and runs the remaining blocks (the *trunk segment*).
    """

    n_owners: int = 2
    cut_layer: int = 1             # number of blocks in each owner head
    combine: str = "concat"        # concat | sum | mean | max
    cut_dim: int = 0               # 0 = keep d_model (exact); >0 = bottleneck
    owner_lr: float = 0.01         # paper Appendix B
    scientist_lr: float = 0.1      # paper Appendix B
    # beyond-paper privacy options (Titcombe et al. 2021 future-work item)
    cut_noise_std: float = 0.0     # Gaussian noise added to cut activations
    nopeek_weight: float = 0.0     # distance-correlation regularizer weight
    # gradient-side label-leakage defenses (Li et al. 2021): applied to
    # the cut gradients the scientist ships back in split mode —
    # deterministic per (seed, seq, owner), so supervised replay stays
    # bit-identical with defenses on (see core/privacy.py)
    grad_noise_std: float = 0.0    # Gaussian noise on shipped cut grads
    grad_norm_mode: str = "none"   # none | unit (equalize per-example
    #                                norms) | sign (signs at one common
    #                                magnitude)


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

# Block kinds understood by the transformer assembler.
#   "attn:global"  full causal attention
#   "attn:local"   sliding-window attention (window = swa_window)
#   "mamba2"       SSD block
#   "slstm"/"mlstm" xLSTM blocks
#   "shared_attn"  zamba2-style shared-parameter attention block
BLOCK_KINDS = ("attn:global", "attn:local", "mamba2", "slstm", "mlstm",
               "shared_attn")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    source: str                    # citation for the config
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0              # 0 → d_model // n_heads
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    post_block_norm: bool = False  # gemma2 pre+post norms
    mlp: str = "swiglu"            # swiglu | geglu | gelu | relu2 | none
    rope: str = "rope"             # rope | mrope | sincos | none
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0      # gemma2 attention logit soft-capping
    logit_softcap: float = 0.0     # gemma2 final logit soft-capping
    swa_window: int = 4096
    tie_embeddings: bool = False

    # Super-block pattern: the repeating unit of heterogeneous blocks.
    # n_layers must be divisible by len(block_pattern); the model is
    # scan-over-superblocks with this unit.  Default: ("attn:global",).
    block_pattern: Tuple[str, ...] = ("attn:global",)

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # encoder-decoder (whisper): head = encoder, trunk = decoder.
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_bidirectional: bool = True

    # modality of owner inputs: "text" (token ids) | "vision_text" (owner 0
    # holds precomputed patch embeddings — frontend stub) | "audio_text"
    # (owner 0 holds precomputed frame embeddings — frontend stub)
    modality: str = "text"
    d_frontend: int = 0            # stub frontend embedding dim (0 → d_model)

    # long-context handling: "native" (sub-quadratic already),
    # "swa" (explicit sliding-window variant used ONLY for long_500k),
    # "skip" (architecture cannot meaningfully run 500k decode)
    long_context: str = "swa"
    long_context_window: int = 8192

    split: SplitConfig = field(default_factory=SplitConfig)

    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    zero_sharding: bool = False    # additionally shard params over "data"
    remat: bool = True             # activation-checkpoint each super-block

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.enc_dec:
            if self.n_enc_layers <= 0:
                raise ValueError("enc_dec arch needs n_enc_layers")
        else:
            if self.n_layers % len(self.block_pattern) != 0:
                raise ValueError(
                    f"{self.name}: n_layers={self.n_layers} not divisible by "
                    f"block pattern of length {len(self.block_pattern)}")

    # ---- derived quantities -------------------------------------------------

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def with_split(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, split=dataclasses.replace(self.split, **kw))

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """The smoke-test variant: same family/block pattern, tiny dims."""
        pattern = self.block_pattern
        n_layers = len(pattern) if not self.enc_dec else 2
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = max(d_model // n_heads, 16)
        n_kv = min(self.n_kv_heads, n_heads)
        kw = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            swa_window=64,
            long_context_window=128,
            zero_sharding=False,
        )
        if self.enc_dec:
            kw["n_enc_layers"] = 2
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 128),
                d_shared=min(self.moe.d_shared, 128) if self.moe.d_shared else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk_size=32)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, chunk_size=32)
        return dataclasses.replace(self, **kw)

    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (analytic, for roofline MODEL_FLOPS).

        ``active_only`` counts only routed-expert params actually used per
        token (top_k of n_experts) — the MoE "active parameters" convention.
        """
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # lm head
        per_layer = {}

        def attn_params():
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

        def mlp_params(d_ff):
            if self.mlp in ("swiglu", "geglu"):
                return 3 * d * d_ff
            return 2 * d * d_ff

        for kind in set(self.block_pattern):
            if kind.startswith("attn") or kind == "shared_attn":
                p = attn_params()
                if self.moe is not None:
                    e = self.moe
                    routed = e.top_k if active_only else e.n_experts
                    p += routed * 3 * d * e.d_expert
                    p += e.n_shared * 3 * d * max(e.d_shared, e.d_expert)
                    p += d * e.n_experts  # router
                elif self.d_ff:
                    p += mlp_params(self.d_ff)
            elif kind == "mamba2":
                s = self.ssm
                d_in = s.expand * d
                p = d * (2 * d_in + 2 * s.n_groups * s.d_state) + d_in * d
                p += d_in  # dt, A, D etc. (order-d_in terms)
            elif kind in ("slstm", "mlstm"):
                x = self.xlstm
                f = x.m_proj_factor if kind == "mlstm" else x.s_proj_factor
                d_in = int(f * d)
                p = 2 * d * d_in + d_in * d + 4 * d_in * d_in // 4
            else:
                raise ValueError(kind)
            per_layer[kind] = p

        n_units = self.n_superblocks
        shared_counted = False
        for kind in self.block_pattern:
            if kind == "shared_attn":
                if not shared_counted:
                    total += per_layer[kind]  # params shared across reuses
                    shared_counted = True
            else:
                total += n_units * per_layer[kind]
        if self.enc_dec:
            # decoder layers: self-attn + cross-attn + mlp
            dec = self.n_layers * (2 * attn_params() + mlp_params(self.d_ff))
            total += dec
        return total


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
