"""The paper's own experiment config (Appendix B).

Dual-headed SplitNN on vertically-partitioned MNIST: each data owner holds
one image half (392 features) and an identical head mapping 392 -> 64 with
ReLU; the data scientist concatenates (128) and runs 128 -> 500 -> 10 with
softmax.  Owner lr 0.01, scientist lr 0.1, batch 128, 20k train images,
30 epochs.
"""
from dataclasses import dataclass, field
from typing import Tuple

from repro.configs.base import SplitConfig


@dataclass(frozen=True)
class MLPSplitConfig:
    name: str = "pyvertical-mnist"
    source: str = "PyVertical (2021), Appendix B"
    n_features: int = 784           # full flattened image
    n_classes: int = 10
    head_layers: Tuple[int, ...] = (64,)           # 392 -> 64 (ReLU)
    trunk_layers: Tuple[int, ...] = (500, 10)      # 128 -> 500 -> 10
    batch_size: int = 128
    n_train: int = 20_000
    epochs: int = 30
    # paper §5.1 future work: imbalanced vertical datasets — explicit
    # per-owner feature widths (must sum to n_features).  None = equal.
    feature_splits: Tuple[int, ...] = None
    split: SplitConfig = field(default_factory=lambda: SplitConfig(
        n_owners=2, cut_layer=1, combine="concat", cut_dim=64,
        owner_lr=0.01, scientist_lr=0.1))


CONFIG = MLPSplitConfig()
