"""gemma2-9b — local+global alternating attention, logit softcaps
[arXiv:2408.00118].

42 layers, d_model 3584, 16 heads (kv=8, head_dim 256), d_ff 14336,
vocab 256000.  GeGLU MLP, RMSNorm pre+post, attention-logit softcap 50,
final-logit softcap 30, 4096-token sliding window on local layers.
"""
from repro.configs.base import ArchConfig, SplitConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    source="arXiv:2408.00118",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    mlp="geglu",
    post_block_norm=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    swa_window=4096,
    tie_embeddings=True,
    block_pattern=("attn:local", "attn:global"),
    # local/SWA layers are native; long_500k runs with global layers
    # falling back to the sliding window (native-ish long-context story).
    long_context="native",
    long_context_window=4096,
    split=SplitConfig(n_owners=2, cut_layer=5),
)
