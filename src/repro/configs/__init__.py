"""Architecture config registry.

``get_config("zamba2-2.7b")`` returns the exact assigned config;
``get_config("zamba2-2.7b", reduced=True)`` returns the CPU smoke-test
variant of the same family.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, MoEConfig, SSMConfig, ShapeConfig,
                                SplitConfig, XLSTMConfig, SHAPES, TRAIN_4K,
                                PREFILL_32K, DECODE_32K, LONG_500K)

_ARCH_MODULES: Dict[str, str] = {
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "llama3-405b": "repro.configs.llama3_405b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(name: str, reduced: bool = False) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    cfg = importlib.import_module(_ARCH_MODULES[name]).CONFIG
    return cfg.reduced() if reduced else cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "XLSTMConfig", "SplitConfig",
    "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "get_config", "get_shape", "list_archs",
]
