"""llama3-405b — GQA, 128k vocab [arXiv:2407.21783].

126 layers, d_model 16384, 128 heads (kv=8), d_ff 53248, vocab 128256.
SwiGLU, RMSNorm, rope theta 500k.  ZeRO sharding over the data axis is
required at this scale.
"""
from repro.configs.base import ArchConfig, SplitConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    mlp="swiglu",
    rope_theta=500000.0,
    zero_sharding=True,
    # pure full attention: long_500k runs only under the explicit
    # sliding-window variant (window 8192), flagged in the roofline table.
    long_context="swa",
    long_context_window=8192,
    split=SplitConfig(n_owners=2, cut_layer=31),
)
