"""qwen2-vl-72b — M-RoPE, dynamic resolution [arXiv:2409.12191].

80 layers, d_model 8192, 64 heads (kv=8), d_ff 29568, vocab 152064.
Vision frontend (ViT + merger) is a STUB per the brief: owner 0 supplies
precomputed patch embeddings (d_frontend=1280) which the head projects to
d_model; owner 1 supplies text tokens.  M-RoPE 3-section rotary positions.
"""
from repro.configs.base import ArchConfig, SplitConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    mlp="swiglu",
    rope="mrope",
    rope_theta=1000000.0,
    modality="vision_text",
    d_frontend=1280,
    zero_sharding=True,
    long_context="swa",
    long_context_window=8192,
    split=SplitConfig(n_owners=2, cut_layer=20),
)
