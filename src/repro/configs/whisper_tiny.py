"""whisper-tiny — encoder-decoder with conv frontend stub [arXiv:2212.04356].

4 encoder + 4 decoder layers, d_model 384, 6 heads (kv=6), d_ff 1536,
vocab 51865.  The mel-spectrogram + conv feature extractor is a STUB per
the brief: owner 0 (the audio owner) supplies precomputed frame embeddings.
Encoder-decoder maps natively onto SplitNN: the encoder IS the owner head,
the decoder IS the scientist trunk, the cross-attention input IS the cut
tensor.  long_500k is skipped: Whisper's decoder context is architecturally
448 tokens and it has no sub-quadratic variant (see DESIGN.md).
"""
from repro.configs.base import ArchConfig, SplitConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=4,               # decoder layers
    n_enc_layers=4,
    enc_dec=True,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    mlp="gelu",
    norm="layernorm",
    rope="sincos",
    modality="audio_text",
    d_frontend=384,
    long_context="skip",
    split=SplitConfig(n_owners=1, cut_layer=4),  # head == whole encoder
)
