"""nemotron-4-15b — GQA, squared-ReLU MLP [arXiv:2402.16819].

32 layers, d_model 6144, 48 heads (kv=8), d_ff 24576, vocab 256000.
LayerNorm, squared-ReLU (non-gated) MLP, rotary positions.
"""
from repro.configs.base import ArchConfig, SplitConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    mlp="relu2",
    norm="layernorm",
    long_context="swa",
    long_context_window=8192,
    split=SplitConfig(n_owners=2, cut_layer=8),
)
