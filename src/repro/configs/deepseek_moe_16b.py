"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28 layers, d_model 2048, 16 heads (kv=16), expert hidden 1408,
vocab 102400.  Every block: attention + MoE FFN with 2 shared experts
(always on) and 64 routed experts, top-6 routing.
"""
from repro.configs.base import ArchConfig, MoEConfig, SplitConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    mlp="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408,
                  n_shared=2, d_shared=1408),
    long_context="swa",
    long_context_window=8192,
    split=SplitConfig(n_owners=2, cut_layer=7),
)
