"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 layers, d_model 768, 4 heads (kv=4), no separate FFN (d_ff=0: xLSTM
blocks carry their own up/down projections), vocab 50304.  Alternating
sLSTM/mLSTM units.
"""
from repro.configs.base import ArchConfig, XLSTMConfig, SplitConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    mlp="none",
    norm="layernorm",
    rope="none",
    block_pattern=("slstm", "mlstm"),
    xlstm=XLSTMConfig(),
    long_context="native",
    split=SplitConfig(n_owners=2, cut_layer=1),
)
