"""Synthetic datasets with unique subject IDs.

This container is offline, so MNIST itself is unavailable; we substitute a
class-conditional image-like dataset with the same geometry (28x28, 10
classes, 784 features) — "MNIST-like" — generated from per-class smooth
prototypes + noise.  Every experiment that the paper runs on MNIST runs on
this dataset; the claim being validated (the split framework trains to high
accuracy on vertically-partitioned image data) is dataset-shape-dependent,
not MNIST-pixel-dependent.  The substitution is recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import numpy as np

from repro.core.resolution import VerticalDataset
from repro.core.vertical import make_ids, partition_features, scatter_to_owners


def make_mnist_like(n: int, seed: int = 0, n_classes: int = 10,
                    side: int = 28) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images (n, side*side) float32 in [0,1], labels (n,) int32).

    Per-class prototype: smooth random low-frequency pattern (outer product
    of random sinusoids), plus per-sample noise and a random shift —
    linearly non-separable but easily learnable, like MNIST."""
    rng = np.random.default_rng(seed)
    xs = np.linspace(0, 1, side)
    protos = []
    for c in range(n_classes):
        fx, fy = rng.uniform(1, 4, 2)
        px, py = rng.uniform(0, np.pi, 2)
        img = np.outer(np.sin(2 * np.pi * fx * xs + px),
                       np.cos(2 * np.pi * fy * xs + py))
        img += rng.normal(0, 0.3, (side, side))
        protos.append(img)
    protos = np.stack(protos)                     # (C, side, side)
    labels = rng.integers(0, n_classes, n).astype(np.int32)
    shift = rng.integers(-2, 3, (n, 2))
    imgs = np.empty((n, side, side), np.float32)
    for i in range(n):
        p = np.roll(protos[labels[i]], shift[i], axis=(0, 1))
        imgs[i] = p + rng.normal(0, 0.22, (side, side))
    imgs = (imgs - imgs.min()) / (imgs.max() - imgs.min())
    return imgs.reshape(n, side * side).astype(np.float32), labels


def make_vertical_mnist_parties(n: int, n_owners: int = 2, seed: int = 0,
                                keep_frac: float = 0.9,
                                feature_splits=None):
    """The paper's Fig. 2 setup: images vertically split across owners
    (left/right halves for 2 owners), labels held by the data scientist.
    Owners hold random overlapping subject subsets in random order — PSI
    resolution is required before training.

    ``feature_splits`` (paper §5.1 future work, imbalanced verticals):
    explicit per-owner feature widths summing to the flattened feature
    dim — the flat 784 vector is cut at those points instead of the
    image axis, and ``n_owners`` is ignored in favor of its length.

    Returns (scientist VerticalDataset(labels), {owner: VerticalDataset}).
    """
    rng = np.random.default_rng(seed)
    X, y = make_mnist_like(n, seed)
    side = int(np.sqrt(X.shape[1]))
    if feature_splits is not None:
        halves = partition_features(X, list(feature_splits))
    elif side % n_owners == 0:
        # left/right halves ≡ contiguous feature slices of the (28, 28)
        # image
        halves = partition_features(X.reshape(n, side, side), n_owners)
    else:
        # owner counts that don't divide the image side (e.g. 8) split
        # the flattened vector instead — still contiguous equal slices
        halves = partition_features(X, n_owners)
    halves = [h.reshape(n, -1) for h in halves]
    ids = make_ids(n)
    owners_raw = scatter_to_owners(ids, halves, rng, keep_frac)
    scientist = VerticalDataset(ids, y)
    owners = {f"owner{i}": VerticalDataset(oid, od)
              for i, (oid, od) in enumerate(owners_raw)}
    return scientist, owners


def make_token_dataset(n_docs: int, seq_len: int, vocab: int, seed: int = 0):
    """Synthetic token streams with learnable structure (order-2 Markov
    chains with per-doc offsets) + subject IDs.  (n, seq_len+1) int32 —
    inputs are [:, :-1], labels [:, 1:]."""
    rng = np.random.default_rng(seed)
    toks = np.empty((n_docs, seq_len + 1), np.int64)
    for i in range(n_docs):
        t = np.empty(seq_len + 1, np.int64)
        t[0] = rng.integers(0, vocab)
        t[1] = rng.integers(0, vocab)
        # one GLOBAL order-2 transition (15% random restarts): the same
        # (t-1, t-2) context predicts the same next token everywhere, so
        # the LM loss floor is well below uniform entropy.
        for j in range(2, seq_len + 1):
            if rng.random() < 0.85:
                t[j] = (t[j - 1] * 31 + t[j - 2] * 7 + 11) % vocab
            else:
                t[j] = rng.integers(0, vocab)
        toks[i] = t
    return toks.astype(np.int32)


def batches(data: Dict[str, np.ndarray], batch_size: int, seed: int = 0,
            epochs: int = 1, drop_last: bool = True) -> Iterator[Dict]:
    """Shuffled mini-batch iterator over aligned arrays."""
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        stop = n - (n % batch_size) if drop_last else n
        for s in range(0, stop, batch_size):
            idx = order[s:s + batch_size]
            yield {k: v[idx] for k, v in data.items()}
