from repro.data.synthetic import (make_mnist_like, make_token_dataset,  # noqa
                                  batches, make_vertical_mnist_parties)
