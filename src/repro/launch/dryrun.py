import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
for the production meshes, with NO array allocation (ShapeDtypeStruct
stand-ins for params, optimizer state, caches and batches).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
        --shape train_4k [--multi-pod] [--trunk-dp-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Writes one JSON artifact per combo to experiments/dryrun/ containing
memory_analysis, cost_analysis and the parsed collective schedule — the
inputs of the §Roofline analysis.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, get_shape, list_archs
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build, shape_supported
from repro.sharding.specs import make_rules, named

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_one(arch: str, shape_name: str, multi_pod: bool,
            trunk_dp_over_pod: bool = False, out_dir: str = ART_DIR,
            tag: str = "", verbose: bool = True, n_microbatches: int = 1,
            ring_cache: bool = False, moe_groups: int = 0,
            capacity_factor: float = 0.0, opt_bf16: bool = False,
            cache_f8: bool = False):
    import dataclasses
    cfg = get_config(arch)
    if cfg.moe is not None and (moe_groups or capacity_factor):
        kw = {}
        if moe_groups:
            kw["dispatch_groups"] = moe_groups
        if capacity_factor:
            kw["capacity_factor"] = capacity_factor
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, **kw))
    shape = get_shape(shape_name)
    if not shape_supported(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": f"long_context={cfg.long_context} (DESIGN.md §3)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, cfg, trunk_dp_over_pod=trunk_dp_over_pod)
    import jax.numpy as jnp
    fn, args, specs, donate = build(
        cfg, shape, mesh, rules, n_microbatches=n_microbatches,
        ring_cache=ring_cache,
        opt_state_dtype=jnp.bfloat16 if opt_bf16 else jnp.float32,
        cache_dtype=jnp.float8_e4m3fn if cache_f8 else None)

    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=named(mesh, specs),
                      donate_argnums=donate).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = analysis.extract_memory(compiled)
    cost = analysis.extract_cost(compiled)
    txt = compiled.as_text()
    colls = analysis.collective_stats(
        txt, devices_per_pod=256 if multi_pod else 0)
    colls.pop("cross_pod_ops", None) if not multi_pod else None

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "trunk_dp_over_pod": trunk_dp_over_pod,
        "n_microbatches": n_microbatches,
        "status": "ok",
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": mem,
        "hbm_per_device_bytes": analysis.hbm_per_device(mem),
        "cost": cost,
        "collectives": {k: v for k, v in colls.items()
                        if k != "cross_pod_ops"},
        "cross_pod_ops_sample": colls.get("cross_pod_ops", [])[:8],
    }
    if verbose:
        print(f"[{rec['mesh']}] {arch} x {shape_name}"
              f"{' +trunk_dp_pod' if trunk_dp_over_pod else ''}: "
              f"compile {rec['compile_s']}s, "
              f"HBM/dev {rec['hbm_per_device_bytes']/2**30:.2f} GiB, "
              f"flops {cost['flops']:.3e}, "
              f"coll {colls['total_bytes']/2**20:.1f} MiB"
              + (f" (cross-pod {colls['cross_pod_bytes']/2**20:.1f} MiB)"
                 if multi_pod else ""))
        print("  memory_analysis:", mem)
        print("  cost_analysis:", cost)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "_tdp" if trunk_dp_over_pod else ""
        tagp = f"_{tag}" if tag else ""
        fn_out = os.path.join(
            out_dir, f"{arch}_{shape_name}_{rec['mesh']}{suffix}{tagp}.json")
        with open(fn_out, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--trunk-dp-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ring-cache", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--opt-bf16", action="store_true")
    ap.add_argument("--cache-f8", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                try:
                    run_one(a, s, mp, args.trunk_dp_pod, args.out,
                            args.tag, n_microbatches=args.microbatches,
                            ring_cache=args.ring_cache,
                            moe_groups=args.moe_groups,
                            capacity_factor=args.capacity_factor,
                            opt_bf16=args.opt_bf16,
                            cache_f8=args.cache_f8)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    failures.append((a, s, mp, str(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
