"""Compiled-HLO analysis: collective traffic + roofline inputs.

``cost_analysis()`` gives HLO FLOPs / bytes, but NOT collective bytes —
those are recovered by parsing the post-SPMD compiled module text, where
shapes are already per-device: the result shape of each all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute is (a good
proxy for) the bytes that land on each device.

Cross-pod detection: on the (pod, data, model) mesh device ids are
pod-major (id // 256 = pod), so any replica group or source-target pair
mixing id//256 values crosses the pod boundary — the PyVertical party
boundary.  C4 requires those to be cut-layer (or scientist-internal
trunk-DP) collectives only.
"""
from __future__ import annotations

import re
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>[^=]*?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
    re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,{} ]*\})\}")
# iota form: replica_groups=[G,N]<=[512] or <=[2,16,16]T(1,0,2)
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+(?:,\d+)*)\]<=\[(\d+(?:,\d+)*)\]"
    r"(?:T\((\d+(?:,\d+)*)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


def _iota_groups(groups_shape, src_shape, perm):
    """Materialize device-id groups from the iota replica-group form."""
    import numpy as np
    ids = np.arange(int(np.prod(src_shape))).reshape(src_shape)
    if perm is not None:
        ids = ids.transpose(perm)
    return ids.reshape(groups_shape)


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, devices_per_pod: int = 0) -> Dict:
    """Sum per-device collective bytes by op kind; flag cross-pod ops."""
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    cross_pod_bytes = 0
    cross_pod_ops: List[str] = []
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:        # async pair: count only the start
            continue
        op, result = m.group("op"), m.group("result")
        b = shape_bytes(result)
        by_kind[op] += b
        n_ops += 1
        if devices_per_pod:
            crosses = False
            g = _GROUPS_RE.search(line)
            if g:
                for grp in re.findall(r"\{([0-9, ]+)\}", g.group(0)):
                    pods = {int(x) // devices_per_pod
                            for x in grp.replace(" ", "").split(",") if x}
                    if len(pods) > 1:
                        crosses = True
                        break
            gi = _IOTA_RE.search(line)
            if gi and not crosses:
                gshape = [int(x) for x in gi.group(1).split(",")]
                sshape = [int(x) for x in gi.group(2).split(",")]
                perm = ([int(x) for x in gi.group(3).split(",")]
                        if gi.group(3) else None)
                try:
                    groups = _iota_groups(gshape, sshape, perm)
                    pods = groups // devices_per_pod
                    if (pods.min(axis=-1) != pods.max(axis=-1)).any():
                        crosses = True
                except Exception:   # noqa: BLE001 — malformed: be loud
                    crosses = True
            p = _PAIRS_RE.search(line)
            if p:
                for a, bb in re.findall(r"\{(\d+),(\d+)\}", p.group(0)):
                    if int(a) // devices_per_pod != int(bb) // devices_per_pod:
                        crosses = True
                        break
            if crosses:
                cross_pod_bytes += b
                cross_pod_ops.append(line.strip()[:160])
    total = sum(by_kind.values())
    return {"per_kind_bytes": by_kind, "total_bytes": total,
            "n_ops": n_ops, "cross_pod_bytes": cross_pod_bytes,
            "cross_pod_ops": cross_pod_ops}


def extract_cost(compiled) -> Dict:
    ca = compiled.cost_analysis() or {}
    # jax cost_analysis returns a dict (sometimes list of dicts)
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def extract_memory(compiled) -> Dict:
    ms = compiled.memory_analysis()
    if ms is None:
        return {}
    return {
        "argument_bytes": ms.argument_size_in_bytes,
        "output_bytes": ms.output_size_in_bytes,
        "temp_bytes": ms.temp_size_in_bytes,
        "alias_bytes": ms.alias_size_in_bytes,
        "code_bytes": ms.generated_code_size_in_bytes,
    }


def hbm_per_device(mem: Dict) -> int:
    """Live bytes per device: args + temps + outputs - donated aliases."""
    if not mem:
        return 0
    return (mem["argument_bytes"] + mem["temp_bytes"]
            + mem["output_bytes"] - mem["alias_bytes"])
