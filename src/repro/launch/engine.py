"""Split-inference serving engine: request queue + wave batching.

A deployer-facing layer over ``SplitModel.prefill``/``decode_step``:
requests are queued, admitted in waves of ``batch_slots``, prefilled
together through the owner heads (each owner contributes its vertical
slice of every request's context), then decoded in lockstep until every
request in the wave hits ``max_new`` or an EOS token.  Static shapes
throughout (one compile per engine), per-wave padding, throughput
accounting.

This is the serving analogue of the paper's training protocol: context
slices stay with their owners; only cut activations reach the scientist,
who alone sees the generated text.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation import batching, transport as transport_mod
from repro.models.model import SplitModel


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (ctx,) int32 — the combined context
    max_new: int = 16


@dataclass
class Result:
    rid: int
    generated: List[int] = field(default_factory=list)
    latency_s: float = 0.0


class ServingEngine:
    def __init__(self, model: SplitModel, params, *, batch_slots: int = 4,
                 ctx_len: int = 128, max_new: int = 32,
                 eos_token: Optional[int] = None, ring_cache: bool = False,
                 pad_token: int = 0, transport: Optional[str] = None,
                 latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None):
        """``transport`` ("direct" | "queue" | "process") routes every
        cut activation through a real ``federation.transport`` channel:
        prefill and decode run as separate owner/scientist segment
        programs and ``stats`` reports *measured* cut bytes off the wire
        instead of the analytic ``cut_layer_traffic`` estimate
        ("process" carries the frames over a real OS pipe —
        ``federation.process_transport`` — with identical byte
        accounting)."""
        cfg = model.cfg
        if cfg.modality != "text":
            raise ValueError("ServingEngine drives text archs")
        self.model, self.params = model, params
        self.B, self.S, self.max_new = batch_slots, ctx_len, max_new
        self.P = cfg.split.n_owners
        self.eos = eos_token
        self.pad = pad_token
        self.ring = ring_cache
        self._queue: List[Request] = []
        self._next_rid = 0
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._ep_owner = self._ep_sci = None
        if transport is not None:
            if cfg.enc_dec:
                raise ValueError("transport-backed serving supports "
                                 "decoder-only text archs")
            if transport == "process":
                from repro.federation.process_transport import \
                    process_endpoint_pair
                self._ep_owner, self._ep_sci = process_endpoint_pair(
                    "owners", "scientist", latency_s=latency_s,
                    bandwidth_bps=bandwidth_bps)
            else:
                self._ep_owner, self._ep_sci = transport_mod.channel_pair(
                    "owners", "scientist", backend=transport,
                    latency_s=latency_s, bandwidth_bps=bandwidth_bps)
            self._prefill_heads = jax.jit(model.prefill_heads)
            self._prefill_trunk = jax.jit(model.prefill_trunk)
            self._decode_heads = jax.jit(model.decode_heads)
            self._decode_trunk = jax.jit(model.decode_trunk)
        self.stats = {"waves": 0, "requests": 0, "tokens_generated": 0,
                      "wall_s": 0.0, "cut_payload_bytes": 0,
                      "cut_wire_bytes": 0, "cut_messages": 0}

    def submit(self, tokens, max_new: Optional[int] = None) -> int:
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) > self.S:
            raise ValueError(f"context {len(tokens)} > engine ctx {self.S}")
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, tokens, max_new or self.max_new))
        return rid

    def _ship_cut(self, cut_arrays) -> jnp.ndarray:
        """Route cut activations through the owner->scientist channel
        (the measured boundary) and return the scientist-side tensor."""
        for i, c in enumerate(cut_arrays):
            self._ep_owner.send("cut_activations", {"cut": np.asarray(c)},
                                seq=i)
        out = [self._ep_sci.recv_kind("cut_activations").payload["cut"]
               for _ in cut_arrays]
        return jnp.asarray(np.stack(out)) if len(out) > 1 \
            else jnp.asarray(out[0])

    def _split_prefill(self, owner_tokens, caches):
        cut, head_caches = self._prefill_heads(
            self.params["heads"], owner_tokens, caches["heads"])
        cut = self._ship_cut([cut[p] for p in range(self.P)])
        logits, trunk_caches = self._prefill_trunk(
            self.params["trunk"], cut, caches["trunk"])
        return logits, {"heads": head_caches, "trunk": trunk_caches}

    def _split_decode(self, caches, tok, pos, pos_local):
        z, head_caches = self._decode_heads(
            self.params["heads"], tok, caches["heads"], pos_local)
        z = self._ship_cut([z])          # only the generation owner's slice
        logits, trunk_caches = self._decode_trunk(
            self.params["trunk"], z, caches["trunk"], pos)
        return logits, {"heads": head_caches, "trunk": trunk_caches}

    def _run_wave(self, wave: List[Request]) -> List[Result]:
        t0 = time.time()
        B, S = self.B, self.S
        # serving layout (federation/batching.py): left-pad for recency,
        # then the standard (P, B, S_p) sequence-slice partition
        toks = batching.pad_contexts([r.tokens for r in wave], B, S,
                                     pad=self.pad, pad_side="left")
        caches = self.model.cache_init(B, S, n_new=self.max_new + 1,
                                       ring=self.ring)
        owner_tokens = batching.serving_owner_slices(toks, self.P)
        if self._ep_owner is not None:
            logits, caches = self._split_prefill(owner_tokens, caches)
        else:
            logits, caches = self._prefill(
                self.params, {"owner_tokens": owner_tokens}, caches)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

        results = [Result(r.rid) for r in wave]
        done = np.zeros(B, bool)
        done[len(wave):] = True                      # empty slots
        for t in range(self.max_new):
            tk = np.asarray(tok[:, 0])
            appended = 0
            for i, r in enumerate(wave):
                if not done[i]:
                    results[i].generated.append(int(tk[i]))
                    appended += 1
                    if (self.eos is not None and tk[i] == self.eos) or \
                            len(results[i].generated) >= r.max_new:
                        done[i] = True
            self.stats["tokens_generated"] += appended
            if done.all() or t == self.max_new - 1:
                break
            if self._ep_owner is not None:
                logits, caches = self._split_decode(caches, tok, S + t,
                                                    S // self.P + t)
            else:
                logits, caches = self._decode(self.params, caches, tok,
                                              S + t, S // self.P + t)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        dt = time.time() - t0
        for res in results:
            res.latency_s = dt
        self.stats["waves"] += 1
        self.stats["requests"] += len(wave)
        self.stats["wall_s"] += dt
        if self._ep_owner is not None:
            st = self._ep_sci.recv_stats["by_kind"].get(
                "cut_activations", {})
            self.stats["cut_payload_bytes"] = st.get("payload_bytes", 0)
            self.stats["cut_wire_bytes"] = st.get("wire_bytes", 0)
            self.stats["cut_messages"] = st.get("count", 0)
        return results

    def run(self) -> Dict[int, Result]:
        """Drain the queue; returns {request_id: Result}."""
        out: Dict[int, Result] = {}
        while self._queue:
            wave, self._queue = (self._queue[:self.B], self._queue[self.B:])
            for res in self._run_wave(wave):
                out[res.rid] = res
        return out
