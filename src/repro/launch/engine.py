"""Split-inference serving: wave + continuous batching over the party
boundary, session multiplexing, and a repeat-entity cut cache.

A deployer-facing layer over ``SplitModel.prefill``/``decode_step``.
Two schedulers share one engine:

  * ``scheduler="wave"`` — the original drain-by-waves path: requests
    are admitted in waves of ``batch_slots``, prefilled together, then
    decoded in lockstep until every request in the wave hits ``max_new``
    or EOS.  One scalar decode position per wave.
  * ``scheduler="continuous"`` — slot-level admission: when a request
    hits EOS/``max_new`` its slot is freed and refilled from the queue
    on the next tick via a per-slot prefill (full-batch shaped, so the
    engine still compiles exactly two programs), and decode runs with a
    *per-slot* position vector (a ``vmap`` of the single-row decode
    step, bit-identical to the batch program — property-tested).
    Throughput tracks active slots instead of the slowest request in a
    wave; refill prefill ships share the decode ship's latency window.

Serving is the inference analogue of the paper's training protocol:
context slices stay with their owners; only cut activations reach the
scientist, who alone sees the generated text.  With a ``transport``
backend the cut tensors are real wire payloads (measured bytes,
injected latency, optional fp16/int8 codec — ``federation.transport``).

The **repeat-entity cut cache** (:class:`CutCache`) keys a request's
padded context by its sha256 content tag (the PR 5 blind-upload dedup
trick applied to serving): a returning entity's admission restores the
owner-head and trunk KV rows plus first-token logits from the cache —
zero head recompute and zero cut-upload bytes, recorded in the engine
``transcript``.  Cached rows are bitwise what a fresh prefill would
produce (prefill is row-independent), so cache hits preserve the
greedy-decode bit-identity guarantee.

**Session multiplexing** (:class:`ServingService`): many engine
sessions share one owner<->scientist channel pair, each session's
frames kind-scoped through ``transport.ScopedEndpoint`` (``"s3:"`` +
kind), with a service-wide shared cut cache.  Admission control is a
bounded queue per session (``max_queue``): ``submit`` raises
:class:`QueueFull` and counts the rejection in backpressure stats.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation import batching, transport as transport_mod
from repro.models.model import SplitModel

__all__ = ["Request", "Result", "ServingEngine", "ServingService",
           "CutCache", "QueueFull", "CUT_DECODE_KIND", "CUT_PREFILL_KIND",
           "ADMIT_KIND"]

#: protocol kinds on the serving boundary (docs/WIRE_PROTOCOL.md)
CUT_DECODE_KIND = "cut_activations"   # per-tick decode cut slices
CUT_PREFILL_KIND = "cut_prefill"      # admission-time context cut rows
ADMIT_KIND = "admit"                  # slot-layout control frame
_CUT_KINDS = (CUT_DECODE_KIND, CUT_PREFILL_KIND)


class QueueFull(RuntimeError):
    """Admission rejected: the bounded request queue is at capacity.

    Carries the backpressure signal the caller needs to do something
    smarter than blind retry: ``queue_depth`` (how deep the queue was at
    rejection) and ``retry_after_s`` (the engine's mean per-request
    service time — a principled retry interval)."""

    def __init__(self, message: str, *, queue_depth: int = 0,
                 retry_after_s: float = 0.0):
        super().__init__(message)
        self.queue_depth = queue_depth
        self.retry_after_s = retry_after_s


@dataclass
class Request:
    rid: int
    tokens: np.ndarray            # (ctx,) int32 — the combined context
    max_new: int = 16
    submit_t: float = 0.0         # wall-clock at submit (latency anchor)
    tag: Optional[str] = None     # content tag of the padded context


@dataclass
class Result:
    rid: int
    generated: List[int] = field(default_factory=list)
    latency_s: float = 0.0        # submit -> finish (queueing + compute)
    error: Optional[str] = None   # set when the request failed (degraded
    #                               service: the engine survives, the
    #                               caller sees a per-request error)


class CutCache:
    """Repeat-entity cut cache: padded-context content tag -> the
    prefill artifacts both parties would otherwise recompute and ship.

    An entry stores the owner-side head KV rows, the scientist-side
    trunk KV rows, and the first-token logits row for one request slot.
    Entries are only valid for the exact engine geometry + codec that
    stored them, so the tag is prefixed with those fields by the engine.
    LRU-bounded (``max_entries``); eviction means a returning entity
    pays one fresh prefill again — correctness is unaffected.
    Thread-safe (shared across a service's sessions)."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._d: "OrderedDict[str, dict]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, tag: str) -> Optional[dict]:
        with self._lock:
            entry = self._d.get(tag)
            if entry is not None:
                self._d.move_to_end(tag)
                self.hits += 1
            else:
                self.misses += 1
            return entry

    def put(self, tag: str, entry: dict) -> None:
        with self._lock:
            self._d[tag] = entry
            self._d.move_to_end(tag)
            while len(self._d) > self.max_entries:
                self._d.popitem(last=False)
                self.evictions += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


class ServingEngine:
    def __init__(self, model: SplitModel, params, *, batch_slots: int = 4,
                 ctx_len: int = 128, max_new: int = 32,
                 eos_token: Optional[int] = None, ring_cache: bool = False,
                 pad_token: int = 0, transport: Optional[str] = None,
                 latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 scheduler: str = "wave",
                 compression: Optional[str] = None,
                 max_queue: Optional[int] = None,
                 cut_cache=None,
                 endpoints: Optional[Tuple] = None):
        """``transport`` ("direct" | "queue" | "process") routes every
        cut activation through a real ``federation.transport`` channel:
        prefill and decode run as separate owner/scientist segment
        programs and ``stats`` reports *measured* cut bytes off the wire
        ("process" carries the frames over a real OS pipe).

        ``scheduler`` picks wave or continuous batching (module doc);
        ``compression`` applies a cut codec ("fp16" | "int8") on the
        wire; ``max_queue`` bounds the admission queue (``submit``
        raises :class:`QueueFull` beyond it); ``cut_cache`` enables the
        repeat-entity cache (``True`` for a private one, or a shared
        :class:`CutCache`); ``endpoints`` injects a pre-built
        (owner, scientist) endpoint pair — how :class:`ServingService`
        multiplexes sessions onto one channel."""
        cfg = model.cfg
        if cfg.modality != "text":
            raise ValueError("ServingEngine drives text archs")
        if scheduler not in ("wave", "continuous"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.model, self.params = model, params
        self.B, self.S, self.max_new = batch_slots, ctx_len, max_new
        self.P = cfg.split.n_owners
        self.eos = eos_token
        self.pad = pad_token
        self.ring = ring_cache
        self.scheduler = scheduler
        self.max_queue = max_queue
        self._codec = transport_mod.get_codec(compression)
        self._cut_dtype = None        # model cut dtype, seen at first ship
        if cut_cache is True:
            cut_cache = CutCache()
        # explicit None-check: an *empty* CutCache is falsy (len 0)
        self.cut_cache: Optional[CutCache] = (
            cut_cache if isinstance(cut_cache, CutCache) else None)
        self._queue: List[Request] = []
        self._next_rid = 0
        self._tick = 0
        #: protocol-event log: (event, rid, detail) tuples — admissions,
        #: refills, cache hits/stores.  The bench and CI smoke assert
        #: against this (e.g. a repeat entity must log "cut_cache_hit").
        self.transcript: List[Tuple] = []
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)
        self._vdecode = jax.jit(self._vdecode_fn)
        self._ep_owner = self._ep_sci = None
        self._owns_endpoints = False
        if endpoints is not None:
            self._ep_owner, self._ep_sci = endpoints
        elif transport is not None:
            if transport == "process":
                from repro.federation.process_transport import \
                    process_endpoint_pair
                self._ep_owner, self._ep_sci = process_endpoint_pair(
                    "owners", "scientist", latency_s=latency_s,
                    bandwidth_bps=bandwidth_bps)
            else:
                self._ep_owner, self._ep_sci = transport_mod.channel_pair(
                    "owners", "scientist", backend=transport,
                    latency_s=latency_s, bandwidth_bps=bandwidth_bps)
            self._owns_endpoints = True
        if self._ep_owner is not None:
            if cfg.enc_dec:
                raise ValueError("transport-backed serving supports "
                                 "decoder-only text archs")
            self._prefill_heads = jax.jit(model.prefill_heads)
            self._prefill_trunk = jax.jit(model.prefill_trunk)
            self._decode_heads = jax.jit(model.decode_heads)
            self._decode_trunk = jax.jit(model.decode_trunk)
            self._vdec_heads = jax.jit(self._vdec_heads_fn)
            self._vdec_trunk = jax.jit(self._vdec_trunk_fn)
        # cache-row plumbing: masked scatter for refilled slots (one
        # compile — slot choice is data, not shape) and single-row
        # gather/set for cut-cache entries.  Trunk cache leaves are
        # (n_units, B, ...) — batch axis 1; head leaves carry a leading
        # owner dim, (P, n_units, B, ...) — batch axis 2.
        self._scatter_trunk = jax.jit(lambda live, fresh, m: jax.tree.map(
            lambda a, b: jnp.where(
                m.reshape((1, -1) + (1,) * (a.ndim - 2)), b, a),
            live, fresh))
        self._scatter_heads = jax.jit(lambda live, fresh, m: jax.tree.map(
            lambda a, b: jnp.where(
                m.reshape((1, 1, -1) + (1,) * (a.ndim - 3)), b, a),
            live, fresh))
        self._get_trunk_row = jax.jit(
            lambda tc, i: jax.tree.map(lambda a: a[:, i], tc))
        self._get_heads_row = jax.jit(
            lambda hc, i: jax.tree.map(lambda a: a[:, :, i], hc))
        self._set_trunk_row = jax.jit(lambda tc, row, i: jax.tree.map(
            lambda a, r: a.at[:, i].set(r), tc, row))
        self._set_heads_row = jax.jit(lambda hc, row, i: jax.tree.map(
            lambda a, r: a.at[:, :, i].set(r), hc, row))
        self.stats = {"waves": 0, "requests": 0, "tokens_generated": 0,
                      "wall_s": 0.0, "cut_payload_bytes": 0,
                      "cut_wire_bytes": 0, "cut_messages": 0,
                      "ticks": 0, "slot_refills": 0, "prefill_calls": 0,
                      "cut_cache_hits": 0,
                      "submitted": 0, "rejected": 0,
                      "peak_queue_depth": 0, "failed_requests": 0}
        self._cut_seen = (0, 0, 0)    # consumed (payload, wire, count)

    # --------------------------------------------------- vmapped programs
    #
    # Continuous batching needs a per-slot decode position (slots are
    # admitted at different ticks).  Each program below vmaps the B=1
    # decode over the cache batch axis with per-slot position vectors;
    # the mapped axis is re-inserted inside (the transformer's KV update
    # hardcodes a (B, s, nkv, hd) cache).  The result is bit-identical
    # to the scalar-position batch program (tests/test_engine.py).

    def _vdecode_fn(self, params, caches, tok, pos, pos_local):
        def one(tc, hc, tk, p, pl):
            cs = {"heads": jax.tree.map(lambda a: a[:, :, None], hc),
                  "trunk": jax.tree.map(lambda a: a[:, None], tc)}
            l, nc = self.model.decode_step(params, cs, tk[None], p, pl)
            return (l[0],
                    jax.tree.map(lambda a: a[:, 0], nc["trunk"]),
                    jax.tree.map(lambda a: a[:, :, 0], nc["heads"]))
        return jax.vmap(one, in_axes=(1, 2, 0, 0, 0), out_axes=(0, 1, 2))(
            caches["trunk"], caches["heads"], tok, pos, pos_local)

    def _vdec_heads_fn(self, heads, hc, tok, pos_local):
        def one(hc1, tk, pl):
            h2 = jax.tree.map(lambda a: a[:, :, None], hc1)
            z, nhc = self.model.decode_heads(heads, tk[None], h2, pl)
            return z[0], jax.tree.map(lambda a: a[:, :, 0], nhc)
        return jax.vmap(one, in_axes=(2, 0, 0), out_axes=(0, 2))(
            hc, tok, pos_local)

    def _vdec_trunk_fn(self, trunk, z, tc, pos):
        def one(tc1, z1, p):
            t2 = jax.tree.map(lambda a: a[:, None], tc1)
            l, ntc = self.model.decode_trunk(trunk, z1[None], t2, p)
            return l[0], jax.tree.map(lambda a: a[:, 0], ntc)
        return jax.vmap(one, in_axes=(1, 0, 0), out_axes=(0, 1))(
            tc, z, pos)

    # ------------------------------------------------------------ admission

    def _retry_after(self) -> float:
        """Mean per-request service time — the backpressure hint shipped
        inside :class:`QueueFull` (0.05 s floor before any request has
        completed)."""
        done = self.stats["requests"]
        return (self.stats["wall_s"] / done) if done else 0.05

    def submit(self, tokens, max_new: Optional[int] = None, *,
               block: bool = False, timeout: Optional[float] = None) -> int:
        """Queue one request.  When a bounded queue is at capacity:
        ``block=False`` (default) raises :class:`QueueFull` carrying
        ``queue_depth``/``retry_after_s`` and counts the rejection in
        ``stats["rejected"]``; ``block=True`` waits (capped-backoff
        polling, at most ``timeout`` seconds, forever when ``None``) for
        another thread to drain the queue before giving up the same
        way."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) > self.S:
            raise ValueError(f"context {len(tokens)} > engine ctx {self.S}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            pause = 0.005
            while block and len(self._queue) >= self.max_queue:
                if deadline is not None and time.monotonic() >= deadline:
                    break
                time.sleep(pause if deadline is None else
                           min(pause, max(0.0,
                                          deadline - time.monotonic())))
                pause = min(pause * 2, 0.25)
            if len(self._queue) >= self.max_queue:
                self.stats["rejected"] += 1
                raise QueueFull(
                    f"admission queue at capacity ({self.max_queue})",
                    queue_depth=len(self._queue),
                    retry_after_s=self._retry_after())
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, tokens,
                                   min(max_new or self.max_new,
                                       self.max_new),
                                   submit_t=time.time()))
        self.stats["submitted"] += 1
        self.stats["peak_queue_depth"] = max(
            self.stats["peak_queue_depth"], len(self._queue))
        return rid

    # ------------------------------------------------------- cut shipping

    def _encode_cut(self, arr) -> Dict[str, np.ndarray]:
        arr = np.asarray(arr)
        if self._cut_dtype is None:
            self._cut_dtype = arr.dtype
        return self._codec.encode(arr)

    def _decode_cut(self, payload) -> jnp.ndarray:
        x = jnp.asarray(self._codec.decode(payload))
        if self._codec.name != "none" and self._cut_dtype is not None:
            # lossy codecs decode to f32; restore the model's cut dtype
            # so the trunk program signature is codec-independent
            x = x.astype(self._cut_dtype)
        return x

    def _ship_cut(self, cut_arrays, kind: str = CUT_DECODE_KIND
                  ) -> jnp.ndarray:
        """Route cut activations through the owner->scientist channel
        (the measured boundary) and return the scientist-side tensor."""
        for i, c in enumerate(cut_arrays):
            self._ep_owner.send(kind, self._encode_cut(c), seq=i)
        out = [self._decode_cut(self._ep_sci.recv_kind(kind).payload)
               for _ in cut_arrays]
        return jnp.stack(out) if len(out) > 1 else out[0]

    def _drain_cut_stats(self) -> None:
        """Fold the channel's cut-kind totals into ``stats`` as
        *deltas* — the engine's numbers accumulate per-engine work even
        when the endpoint is shared or long-lived (regression-tested
        against ``recv_stats["by_kind"]``)."""
        if self._ep_sci is None:
            return
        bk = self._ep_sci.recv_stats["by_kind"]
        tot = [0, 0, 0]
        for kind in _CUT_KINDS:
            st = bk.get(kind, {})
            tot[0] += st.get("payload_bytes", 0)
            tot[1] += st.get("wire_bytes", 0)
            tot[2] += st.get("count", 0)
        seen = self._cut_seen
        self.stats["cut_payload_bytes"] += tot[0] - seen[0]
        self.stats["cut_wire_bytes"] += tot[1] - seen[1]
        self.stats["cut_messages"] += tot[2] - seen[2]
        self._cut_seen = tuple(tot)

    # ------------------------------------------------------ wave scheduler

    def _split_prefill(self, owner_tokens, caches):
        cut, head_caches = self._prefill_heads(
            self.params["heads"], owner_tokens, caches["heads"])
        self.stats["prefill_calls"] += 1
        cut = self._ship_cut([cut[p] for p in range(self.P)],
                             CUT_DECODE_KIND)
        logits, trunk_caches = self._prefill_trunk(
            self.params["trunk"], cut, caches["trunk"])
        return logits, {"heads": head_caches, "trunk": trunk_caches}

    def _split_decode(self, caches, tok, pos, pos_local):
        z, head_caches = self._decode_heads(
            self.params["heads"], tok, caches["heads"], pos_local)
        z = self._ship_cut([z])          # only the generation owner's slice
        logits, trunk_caches = self._decode_trunk(
            self.params["trunk"], z, caches["trunk"], pos)
        return logits, {"heads": head_caches, "trunk": trunk_caches}

    def _run_wave(self, wave: List[Request]) -> List[Result]:
        t0 = time.time()
        B, S = self.B, self.S
        # serving layout (federation/batching.py): left-pad for recency,
        # then the standard (P, B, S_p) sequence-slice partition
        toks = batching.pad_contexts([r.tokens for r in wave], B, S,
                                     pad=self.pad, pad_side="left")
        caches = self.model.cache_init(B, S, n_new=self.max_new + 1,
                                       ring=self.ring)
        owner_tokens = batching.serving_owner_slices(toks, self.P)
        if self._ep_owner is not None:
            logits, caches = self._split_prefill(owner_tokens, caches)
        else:
            logits, caches = self._prefill(
                self.params, {"owner_tokens": owner_tokens}, caches)
            self.stats["prefill_calls"] += 1
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

        results = [Result(r.rid) for r in wave]
        done = np.zeros(B, bool)
        done[len(wave):] = True                      # empty slots
        for t in range(self.max_new):
            tk = np.asarray(tok[:, 0])
            appended = 0
            now = time.time()
            for i, r in enumerate(wave):
                if not done[i]:
                    results[i].generated.append(int(tk[i]))
                    appended += 1
                    if (self.eos is not None and tk[i] == self.eos) or \
                            len(results[i].generated) >= r.max_new:
                        done[i] = True
                        results[i].latency_s = now - r.submit_t
            self.stats["tokens_generated"] += appended
            if done.all() or t == self.max_new - 1:
                break
            if self._ep_owner is not None:
                logits, caches = self._split_decode(caches, tok, S + t,
                                                    S // self.P + t)
            else:
                logits, caches = self._decode(self.params, caches, tok,
                                              S + t, S // self.P + t)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        now = time.time()
        for r, res in zip(wave, results):
            if res.latency_s == 0.0:     # hit the max_new ceiling
                res.latency_s = now - r.submit_t
        self.stats["waves"] += 1
        self.stats["requests"] += len(wave)
        self.stats["wall_s"] += now - t0
        self._drain_cut_stats()
        return results

    # ------------------------------------------------ continuous scheduler

    def _entity_tag(self, row: np.ndarray) -> str:
        """Cache key = content tag x everything that changes the stored
        rows bit-for-bit: geometry, codec, and which prefill program
        (fused vs transport-split) produced them."""
        path = "t" if self._ep_owner is not None else "l"
        return (f"{self.B}x{self.S}+{self.max_new}:{int(self.ring)}:"
                f"{path}:{self._codec.name}:{batching.context_tag(row)}")

    def _admit(self, free: List[int]) -> List[Tuple[int, Request, dict]]:
        """Pop up to ``len(free)`` queued requests into free slots.
        Returns [(slot, request, cache_entry_or_None)] and logs the
        admission; the caller runs the prefill/restore."""
        admitted = []
        refill = self._tick > 0
        for slot in free:
            if not self._queue:
                break
            req = self._queue.pop(0)
            row = batching.pad_context_row(req.tokens, self.S,
                                           pad=self.pad)
            req.tag = self._entity_tag(row)
            # "is not None", not truthiness: an EMPTY CutCache is falsy
            # (__len__) but must still count its misses
            entry = (self.cut_cache.get(req.tag)
                     if self.cut_cache is not None else None)
            admitted.append((slot, req, entry, row))
            event = "refill" if refill else "admit"
            self.transcript.append((event, req.rid, slot, self._tick))
            if refill:
                self.stats["slot_refills"] += 1
            if entry is not None:
                self.stats["cut_cache_hits"] += 1
                self.transcript.append(
                    ("cut_cache_hit", req.rid, req.tag[-16:]))
        return admitted

    def _refill_send(self, admitted, caches) -> Optional[dict]:
        """Owner half of an admission: fresh full-batch-shaped head
        prefill with the admitted contexts in their slot rows, cut rows
        for exactly those slots shipped, and the fresh head KV rows
        masked-scattered into the live caches (prefill is
        row-independent, so each admitted row is bitwise what a
        dedicated prefill would produce).  Cache hits skip the prefill
        for their row (all-pad filler; all-cached admissions skip it
        entirely — the control frame is the only thing on the wire).
        Called *after* the tick's decode ship is sent, so both ships
        share one injected-latency window."""
        B, S, P = self.B, self.S, self.P
        fresh_slots = [(s, r) for s, r, e, _ in admitted if e is None]
        if not fresh_slots:
            if self._ep_owner is not None and admitted:
                idx = np.asarray([s for s, _, _, _ in admitted], np.int32)
                self._ep_owner.send(ADMIT_KIND, {
                    "slots": idx, "cached": np.ones(len(idx), np.uint8)})
            return None

        ctx = np.full((B, S), self.pad, np.int32)
        for (slot, req, entry, row) in admitted:
            if entry is None:
                ctx[slot] = row
        fresh = self.model.cache_init(B, S, n_new=self.max_new + 1,
                                      ring=self.ring)
        owner_tokens = batching.serving_owner_slices(ctx, P)
        idx = np.asarray([s for s, _ in fresh_slots], np.int64)
        mask = np.zeros(B, bool)
        mask[idx] = True
        ship = {"fresh": fresh, "idx": idx, "mask": jnp.asarray(mask),
                "fresh_slots": fresh_slots}

        if self._ep_owner is not None:
            cut, fresh_hc = self._prefill_heads(
                self.params["heads"], owner_tokens, fresh["heads"])
            self.stats["prefill_calls"] += 1
            # ship only the admitted rows' cut slices; the scientist
            # scatters them into an all-zero buffer (row independence:
            # filler rows never touch admitted rows' results)
            cut_h = np.asarray(cut)
            self._ep_owner.send(ADMIT_KIND, {
                "slots": idx.astype(np.int32),
                "cached": np.zeros(len(idx), np.uint8)})
            for p in range(P):
                self._ep_owner.send(CUT_PREFILL_KIND,
                                    self._encode_cut(cut_h[p, idx]),
                                    seq=p)
            ship["cut_shape"] = cut_h.shape
            ship["cut_dtype"] = cut_h.dtype
            ship["fresh_hc"] = fresh_hc
            caches["heads"] = self._scatter_heads(
                caches["heads"], fresh_hc, ship["mask"])
        else:
            ship["owner_tokens"] = owner_tokens
        return ship

    def _refill_recv(self, ship, admitted, caches) -> Dict[int, np.ndarray]:
        """Scientist half of an admission: receive the fresh cut rows,
        trunk-prefill them, scatter the fresh trunk KV rows, restore
        cached entries' rows, store new cache entries.  Returns
        {slot: first-token logits row} for every admitted slot."""
        logits_rows: Dict[int, np.ndarray] = {}
        if ship is not None:
            idx = ship["idx"]
            if self._ep_owner is not None:
                self._ep_sci.recv_kind(ADMIT_KIND)
                buf = np.zeros(ship["cut_shape"], ship["cut_dtype"])
                for p in range(self.P):
                    got = self._decode_cut(
                        self._ep_sci.recv_kind(CUT_PREFILL_KIND).payload)
                    buf[p, idx] = np.asarray(got)
                logits, fresh_tc = self._prefill_trunk(
                    self.params["trunk"], jnp.asarray(buf),
                    ship["fresh"]["trunk"])
                fresh_hc = ship["fresh_hc"]
            else:
                logits, fresh_caches = self._prefill(
                    self.params, {"owner_tokens": ship["owner_tokens"]},
                    ship["fresh"])
                self.stats["prefill_calls"] += 1
                fresh_hc, fresh_tc = (fresh_caches["heads"],
                                      fresh_caches["trunk"])
                caches["heads"] = self._scatter_heads(
                    caches["heads"], fresh_hc, ship["mask"])
            caches["trunk"] = self._scatter_trunk(
                caches["trunk"], fresh_tc, ship["mask"])
            logits_np = np.asarray(logits)
            for slot, req in ship["fresh_slots"]:
                logits_rows[slot] = logits_np[slot]
                if self.cut_cache is not None:
                    i = jnp.int32(slot)
                    self.cut_cache.put(req.tag, {
                        "hc_row": self._get_heads_row(fresh_hc, i),
                        "tc_row": self._get_trunk_row(fresh_tc, i),
                        "logits": logits_np[slot]})
                    self.transcript.append(
                        ("cut_cache_store", req.rid, req.tag[-16:]))
        elif admitted and self._ep_owner is not None:
            self._ep_sci.recv_kind(ADMIT_KIND)

        for (slot, req, entry, row) in admitted:
            if entry is not None:
                i = jnp.int32(slot)
                caches["heads"] = self._set_heads_row(
                    caches["heads"], entry["hc_row"], i)
                caches["trunk"] = self._set_trunk_row(
                    caches["trunk"], entry["tc_row"], i)
                logits_rows[slot] = entry["logits"]
        return logits_rows

    def _fail_pending(self, exc: BaseException, out: Dict[int, "Result"],
                      slots: Optional[List[Optional[Request]]] = None,
                      results: Optional[Dict[int, "Result"]] = None
                      ) -> None:
        """Degraded service: the scheduler hit a transport/runtime fault.
        Every in-flight and queued request gets a per-request ``error``
        Result instead of the whole engine call blowing up — a serving
        deployment keeps answering its other sessions."""
        err = f"{type(exc).__name__}: {exc}"
        now = time.time()
        for req in ([r for r in (slots or []) if r is not None]
                    + self._queue):
            res = (results or {}).get(req.rid) or Result(req.rid)
            res.error = err
            res.latency_s = now - req.submit_t
            out[req.rid] = res
            self.stats["failed_requests"] += 1
        if slots is not None:
            slots[:] = [None] * len(slots)
        self._queue.clear()
        self.transcript.append(("degraded", -1, err[:120]))

    def _run_continuous(self) -> Dict[int, Result]:
        out: Dict[int, Result] = {}
        if not self._queue:
            return out
        t0 = time.time()
        B, S, P = self.B, self.S, self.P
        caches = self.model.cache_init(B, S, n_new=self.max_new + 1,
                                       ring=self.ring)
        slots: List[Optional[Request]] = [None] * B
        results: Dict[int, Result] = {}
        gen = np.zeros(B, np.int64)        # tokens appended per slot
        tok_np = np.zeros(B, np.int32)     # next token to append per slot
        self._tick = 0

        try:
            self._continuous_loop(out, caches, slots, results, gen, tok_np)
        except (RuntimeError, OSError) as e:
            if isinstance(e, QueueFull):
                raise
            self._fail_pending(e, out, slots, results)

        self.stats["wall_s"] += time.time() - t0
        self._drain_cut_stats()
        return out

    def _continuous_loop(self, out, caches, slots, results, gen, tok_np
                         ) -> None:
        B, S, P = self.B, self.S, self.P
        while self._queue or any(s is not None for s in slots):
            continuing = [i for i in range(B) if slots[i] is not None]
            free = [i for i in range(B) if slots[i] is None]
            admitted = self._admit(free) if self._queue else []

            # one decode tick for the continuing slots (input: the token
            # appended last tick, at its per-slot position).  The whole
            # batch decodes — freed rows carry garbage at frozen
            # positions, which row independence keeps harmless.  In
            # transport mode the decode ship and the refill's prefill
            # ship are both *sent* before either recv blocks on its
            # delivery deadline, so a refill tick pays one injected-
            # latency window, not two.
            logits_dec = None
            if continuing:
                tok = jnp.asarray(tok_np[:, None])
                pos = jnp.asarray(S + np.maximum(gen, 1) - 1, jnp.int32)
                pos_l = jnp.asarray(S // P + np.maximum(gen, 1) - 1,
                                    jnp.int32)
                if self._ep_owner is not None:
                    z, hc = self._vdec_heads(self.params["heads"],
                                             caches["heads"], tok, pos_l)
                    caches["heads"] = hc
                    self._ep_owner.send(CUT_DECODE_KIND,
                                        self._encode_cut(z))
                    ship = self._refill_send(admitted, caches) \
                        if admitted else None
                    z = self._decode_cut(
                        self._ep_sci.recv_kind(CUT_DECODE_KIND).payload)
                    logits_dec, tc = self._vdec_trunk(
                        self.params["trunk"], z, caches["trunk"], pos)
                    caches["trunk"] = tc
                else:
                    logits_dec, tc, hc = self._vdecode(
                        self.params, caches, tok, pos, pos_l)
                    caches = {"heads": hc, "trunk": tc}
                    ship = self._refill_send(admitted, caches) \
                        if admitted else None
                logits_rows = self._refill_recv(ship, admitted, caches) \
                    if admitted else {}
                logits_dec = np.asarray(logits_dec)
            else:
                ship = self._refill_send(admitted, caches) \
                    if admitted else None
                logits_rows = self._refill_recv(ship, admitted, caches) \
                    if admitted else {}

            for i in continuing:
                tok_np[i] = int(np.argmax(logits_dec[i]))
            for slot, req, entry, _ in admitted:
                slots[slot] = req
                results[req.rid] = Result(req.rid)
                gen[slot] = 0
                tok_np[slot] = int(np.argmax(logits_rows[slot]))

            # append phase: every active slot banks one token, then
            # EOS/max_new finishes free the slot for next tick's refill
            now = time.time()
            for i in range(B):
                req = slots[i]
                if req is None:
                    continue
                res = results[req.rid]
                res.generated.append(int(tok_np[i]))
                gen[i] += 1
                self.stats["tokens_generated"] += 1
                if (self.eos is not None and tok_np[i] == self.eos) or \
                        len(res.generated) >= req.max_new:
                    res.latency_s = now - req.submit_t
                    self.transcript.append(("finish", req.rid, i,
                                            self._tick))
                    out[req.rid] = res
                    self.stats["requests"] += 1
                    slots[i] = None
            self._tick += 1
            self.stats["ticks"] += 1

    # --------------------------------------------------------------- run

    def run(self) -> Dict[int, Result]:
        """Drain the queue; returns {request_id: Result}.  Requests that
        hit a transport/runtime fault mid-flight come back with
        ``Result.error`` set instead of raising (degraded service)."""
        if self.scheduler == "continuous":
            return self._run_continuous()
        out: Dict[int, Result] = {}
        while self._queue:
            wave, self._queue = (self._queue[:self.B], self._queue[self.B:])
            try:
                for res in self._run_wave(wave):
                    out[res.rid] = res
            except (RuntimeError, OSError) as e:
                self._queue = wave + self._queue   # wave died unserved
                self._fail_pending(e, out)
        return out

    def close(self) -> None:
        """Release engine-owned transport endpoints (process pipes own a
        writer thread each).  Shared/service endpoints are untouched."""
        if self._owns_endpoints:
            for ep in (self._ep_owner, self._ep_sci):
                if ep is not None and hasattr(ep, "close"):
                    ep.close()


class ServingService:
    """One split-serving deployment: a single owner<->scientist channel
    shared by many concurrent engine sessions, plus a service-wide
    repeat-entity :class:`CutCache`.

    Each ``session()`` is a full :class:`ServingEngine` whose frames ride
    the shared channel with a ``"s{sid}:"`` kind prefix
    (``transport.ScopedEndpoint``) — the process-transport multiplex
    header and ``recv_kind``'s stash absorb cross-session interleaving,
    and per-session stats come from the prefix-filtered ``by_kind``
    totals.  Sessions may run on separate threads (channel send/recv are
    locked).  Engine defaults passed here apply to every session; the
    shared cut cache requires sessions to share geometry (the cache tag
    enforces it — mismatched sessions simply never hit)."""

    def __init__(self, model: SplitModel, params, *,
                 transport: str = "queue", latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 cut_cache=True, cache_entries: int = 256,
                 **engine_defaults):
        self.model, self.params = model, params
        self.transport = transport
        if transport == "process":
            from repro.federation.process_transport import \
                process_endpoint_pair
            self._ep_owner, self._ep_sci = process_endpoint_pair(
                "owners", "scientist", latency_s=latency_s,
                bandwidth_bps=bandwidth_bps)
        else:
            self._ep_owner, self._ep_sci = transport_mod.channel_pair(
                "owners", "scientist", backend=transport,
                latency_s=latency_s, bandwidth_bps=bandwidth_bps)
        if cut_cache is True:
            cut_cache = CutCache(cache_entries)
        self.cut_cache = (cut_cache if isinstance(cut_cache, CutCache)
                          else None)
        self._defaults = dict(engine_defaults)
        self._defaults.setdefault("scheduler", "continuous")
        self._sid = 0
        self.sessions: List[ServingEngine] = []

    def session(self, **engine_kw) -> ServingEngine:
        """A new multiplexed serving session on the shared channel."""
        sid = self._sid
        self._sid += 1
        scope = f"s{sid}:"
        kw = {**self._defaults, **engine_kw}
        eng = ServingEngine(
            self.model, self.params, cut_cache=self.cut_cache,
            endpoints=(transport_mod.ScopedEndpoint(self._ep_owner, scope),
                       transport_mod.ScopedEndpoint(self._ep_sci, scope)),
            **kw)
        eng.sid = sid
        self.sessions.append(eng)
        return eng

    @property
    def channel_stats(self) -> Dict[str, object]:
        """The shared channel's raw (un-scoped) receive totals."""
        return self._ep_sci.recv_stats

    def close(self) -> None:
        for ep in (self._ep_owner, self._ep_sci):
            if hasattr(ep, "close"):
                ep.close()
