"""End-to-end SplitNN training launcher (runs for real on the host mesh).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 50 --batch 8 --seq 256

A thin client of ``VerticalSession``: token streams are vertically
partitioned into sequence-slice owners + a label-holding scientist, the
session resolves/aligns them (DH-PSI), builds the split model through the
registry, and runs the jitted per-segment-optimizer loop with
checkpointing.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.federation import VerticalSession, sequence_parties


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--owner-lr", type=float, default=1e-3)
    ap.add_argument("--scientist-lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.modality != "text":
        raise SystemExit("train.py drives text archs; see examples/ for "
                         "vlm/audio training")
    toks = make_token_dataset(max(args.batch * 8, 64), args.seq,
                              cfg.vocab, args.seed)
    session = VerticalSession(
        *sequence_parties(toks, cfg.split.n_owners), seed=args.seed)
    session.resolve(group="modp512")
    session.build(cfg, seed=args.seed)

    model = session.adapter.model
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(session.params))
    print(f"arch={cfg.name} reduced={args.reduced} params={n_params/1e6:.1f}M"
          f" owners={cfg.split.n_owners} cut_layer={model.n_head_units}")

    history = session.fit(
        steps=args.steps, batch_size=args.batch,
        owner_lr=args.owner_lr, scientist_lr=args.scientist_lr,
        log_every=args.log_every,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0)
    return history["final"]["loss"]


if __name__ == "__main__":
    main()
