"""End-to-end SplitNN training launcher (runs for real on the host mesh).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --reduced --steps 50 --batch 8 --seq 256

Builds the vertical data (token streams split across owners), the split
model, per-segment optimizers (paper: owners and scientist train their own
segments), and runs jitted train steps with checkpointing.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.core.splitnn import make_split_train_step, train_state_init
from repro.data import make_token_dataset, batches
from repro.models.model import SplitModel
from repro.optim import adam, chain, clip_by_global_norm, multi_segment, sgd


def make_batch(cfg, toks):
    """toks: (B, S+1) -> owner-partitioned training batch."""
    B, S1 = toks.shape
    S = S1 - 1
    P = cfg.split.n_owners
    inp, lab = toks[:, :-1], toks[:, 1:]
    owner_tokens = inp.reshape(B, P, S // P).transpose(1, 0, 2)
    return {"owner_tokens": jnp.asarray(owner_tokens),
            "labels": jnp.asarray(lab)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--owner-lr", type=float, default=1e-3)
    ap.add_argument("--scientist-lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.modality != "text":
        raise SystemExit("train.py drives text archs; see examples/ for "
                         "vlm/audio training")
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} reduced={args.reduced} params={n_params/1e6:.1f}M"
          f" owners={cfg.split.n_owners} cut_layer={model.n_head_units}")

    opt = multi_segment({
        "heads": chain(clip_by_global_norm(1.0), adam(args.owner_lr)),
        "trunk": chain(clip_by_global_norm(1.0), adam(args.scientist_lr)),
    })
    state = train_state_init(params, opt)
    step_fn = make_split_train_step(model.loss_fn, opt)

    toks = make_token_dataset(max(args.batch * 8, 64), args.seq,
                              cfg.vocab, args.seed)
    it = batches({"toks": toks}, args.batch, seed=args.seed, epochs=10_000)

    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch(cfg, next(it)["toks"])
        params, state, metrics = step_fn(params, state, batch, i)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"({(time.time()-t0):.1f}s)")
        if args.ckpt_every and args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            d = ckpt.save_split(args.ckpt_dir, params, i + 1)
            print(f"  checkpointed (per-party) -> {d}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
