"""Step builders: the jittable train / prefill / decode functions plus
their ShapeDtypeStruct input stand-ins and shardings for a given
(architecture x input-shape x mesh).

Everything here is allocation-free: params/optimizer/caches are
``jax.eval_shape`` structures, batches are ShapeDtypeStructs — the same
pattern the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import SplitModel
from repro.optim import adam, apply_updates, chain, clip_by_global_norm
from repro.sharding import (ShardingRules, batch_specs, cache_specs,
                            param_specs, sharding_context)
from repro.sharding.specs import make_rules, named


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def swa_for(cfg: ArchConfig, shape: ShapeConfig) -> Optional[int]:
    """The explicit sliding-window long-context variant (DESIGN.md §3)."""
    if shape.name == "long_500k" and cfg.long_context == "swa":
        return cfg.long_context_window
    return None


def shape_supported(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k" and cfg.long_context == "skip":
        return False
    return True


# ---------------------------------------------------------------------------
# Input structs
# ---------------------------------------------------------------------------


def batch_structs(cfg: ArchConfig, shape: ShapeConfig,
                  with_labels: bool) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    half = S // 2
    if cfg.modality == "text":
        P = cfg.split.n_owners
        b = {"owner_tokens": sds((P, B, S // P), jnp.int32)}
        if with_labels:
            b["labels"] = sds((B, S), jnp.int32)
    elif cfg.modality == "vision_text":
        b = {"patches": sds((B, half, cfg.d_frontend), jnp.bfloat16),
             "tokens": sds((B, half), jnp.int32)}
        if with_labels:
            b["labels"] = sds((B, S), jnp.int32)
    elif cfg.modality == "audio_text":
        b = {"frames": sds((B, half, cfg.d_frontend), jnp.bfloat16),
             "tokens": sds((B, half), jnp.int32)}
        if with_labels:
            b["labels"] = sds((B, half), jnp.int32)
    else:
        raise ValueError(cfg.modality)
    return b


def make_optimizer(cfg: ArchConfig, opt_state_dtype=jnp.float32):
    return chain(clip_by_global_norm(1.0),
                 adam(3e-4, state_dtype=opt_state_dtype))


# ---------------------------------------------------------------------------
# Builders — each returns (fn, args_structs, in_specs, donate_argnums)
# ---------------------------------------------------------------------------


def _split_micro(batch, n: int):
    """Reshape every batch leaf to (n_micro, micro_batch, ...).  The owner
    dim of owner_tokens (P, B, S_p) stays outermost within a microbatch."""
    out = {}
    for k, v in batch.items():
        if k == "owner_tokens":
            P, B, S_p = v.shape
            out[k] = v.reshape(P, n, B // n, S_p).transpose(1, 0, 2, 3)
        else:
            out[k] = v.reshape((n, v.shape[0] // n) + v.shape[1:])
    return out


def build_train(cfg: ArchConfig, shape: ShapeConfig, mesh,
                rules: ShardingRules, n_microbatches: int = 1,
                opt_state_dtype=jnp.float32):
    model = SplitModel(cfg)
    optimizer = make_optimizer(cfg, opt_state_dtype)
    swa = swa_for(cfg, shape)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p, b):
            return model.loss_fn(p, b, swa_override=swa)

        with sharding_context(mesh, rules):
            if n_microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                # gradient accumulation: one microbatch forward+backward at
                # a time — activation live-set shrinks by n_microbatches.
                micro = _split_micro(batch, n_microbatches)

                def body(acc, mb):
                    g_acc, l_acc = acc
                    (l, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                    return (g_acc, l_acc + l), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(
                    body, (g0, jnp.zeros((), jnp.float32)), micro)
                inv = 1.0 / n_microbatches
                grads = jax.tree.map(lambda g: g * inv, grads)
                loss = loss * inv
                metrics = {"loss": loss, "aux": jnp.zeros_like(loss)}
            updates, opt_state_n = optimizer.update(grads, opt_state,
                                                    params, step)
            params_n = apply_updates(params, updates)
        return params_n, opt_state_n, metrics

    p_struct = model.param_specs()
    o_struct = jax.eval_shape(optimizer.init, p_struct)
    b_struct = batch_structs(cfg, shape, with_labels=True)
    s_struct = sds((), jnp.int32)

    p_spec = param_specs(p_struct, cfg, mesh, rules)
    o_spec = _opt_specs(optimizer, p_struct, p_spec, cfg, mesh, rules)
    b_spec = batch_specs(b_struct, cfg, mesh, rules)

    args = (p_struct, o_struct, b_struct, s_struct)
    specs = (p_spec, o_spec, b_spec, None)
    return train_step, args, specs, (0, 1)


def _opt_specs(optimizer, p_struct, p_spec, cfg, mesh, rules):
    """Optimizer-state specs: same rules applied leaf-by-leaf (m/v mirror
    params; empty chain states stay empty)."""
    o_struct = jax.eval_shape(optimizer.init, p_struct)
    return param_specs(o_struct, cfg, mesh, rules)


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  rules: ShardingRules, n_new: int = 8):
    model = SplitModel(cfg)
    swa = swa_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len

    def prefill(params, batch, caches):
        with sharding_context(mesh, rules):
            return model.prefill(params, batch, caches, swa_override=swa)

    p_struct = model.param_specs()
    b_struct = batch_structs(cfg, shape, with_labels=False)
    c_struct = jax.eval_shape(
        functools.partial(model.cache_init, B, S, n_new))

    p_spec = param_specs(p_struct, cfg, mesh, rules)
    b_spec = batch_specs(b_struct, cfg, mesh, rules)
    c_spec = cache_specs(c_struct, cfg, mesh, rules)
    args = (p_struct, b_struct, c_struct)
    specs = (p_spec, b_spec, c_spec)
    return prefill, args, specs, (2,)


def build_decode(cfg: ArchConfig, shape: ShapeConfig, mesh,
                 rules: ShardingRules, n_new: int = 8,
                 ring_cache: bool = False, cache_dtype=None):
    """serve_step: ONE new token against a seq_len-deep cache."""
    model = SplitModel(cfg)
    swa = swa_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len

    def serve_step(params, caches, token, pos, pos_local):
        with sharding_context(mesh, rules):
            return model.decode_step(params, caches, token, pos, pos_local,
                                     swa_override=swa)

    p_struct = model.param_specs()
    c_struct = jax.eval_shape(
        functools.partial(model.cache_init, B, S, n_new, ring=ring_cache,
                          swa_override=swa or 0, cache_dtype=cache_dtype))
    t_struct = sds((B, 1), jnp.int32)
    s_struct = sds((), jnp.int32)

    p_spec = param_specs(p_struct, cfg, mesh, rules)
    c_spec = cache_specs(c_struct, cfg, mesh, rules)
    t_spec = batch_specs({"token": t_struct}, cfg, mesh, rules)["token"]
    args = (p_struct, c_struct, t_struct, s_struct, s_struct)
    specs = (p_spec, c_spec, t_spec, None, None)
    return serve_step, args, specs, (1,)


def build(cfg: ArchConfig, shape: ShapeConfig, mesh, rules=None,
          n_microbatches: int = 1, ring_cache: bool = False,
          opt_state_dtype=jnp.float32, cache_dtype=None, **kw):
    rules = rules if rules is not None else make_rules(mesh, cfg, **kw)
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, rules,
                           n_microbatches=n_microbatches,
                           opt_state_dtype=opt_state_dtype)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, rules)
    if shape.kind == "decode":
        return build_decode(cfg, shape, mesh, rules,
                            ring_cache=ring_cache, cache_dtype=cache_dtype)
    raise ValueError(shape.kind)
