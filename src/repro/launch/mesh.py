"""Production meshes.

Target: TPU v5e pods, 256 chips/pod.  Single-pod (16, 16) ("data","model");
multi-pod (2, 16, 16) ("pod","data","model") — the "pod" axis hosts the
PyVertical data-owner dimension (2 owners = 2 pods).

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for tests/examples on however many devices exist."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
