"""Batched split-inference launcher: prefill the vertically-partitioned
context through the owner heads, then decode new tokens through the
generation-owner head + scientist trunk.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
        --reduced --batch 4 --ctx 128 --new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.models.model import SplitModel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.modality != "text":
        raise SystemExit("serve.py drives text archs")
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    B, S, P = args.batch, args.ctx, cfg.split.n_owners
    toks = make_token_dataset(B, S, cfg.vocab, args.seed)[:, :S]
    owner_tokens = toks.reshape(B, P, S // P).transpose(1, 0, 2)
    batch = {"owner_tokens": jnp.asarray(owner_tokens)}

    caches = model.cache_init(B, S, n_new=args.new)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    print(f"prefill {B}x{S}: {time.time()-t0:.2f}s")

    key = jax.random.PRNGKey(args.seed)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for t in range(args.new - 1):
        logits, caches = decode(params, caches, tok, S + t, S // P + t)
        if args.temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(
                k, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.new-1} steps in {dt:.2f}s "
          f"({(args.new-1)*B/max(dt,1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  request {b}: ...{toks[b,-8:].tolist()} -> "
              f"{gen[b].tolist()}")
    return gen


if __name__ == "__main__":
    main()
