"""Partitioning rules for the SplitNN system on the production meshes.

Single-pod mesh (16, 16) = ("data", "model"); multi-pod (2, 16, 16) =
("pod", "data", "model").  The owner (data-owner) dimension of head
params/activations maps onto "pod" — PyVertical's parties at datacenter
scale; the cut-layer all-gather is then the only *protocol* cross-pod
collective (trunk-internal data parallelism is scientist-internal).

``trunk_dp_over_pod`` is the beyond-paper optimization lever: the baseline
(paper-faithful) deployment replicates trunk compute across pods (the
scientist owns the trunk); the optimized variant lets the trunk
data-parallelize over ("pod", "data") after the cut.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    multi_pod: bool = False
    model_axis: str = "model"
    data_axis: str = "data"
    pod_axis: Optional[str] = None              # None on the single-pod mesh
    fsdp: bool = False                          # ZeRO param sharding
    trunk_dp_over_pod: bool = False             # beyond-paper lever
    # decode-cache context parallelism: shard cache sequence dim
    cache_seq_axes: Tuple[str, ...] = ("model",)

    @property
    def owner_axis(self):
        return self.pod_axis

    @property
    def trunk_batch(self):
        if self.multi_pod and self.trunk_dp_over_pod:
            return (self.pod_axis, self.data_axis)
        return (self.data_axis,)


def abstract_mesh(axis_sizes, axis_names):
    """``jax.sharding.AbstractMesh`` across the signature change: the
    0.4.x line takes one ``((name, size), ...)`` tuple, jax >= 0.5 takes
    ``(sizes, names)``.  Spec construction only consults ``mesh.shape`` /
    ``axis_names``, so no devices are needed either way."""
    try:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(axis_sizes),
                                         tuple(axis_names))


def make_rules(mesh, cfg, **kw) -> ShardingRules:
    multi = "pod" in mesh.axis_names
    return ShardingRules(multi_pod=multi, pod_axis="pod" if multi else None,
                         fsdp=cfg.zero_sharding, **kw)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

# logical trailing-dims spec per param name: tokens are placeholders
# resolved against the rules ("model" -> model axis, "fsdp" -> data axis
# when zero-sharding, else replicated).
_PARAM_RULES = [
    # (suffix, logical_ndim or None, spec template)
    ("embed/table", 2, ("model", "fsdp")),
    ("lm_head/w", 2, (None, "model")),
    ("front_proj/w", 2, (None, "model")),
    ("cut_proj/w", 2, (None, None)),
    ("in_proj/w", 2, ("fsdp", "model")),        # trunk in_proj & mamba in_proj
    ("attn/wq/w", 2, ("fsdp", "model")),
    ("attn/wk/w", 2, ("fsdp", "model")),
    ("attn/wv/w", 2, ("fsdp", "model")),
    ("xattn/wq/w", 2, ("fsdp", "model")),
    ("xattn/wk/w", 2, ("fsdp", "model")),
    ("xattn/wv/w", 2, ("fsdp", "model")),
    ("attn/wo/w", 2, ("model", "fsdp")),
    ("xattn/wo/w", 2, ("model", "fsdp")),
    ("ffn/w_in/w", 2, ("fsdp", "model")),
    ("ffn/w_gate/w", 2, ("fsdp", "model")),
    ("ffn/w_out/w", 2, ("model", "fsdp")),
    ("shared/w_in/w", 2, ("fsdp", "model")),
    ("shared/w_gate/w", 2, ("fsdp", "model")),
    ("shared/w_out/w", 2, ("model", "fsdp")),
    ("router/w", 2, (None, None)),
    # MoE experts: expert-parallel over the model axis when E divides it,
    # else fall back to tensor-parallel experts (shard d_expert) — the
    # mixtral case (8 experts on a 16-way model axis).
    ("w_in", 3, ("expert", None, "expert_alt")),   # (E, d, d_e)
    ("w_gate", 3, ("expert", None, "expert_alt")),
    ("w_out", 3, ("expert", "expert_alt", None)),  # (E, d_e, d)
    ("conv_w", 2, (None, "model")),
    ("mamba/out_proj/w", 2, ("model", "fsdp")),
    ("up_x/w", 2, ("fsdp", "model")),
    ("up_z/w", 2, ("fsdp", "model")),
    ("cell/wq/w", 2, (None, "model")),
    ("cell/wk/w", 2, (None, "model")),
    ("cell/wv/w", 2, (None, "model")),
    ("w_if/w", 2, ("model", None)),
    ("cell/down/w", 2, ("model", "fsdp")),
    ("w_gates/w", 2, ("fsdp", "model")),
    ("r_gates", 3, (None, None, None)),
    ("cell/up/w", 2, ("fsdp", "model")),
    ("up/w", 2, ("fsdp", "model")),
    ("down/w", 2, ("model", "fsdp")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(f"#{k.idx}")
        else:
            parts.append(str(k))
    return "/".join(parts)


def _divisible(dim: int, axes, mesh) -> bool:
    if axes is None:
        return True
    names = axes if isinstance(axes, tuple) else (axes,)
    size = int(np.prod([mesh.shape[a] for a in names]))
    return dim % size == 0


def _resolve(template, rules: ShardingRules, cfg, mesh, shape, offset):
    """Template tokens -> mesh axes, with divisibility guards."""
    out = []
    expert_sharded = False
    if "expert" in template:
        e_dim = shape[offset + template.index("expert")]
        expert_sharded = _divisible(e_dim, rules.model_axis, mesh)
    for i, tok in enumerate(template):
        dim = shape[offset + i]
        ax: Any = None
        if tok == "model":
            ax = rules.model_axis
        elif tok == "fsdp":
            ax = rules.data_axis if rules.fsdp else None
        elif tok == "expert":
            ax = rules.model_axis if expert_sharded else None
        elif tok == "expert_alt":
            ax = None if expert_sharded else rules.model_axis
        if ax is not None and not _divisible(dim, ax, mesh):
            ax = None
        out.append(ax)
    return out


def param_specs(param_shapes, cfg, mesh, rules: ShardingRules):
    """PartitionSpec tree matching an eval_shape'd param tree."""

    def leaf(path, x):
        ps = _path_str(path)
        ndim = len(x.shape)
        for suffix, lnd, template in _PARAM_RULES:
            if ps.endswith(suffix) and (lnd is None or lnd <= ndim):
                # count stacking prefixes: owner dim (heads/...), unit dim
                n_prefix = ndim - lnd
                spec = [None] * n_prefix
                if ("heads/" in ps and n_prefix >= 1
                        and rules.owner_axis
                        and _divisible(x.shape[0], rules.owner_axis, mesh)):
                    spec[0] = rules.owner_axis
                spec += _resolve(template, rules, cfg, mesh, x.shape,
                                 n_prefix)
                return P(*spec)
        # default: replicate (norm scales, biases, scalars)
        spec = [None] * ndim
        if ("heads/" in ps and ndim >= 1 and rules.owner_axis
                and _divisible(x.shape[0], rules.owner_axis, mesh)):
            spec[0] = rules.owner_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, param_shapes)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_shapes, cfg, mesh, rules: ShardingRules):
    """Specs for a training/prefill batch dict (owner inputs + labels)."""

    def leaf(path, x):
        name = _path_str(path)
        d = rules.data_axis
        if name == "owner_tokens":                 # (P, B, S_p)
            pod = (rules.owner_axis if rules.owner_axis
                   and _divisible(x.shape[0], rules.owner_axis, mesh)
                   else None)
            db = d if _divisible(x.shape[1], d, mesh) else None
            return P(pod, db, None)
        if name in ("patches", "frames"):          # (B, S_p, d_f)
            db = d if _divisible(x.shape[0], d, mesh) else None
            return P(db, None, None)
        if name in ("tokens", "labels"):           # (B, S)
            db = d if _divisible(x.shape[0], d, mesh) else None
            return P(db, *([None] * (len(x.shape) - 1)))
        if name in ("token",):                     # decode (B, 1)
            db = d if _divisible(x.shape[0], d, mesh) else None
            return P(db, None)
        return P(*([None] * len(x.shape)))

    return jax.tree_util.tree_map_with_path(leaf, batch_shapes)


def cache_specs(cache_shapes, cfg, mesh, rules: ShardingRules):
    """Decode-cache specs.  KV caches (units, B, S, n_kv, hd): batch over
    data when divisible, sequence over ``cache_seq_axes`` (context
    parallelism — essential at 500k); recurrent states: batch over data."""

    def leaf(path, x):
        ps = _path_str(path)
        d = rules.data_axis
        shape = x.shape
        spec = [None] * len(shape)
        # find the batch dim: KV caches are (units, B, S, n_kv, hd);
        # ssm states (units, B, ...); stacked-owner versions have a
        # leading P dim.
        b_dim = 0
        if ps.startswith("heads") and not ps.startswith("heads/patches") \
                and not ps.startswith("heads/tokens"):
            if rules.owner_axis and _divisible(shape[0], rules.owner_axis,
                                               mesh):
                spec[0] = rules.owner_axis
            b_dim = 2                              # (P, units, B, ...)
        else:
            b_dim = 1                              # (units, B, ...)
        if ps.startswith("enc"):                   # (B, S_enc, d)
            if _divisible(shape[0], d, mesh):
                spec[0] = d
            return P(*spec)
        if b_dim < len(shape) and _divisible(shape[b_dim], d, mesh):
            spec[b_dim] = d
        # kv-cache sequence dim: (.., B, S, n_kv, hd) with ndim-b_dim == 4
        if len(shape) - b_dim == 4 and (ps.endswith("/k")
                                        or ps.endswith("/v")):
            s_dim = b_dim + 1
            axes = tuple(a for a in rules.cache_seq_axes
                         if a in mesh.axis_names)
            if spec[b_dim] is None:
                # batch unshardable (B=1): context-parallel over data too
                axes = tuple(dict.fromkeys((rules.data_axis,) + axes))
            if axes and _divisible(shape[s_dim], axes, mesh):
                spec[s_dim] = axes if len(axes) > 1 else axes[0]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Activation constraints (hooked from model code)
# ---------------------------------------------------------------------------

_CTX: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx",
                                                      default=None)


@contextlib.contextmanager
def sharding_context(mesh, rules: ShardingRules):
    tok = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def constrain(x, name: str):
    """Annotate a model-internal activation.  No-op without a context."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    d, m = rules.data_axis, rules.model_axis
    tb = rules.trunk_batch
    tb = tuple(a for a in tb if a)

    def guard(spec):
        fixed = []
        for dim, ax in zip(x.shape, spec):
            fixed.append(ax if ax is None or _divisible(dim, ax, mesh)
                         else None)
        return P(*fixed)

    if name == "cut_stacked":        # (P, B, S_p, k)
        pod = rules.owner_axis
        spec = (pod, d, None, None)
    elif name == "combined":         # (B, S, k) — trunk input, post-combine
        spec = (tb if len(tb) > 1 else (tb[0] if tb else None), None, None)
    elif name == "trunk_hidden":     # (B, S, d)
        spec = (tb if len(tb) > 1 else (tb[0] if tb else None), None, None)
    elif name == "logits":           # (B, S, vocab)
        spec = (tb if len(tb) > 1 else (tb[0] if tb else None), None, m)
    elif name == "moe_buffer":       # (E, C, d) dispatch/combine buffer
        spec = (m, d, None)
    elif name == "moe_buffer_grouped":  # (G, E, C_g, d): G rides data
        spec = (d, m, None, None)
    else:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, guard(spec)))
