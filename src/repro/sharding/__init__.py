from repro.sharding.specs import (ShardingRules, param_specs, batch_specs,  # noqa
                                  cache_specs, named, constrain,
                                  sharding_context)
