"""Basic layers: norms, embeddings, rotary positions, activations.

All modules are functional: ``*_init(key, ...) -> params`` plus a pure
apply function.  Params are stored in the arch's ``param_dtype`` (fp32 by
default) and cast to ``compute_dtype`` (bf16) at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cast(x, dtype):
    return x.astype(dtype) if x.dtype != dtype else x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def norm_apply(params, x, kind: str, eps: float = 1e-5):
    # reductions in fp32 for stability
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * (1.0 + params["scale"].astype(jnp.float32))
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / embedding
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense_apply(params, x):
    w = cast(params["w"], x.dtype)
    return x @ w


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed_apply(params, ids, dtype):
    return cast(params["table"], dtype)[ids]


def softcap(x, cap: float):
    """Gemma2 soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE / M-RoPE / sin-cos)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    ang = ang[..., None, :]                               # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections=(2, 3, 3)):
    """Qwen2-VL M-RoPE: rotary with 3 position streams (t, h, w).

    ``positions3``: (..., S, 3).  The head_dim/2 frequency slots are split
    into ``sections`` proportional groups fed by the t/h/w position streams.
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                         # (half,)
    total = sum(sections)
    bounds = np.cumsum([int(half * s / total) for s in sections])
    bounds[-1] = half
    slot = np.zeros((half,), np.int32)
    prev = 0
    for i, b in enumerate(bounds):
        slot[prev:b] = i
        prev = b
    slot = jnp.asarray(slot)                              # (half,) in {0,1,2}
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(slot, positions3.shape[:-1] + (half,)), axis=-1)
    ang = pos * freqs                                     # (..., S, half)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sincos_positions(positions, d: int):
    """Whisper-style fixed sinusoidal position embeddings. (..., S) -> (..., S, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (np.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
