"""Mamba2 (SSD) block — chunked state-space-dual algorithm in pure JAX.

This is the jnp oracle for the ``mamba2_scan`` Pallas kernel.  The chunked
SSD computation (Dao & Gu 2024): within-chunk quadratic term + inter-chunk
state recurrence carried by a ``lax.scan``, so compiled HLO size is
independent of sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (with decode state)
# ---------------------------------------------------------------------------


def conv1d_apply(w, x, state=None):
    """Depthwise causal conv.  w: (W, C); x: (B, S, C).

    ``state``: (B, W-1, C) previous inputs for decode.  Returns (y, new_state).
    """
    W = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(x_pad[:, i:i + x.shape[1]] * layers.cast(w[i], x.dtype)
            for i in range(W))
    new_state = x_pad[:, -(W - 1):] if W > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B_in, C_in, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P)   per-head inputs
    dt: (B, S, H)     positive step sizes
    A: (H,)           negative per-head decay rates
    B_in, C_in: (B, S, G, N)   input/output projections (G groups, H%G==0)
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    Bb, S, H, P = x.shape
    G, N = B_in.shape[2], B_in.shape[3]
    rep = H // G
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    f32 = jnp.float32

    def padded(a):
        if pad:
            a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        return a.astype(f32)

    xc = padded(x).reshape(Bb, nc, L, H, P)
    dtc = padded(dt).reshape(Bb, nc, L, H)
    Bc = padded(B_in).reshape(Bb, nc, L, G, N)
    Cc = padded(C_in).reshape(Bb, nc, L, G, N)
    Bh = jnp.repeat(Bc, rep, axis=3)                      # (B,nc,L,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    if initial_state is None:
        s0 = jnp.zeros((Bb, H, N, P), f32)
    else:
        s0 = initial_state.astype(f32)

    mask = jnp.tril(jnp.ones((L, L), bool))
    Af = A.astype(f32)

    # One chunk per scan step: bounds live memory to a single chunk — the
    # same structure the Pallas kernel uses (sequential grid + VMEM carry).
    def body(s_prev, xs):
        xk, dtk, Bk, Ck = xs        # (B,L,H,P) (B,L,H) (B,L,H,N) (B,L,H,N)
        a = dtk * Af                                      # (B,L,H) ≤ 0
        cum = jnp.cumsum(a, axis=1)                       # inclusive
        total = cum[:, -1]                                # (B,H)
        # within-chunk quadratic term: L_ij = exp(cum_i - cum_j), j ≤ i.
        # Mask BEFORE the exp: the j > i entries are positive and overflow
        # to inf, which would poison the backward pass through `where`.
        diff = cum[:, :, None, :] - cum[:, None, :, :]    # (B,i,j,H)
        Ldec = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum("blhn,bmhn->blmh", Ck, Bk)    # (B,i,j,H)
        M = scores * Ldec * dtk[:, None, :, :]            # weight dt_j
        y_intra = jnp.einsum("blmh,bmhp->blhp", M, xk)
        # inter-chunk term from carried state
        y_inter = jnp.einsum("blhn,bhnp->blhp",
                             Ck * jnp.exp(cum)[..., None], s_prev)
        # chunk state contribution + recurrence
        w = jnp.exp(total[:, None] - cum) * dtk           # (B,L,H)
        state_c = jnp.einsum("blh,blhn,blhp->bhnp", w, Bk, xk)
        s_next = jnp.exp(total)[..., None, None] * s_prev + state_c
        return s_next, y_intra + y_inter

    xs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
          Bh.transpose(1, 0, 2, 3, 4), Ch.transpose(1, 0, 2, 3, 4))
    s_fin, yc = jax.lax.scan(body, s0, xs)                # yc: (nc,B,L,H,P)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bb, nc * L, H, P)[:, :S]
    return y.astype(x.dtype), s_fin


def ssd_step(x, dt, A, B_in, C_in, state):
    """Single decode step.  x: (B,1,H,P); state: (B,H,N,P)."""
    f32 = jnp.float32
    H = x.shape[2]
    G = B_in.shape[2]
    rep = H // G
    xf = x[:, 0].astype(f32)                              # (B,H,P)
    dtf = dt[:, 0].astype(f32)                            # (B,H)
    Bh = jnp.repeat(B_in[:, 0].astype(f32), rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(C_in[:, 0].astype(f32), rep, axis=1)
    decay = jnp.exp(dtf * A.astype(f32))                  # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dtf, Bh, xf)
    new_state = decay[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y[:, None].astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": layers.dense_init(
            ks[0], d, 2 * d_in + 2 * s.n_groups * s.d_state + H, dtype),
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_ch), dtype) * 0.2,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(dtype)),
        "dt_bias": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "gate_norm": layers.norm_init(d_in, "rmsnorm", dtype),
        "out_proj": layers.dense_init(ks[2], d_in, d, dtype),
    }


def mamba2_cache_init(batch: int, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return {"conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
            "state": jnp.zeros((batch, H, s.d_state, s.head_dim), dtype)}


def mamba2_apply(params, x, cfg, cache=None):
    """x: (B, S, d) -> (y (B, S, d), new_cache)."""
    s = cfg.ssm
    Bb, S, d = x.shape
    d_in = s.expand * d
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state

    zxbcdt = layers.dense_apply(params["in_proj"], x)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = conv1d_apply(params["conv_w"], conv_in, conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(conv_out, [d_in, d_in + gn], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(Bb, S, H, s.head_dim)
    Bm = Bm.reshape(Bb, S, s.n_groups, s.d_state)
    Cm = Cm.reshape(Bb, S, s.n_groups, s.d_state)

    if cache is not None and S == 1:          # decode: single-step recurrence
        y, new_state = ssd_step(xh, dt, A, Bm, Cm, cache["state"])
    else:                                     # train / prefill: chunked scan
        init = cache["state"] if cache is not None else None
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk_size,
                                   initial_state=init)

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(Bb, S, d_in).astype(x.dtype)
    y = layers.norm_apply(params["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = layers.dense_apply(params["out_proj"], y)
    new_cache = ({"conv": new_conv, "state": new_state.astype(
        cache["state"].dtype)} if cache is not None else None)
    return out, new_cache
