"""Block assembly: heterogeneous super-blocks + scan-over-superblocks.

An architecture is ``n_superblocks`` repetitions of ``cfg.block_pattern``
(e.g. zamba2: 5x mamba2 + 1 shared_attn).  Parameters of the units are
stacked on a leading dim and the stack is applied with ``jax.lax.scan`` so
compiled HLO size is independent of depth; each super-block is optionally
rematerialized (``cfg.remat``).

Block kinds: attn:global, attn:local, shared_attn (zamba2 weight sharing),
mamba2, slstm, mlstm, dec (whisper decoder block with cross-attention).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mlp as mlp_mod, moe as moe_mod
from repro.models import ssm, xlstm


def _has_ffn(cfg) -> bool:
    return cfg.moe is not None or (cfg.d_ff > 0 and cfg.mlp != "none")


def _ffn_init(key, cfg, dtype):
    if cfg.moe is not None:
        return moe_mod.moe_init(key, cfg.d_model, cfg.moe, cfg.mlp, dtype)
    return mlp_mod.mlp_init(key, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)


def _ffn_apply(params, x, cfg):
    if cfg.moe is not None:
        return moe_mod.moe_apply(params, x, cfg.moe, cfg.mlp)
    return mlp_mod.mlp_apply(params, x, cfg.mlp), 0.0


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def block_init(key, cfg, kind: str, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {"norm1": layers.norm_init(d, cfg.norm, dtype)}
    if kind in ("attn:global", "attn:local", "shared_attn", "dec"):
        p["attn"] = attention.attn_init(ks[0], cfg, dtype)
        if kind == "dec":
            p["norm_x"] = layers.norm_init(d, cfg.norm, dtype)
            p["xattn"] = attention.attn_init(ks[1], cfg, dtype)
        if _has_ffn(cfg):
            p["norm2"] = layers.norm_init(d, cfg.norm, dtype)
            p["ffn"] = _ffn_init(ks[2], cfg, dtype)
        if cfg.post_block_norm:
            p["post1"] = layers.norm_init(d, cfg.norm, dtype)
            if _has_ffn(cfg):
                p["post2"] = layers.norm_init(d, cfg.norm, dtype)
    elif kind == "mamba2":
        p["mamba"] = ssm.mamba2_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["cell"] = xlstm.slstm_init(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["cell"] = xlstm.mlstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def block_cache_init(batch: int, cfg, kind: str, s_max: int,
                     dtype=jnp.bfloat16, window_slots: int = 0):
    if kind in ("attn:global", "attn:local", "shared_attn", "dec"):
        s_eff = min(s_max, window_slots) if window_slots else s_max
        return attention.init_kv_cache(batch, s_eff, cfg.n_kv_heads,
                                       cfg.head_dim, dtype)
    if kind == "mamba2":
        return ssm.mamba2_cache_init(batch, cfg, jnp.float32)
    if kind == "slstm":
        return xlstm.slstm_cache_init(batch, cfg, jnp.float32)
    if kind == "mlstm":
        return xlstm.mlstm_cache_init(batch, cfg, jnp.float32)
    raise ValueError(kind)


def block_apply(params, x, *, cfg, kind: str, positions=None,
                attn_kind: str = "causal", window: int = 0, cache=None,
                pos=None, enc_out=None, chunk: int = 1024):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = 0.0
    h = layers.norm_apply(params["norm1"], x, cfg.norm, cfg.norm_eps)
    if kind in ("attn:global", "attn:local", "shared_attn", "dec"):
        a, new_cache = attention.attn_apply(
            params["attn"], h, cfg=cfg, kind=attn_kind, positions=positions,
            window=window, cache=cache, pos=pos, chunk=chunk)
        if cfg.post_block_norm:
            a = layers.norm_apply(params["post1"], a, cfg.norm, cfg.norm_eps)
        x = x + a
        if kind == "dec" and enc_out is not None:
            h = layers.norm_apply(params["norm_x"], x, cfg.norm, cfg.norm_eps)
            a, _ = attention.attn_apply(params["xattn"], h, cfg=cfg,
                                        kind="bidir", kv_x=enc_out,
                                        chunk=chunk)
            x = x + a
        if _has_ffn(cfg):
            h = layers.norm_apply(params["norm2"], x, cfg.norm, cfg.norm_eps)
            f, aux = _ffn_apply(params["ffn"], h, cfg)
            if cfg.post_block_norm:
                f = layers.norm_apply(params["post2"], f, cfg.norm,
                                      cfg.norm_eps)
            x = x + f
    elif kind == "mamba2":
        y, new_cache = ssm.mamba2_apply(params["mamba"], h, cfg, cache)
        x = x + y.astype(x.dtype)
    elif kind == "slstm":
        y, new_cache = xlstm.slstm_apply(params["cell"], h, cfg, cache)
        x = x + y.astype(x.dtype)
    elif kind == "mlstm":
        y, new_cache = xlstm.mlstm_apply(params["cell"], h, cfg, cache)
        x = x + y.astype(x.dtype)
    else:
        raise ValueError(kind)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack of super-blocks
# ---------------------------------------------------------------------------


def stack_init(key, cfg, n_units: int, pattern=None, dtype=jnp.float32):
    """Returns {"units": unit-stacked params, "shared": shared params}."""
    pattern = pattern if pattern is not None else cfg.block_pattern
    shared = {}
    if "shared_attn" in pattern:
        key, sk = jax.random.split(key)
        shared["shared_attn"] = block_init(sk, cfg, "shared_attn", dtype)

    def unit_init(k):
        ks = jax.random.split(k, len(pattern))
        unit = {}
        for i, kind in enumerate(pattern):
            if kind == "shared_attn":
                unit[f"b{i}"] = {}        # params live in `shared`
            else:
                unit[f"b{i}"] = block_init(ks[i], cfg, kind, dtype)
        return unit

    unit_keys = jax.random.split(key, n_units)
    units = jax.vmap(unit_init)(unit_keys)
    return {"units": units, "shared": shared}


def stack_cache_init(batch: int, cfg, n_units: int, s_max: int,
                     pattern=None, dtype=jnp.bfloat16, ring: bool = False,
                     swa_override: int = 0):
    """``ring=True`` trims sliding-window layers' caches to their window
    (ring-buffer slots): attn:local uses cfg.swa_window; when
    ``swa_override`` is set (the explicit long-context variant) global
    layers are windowed too."""
    pattern = pattern if pattern is not None else cfg.block_pattern

    def slots(kind):
        if not ring:
            return 0
        if kind == "attn:local":
            return cfg.swa_window
        if kind in ("attn:global", "shared_attn") and swa_override:
            return swa_override
        return 0

    def one_unit(_):
        return {f"b{i}": block_cache_init(batch, cfg, kind, s_max, dtype,
                                          window_slots=slots(kind))
                for i, kind in enumerate(pattern)}

    unit = one_unit(None)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_units,) + a.shape),
                        unit)


def stack_apply(params, x, *, cfg, pattern=None, positions=None,
                caches=None, pos=None, enc_out=None, chunk: int = 1024,
                swa_override: Optional[int] = None, bidir: bool = False):
    """Apply all super-blocks.  Returns (x, new_caches, aux_total).

    ``swa_override``: when set, every attn:global runs as sliding-window
    with this window (the explicit long-context variant, see DESIGN.md).
    ``bidir``: bidirectional self-attention (whisper encoder).
    """
    pattern = pattern if pattern is not None else cfg.block_pattern
    shared = params["shared"]

    def superblock(x_aux, unit_params, unit_caches):
        x, aux = x_aux
        new_caches = {}
        for i, kind in enumerate(pattern):
            bp = (shared["shared_attn"] if kind == "shared_attn"
                  else unit_params[f"b{i}"])
            cache_i = None if unit_caches is None else unit_caches[f"b{i}"]
            attn_kind, window = "causal", 0
            if kind == "attn:local":
                attn_kind, window = "local", cfg.swa_window
            elif kind in ("attn:global", "shared_attn", "dec"):
                if swa_override:
                    attn_kind, window = "local", swa_override
            if bidir and kind.startswith("attn"):
                attn_kind, window = "bidir", 0
            if kind == "dec" and enc_out is None:
                raise ValueError("dec block needs enc_out")
            x, nc, aux_i = block_apply(
                bp, x, cfg=cfg, kind=kind, positions=positions,
                attn_kind=attn_kind, window=window, cache=cache_i, pos=pos,
                enc_out=enc_out, chunk=chunk)
            new_caches[f"b{i}"] = nc
            aux = aux + aux_i
        return (x, aux), new_caches

    if cfg.remat:
        superblock = jax.checkpoint(superblock)

    aux0 = jnp.zeros((), jnp.float32)
    if caches is None:
        def body(carry, unit_params):
            carry, _ = superblock(carry, unit_params, None)
            return carry, None
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["units"])
        return x, None, aux

    def body(carry, xs):
        unit_params, unit_caches = xs
        carry, new_caches = superblock(carry, unit_params, unit_caches)
        return carry, new_caches

    (x, aux), new_caches = jax.lax.scan(body, (x, aux0),
                                        (params["units"], caches))
    return x, new_caches, aux
