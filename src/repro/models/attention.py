"""Attention: GQA, causal/local/bidirectional masks, softcap, KV caches.

The core ``attention`` function is flash-style: it never materializes the
full (Sq, Skv) score matrix when Skv is large — it scans over KV chunks
with an online-softmax accumulator.  This is also the jnp oracle for the
Pallas ``block_attention`` kernel (kernels/block_attention/ref.py wraps it).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -2.0 ** 30  # large-but-finite: keeps padded-row softmax NaN-free


def _mask(q_pos, kv_pos, kind: str, window: int, kv_len):
    """Boolean mask (..., Sq, Skv): True = attend."""
    pq = q_pos[..., :, None]
    pk = kv_pos[..., None, :]
    if kind == "bidir":
        m = jnp.ones(jnp.broadcast_shapes(pq.shape, pk.shape), bool)
    elif kind == "causal":
        m = pk <= pq
    elif kind == "local":
        m = (pk <= pq) & (pk > pq - window)
    else:
        raise ValueError(kind)
    if kv_len is not None:
        m = m & (pk < kv_len)
    return m


def attention(q, k, v, *, kind: str = "causal", window: int = 0,
              softcap: float = 0.0, q_offset=0, kv_len=None,
              chunk: int = 1024, scale: Optional[float] = None):
    """GQA attention.

    q: (B, Sq, nh, hd);  k, v: (B, Skv, nkv, hd);  nh % nkv == 0.
    ``q_offset``: position of q[0] (decode: current length-1).
    ``kv_len``: number of valid cache entries (decode), None = all valid.
    """
    B, Sq, nh, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    scale = scale if scale is not None else hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, nkv, g, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def scores_of(k_chunk, kv_pos):
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_chunk.astype(jnp.float32))
        if softcap > 0.0:
            s = softcap_ * jnp.tanh(s / softcap_)
        m = _mask(q_pos, kv_pos, kind, window, kv_len)     # (Sq, chunk)
        return jnp.where(m[None, None, None], s, NEG_INF)

    softcap_ = softcap

    # Direct (non-chunked) path: small KV, or decode (Sq == 1, where the
    # score tensor is linear in Skv and chunking would only force XLA to
    # gather a sequence-sharded cache — flash-decode stays sharded here).
    if Skv <= chunk or Sq == 1:
        s = scores_of(k, jnp.arange(Skv))                  # (B,nkv,g,Sq,Skv)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
        return o.reshape(B, Sq, nh, hd).astype(q.dtype)

    # --- online-softmax scan over KV chunks (flash-style) -----------------
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    eff_len = kv_len if kv_len is not None else Skv

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_i, v_i, idx = xs
        kv_pos = idx * chunk + jnp.arange(chunk)
        s = scores_of(k_i, kv_pos)                         # (B,nkv,g,Sq,chunk)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[..., None])
        corr = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p, v_i.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_cur, l_cur, acc), None

    init = (jnp.full((B, nkv, g, Sq), NEG_INF, jnp.float32),
            jnp.zeros((B, nkv, g, Sq), jnp.float32),
            jnp.zeros((B, nkv, g, Sq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  (kc, vc, jnp.arange(n_chunks)))
    del m, eff_len
    o = acc / jnp.maximum(l[..., None], 1e-30)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, nh, hd)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + GQA) and KV cache
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype=jnp.float32):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], d, qd, dtype),
        "wk": layers.dense_init(ks[1], d, kvd, dtype),
        "wv": layers.dense_init(ks[2], d, kvd, dtype),
        "wo": layers.dense_init(ks[3], qd, d, dtype),
    }


def init_kv_cache(batch: int, s_max: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {"k": jnp.zeros((batch, s_max, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, s_max, n_kv, head_dim), dtype)}


def update_kv_cache(cache, k_new, v_new, pos):
    """Write k/v (B, Sq, nkv, hd) at position ``pos`` (scalar)."""
    idx = (0, pos, 0, 0)
    return {"k": jax.lax.dynamic_update_slice(cache["k"],
                                              k_new.astype(cache["k"].dtype), idx),
            "v": jax.lax.dynamic_update_slice(cache["v"],
                                              v_new.astype(cache["v"].dtype), idx)}


def update_kv_cache_ring(cache, k_new, v_new, pos):
    """Ring-buffer write for window-trimmed caches (W slots, W = window):
    slot(p) = p mod W.  Sliding-window layers never need more than the
    last W tokens, so the cache holds exactly the window — the §Perf
    memory-term optimization for decode shapes.

    Decode (Sq == 1): write at slot pos %% W.
    Prefill (Sq >= W, pos == 0): keep only the last W tokens, rolled so
    element at slot i has position p ≡ i (mod W).
    """
    W = cache["k"].shape[1]
    Sq = k_new.shape[1]
    if Sq == 1:
        slot = jnp.asarray(pos) % W
        idx = (0, slot, 0, 0)
        return {"k": jax.lax.dynamic_update_slice(
                    cache["k"], k_new.astype(cache["k"].dtype), idx),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v_new.astype(cache["v"].dtype), idx)}
    if Sq >= W:
        kt = jnp.roll(k_new[:, -W:], Sq % W, axis=1)
        vt = jnp.roll(v_new[:, -W:], Sq % W, axis=1)
        return {"k": kt.astype(cache["k"].dtype),
                "v": vt.astype(cache["v"].dtype)}
    # short prefill from position `pos` (assumed no wrap)
    return update_kv_cache(cache, k_new, v_new, pos)


def attn_apply(params, x, *, cfg, kind: str, positions=None, window: int = 0,
               cache=None, pos=None, kv_x=None, chunk: int = 1024):
    """Full attention sub-layer (no norm/residual — caller owns those).

    x: (B, Sq, d).  ``kv_x``: cross-attention source (B, Skv, d) — when
    given, k/v come from it and the mask is bidirectional.
    ``cache``/``pos``: decode-mode KV cache handling.
    Returns (out, new_cache).
    """
    B, Sq, _ = x.shape
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = layers.dense_apply(params["wq"], x).reshape(B, Sq, nh, hd)
    src = kv_x if kv_x is not None else x
    k = layers.dense_apply(params["wk"], src).reshape(B, src.shape[1], nkv, hd)
    v = layers.dense_apply(params["wv"], src).reshape(B, src.shape[1], nkv, hd)

    if kv_x is not None:
        kind = "bidir"

    if positions is not None and cfg.rope != "none" and kv_x is None:
        if cfg.rope == "mrope":
            q = layers.apply_mrope(q, positions, cfg.rope_theta)
            k = layers.apply_mrope(k, positions, cfg.rope_theta)
        elif cfg.rope == "rope":
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
        # sincos positions are added at the embedding, not rotary.

    q_offset, kv_len = 0, None
    if cache is not None:
        # window-trimmed ring cache: a local-attention layer whose cache
        # holds exactly `window` slots (slot = position mod W)
        ring = (kind == "local" and window > 0
                and cache["k"].shape[1] <= window)
        if ring:
            W = cache["k"].shape[1]
            cache = update_kv_cache_ring(cache, k, v, pos)
            if Sq == 1:
                # ring slots are an arbitrary permutation of the last
                # min(pos+1, W) positions — all inside the window, so the
                # mask is just slot validity (RoPE was applied at write).
                k, v = cache["k"], cache["v"]
                kind, window = "bidir", 0
                kv_len = jnp.minimum(pos + 1, W)
            # prefill: attend over the in-call k/v with the plain local
            # mask; the ring cache is storage for later decode steps.
        else:
            cache = update_kv_cache(cache, k, v, pos)
            k, v = cache["k"], cache["v"]
            q_offset = pos
            kv_len = pos + Sq

    out = attention(q, k.astype(q.dtype), v.astype(q.dtype), kind=kind,
                    window=window, softcap=cfg.attn_softcap,
                    q_offset=q_offset, kv_len=kv_len, chunk=chunk)
    out = layers.dense_apply(params["wo"], out.reshape(B, Sq, nh * hd))
    return out, cache
