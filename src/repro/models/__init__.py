from repro.models.model import SplitModel  # noqa: F401
