"""SplitModel — the paper's multi-headed SplitNN wrapped around any
assigned architecture.

The full network (``cfg.n_superblocks`` super-blocks) is split by layer:
each of ``cfg.split.n_owners`` data owners runs an identical *head segment*
(embedding + ``cut_layer`` super-blocks) on its private vertical slice of
the input; the data scientist combines the cut-layer activations
(concat | sum | mean | max) and runs the *trunk segment* (remaining
super-blocks + final norm + LM head) and the loss.

Vertical-partition semantics per family (DESIGN.md §2):
  text     owner p holds sequence slice [p*S/P, (p+1)*S/P)
  vlm      owner 0 holds patch embeddings (frontend stub), owner 1 text
  audio    owner 0 holds frame embeddings; head = whisper encoder,
           trunk = whisper decoder (enc-dec IS a SplitNN)

Head params for text archs are stacked on a leading owner dim (the paper's
symmetric-segment assumption) so the owner dim can be sharded over the
``pod`` mesh axis — the cut-layer all-gather is then the only cross-pod
collective, PyVertical's communication pattern at datacenter scale.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import attention, layers, transformer
from repro.sharding.specs import constrain

Params = Dict[str, Any]


def _cdtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


class SplitModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        sp = cfg.split
        self.P = sp.n_owners
        if cfg.enc_dec:
            self.n_head_units = cfg.n_enc_layers  # encoder layers (pattern len 1)
            self.n_trunk_units = cfg.n_layers
            self.head_pattern = ("attn:global",)  # bidirectional handled below
            self.trunk_pattern = ("dec",)
        else:
            n_units = cfg.n_superblocks
            cut = min(max(sp.cut_layer, 1), n_units - 1)
            self.n_head_units = cut
            self.n_trunk_units = n_units - cut
            self.head_pattern = cfg.block_pattern
            self.trunk_pattern = cfg.block_pattern
        self.k = sp.cut_dim if sp.cut_dim > 0 else cfg.d_model

    # ------------------------------------------------------------------ init

    def _head_init_one(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        p: Params = {"blocks": transformer.stack_init(
            ks[0], cfg, self.n_head_units, self.head_pattern, _pdtype(cfg))}
        if cfg.modality == "text":
            p["embed"] = layers.embed_init(ks[1], cfg.vocab, cfg.d_model,
                                           _pdtype(cfg))
        elif cfg.modality == "vision_text":
            # owner 0: frontend projection; owner 1: token embedding.
            # symmetric param STRUCTURE (stackable), asymmetric use.
            p["embed"] = layers.embed_init(ks[1], cfg.vocab, cfg.d_model,
                                           _pdtype(cfg))
            p["front_proj"] = layers.dense_init(
                ks[2], cfg.d_frontend or cfg.d_model, cfg.d_model,
                _pdtype(cfg))
        elif cfg.modality == "audio_text":
            p["front_proj"] = layers.dense_init(
                ks[2], cfg.d_frontend or cfg.d_model, cfg.d_model,
                _pdtype(cfg))
        if cfg.split.cut_dim > 0:
            p["cut_proj"] = layers.dense_init(ks[3], cfg.d_model, self.k,
                                              _pdtype(cfg))
        return p

    def init(self, key) -> Params:
        cfg = self.cfg
        kh, kt = jax.random.split(key)
        head_keys = jax.random.split(kh, self.P)
        heads = jax.vmap(self._head_init_one)(head_keys)

        ks = jax.random.split(kt, 4)
        trunk: Params = {"blocks": transformer.stack_init(
            ks[0], cfg, self.n_trunk_units, self.trunk_pattern, _pdtype(cfg))}
        if cfg.split.cut_dim > 0:
            trunk["in_proj"] = layers.dense_init(ks[1], self.k, cfg.d_model,
                                                 _pdtype(cfg))
        trunk["out_norm"] = layers.norm_init(cfg.d_model, cfg.norm,
                                             _pdtype(cfg))
        trunk["lm_head"] = layers.dense_init(ks[2], cfg.d_model, cfg.vocab,
                                             _pdtype(cfg), scale=0.02)
        if cfg.enc_dec:
            trunk["embed"] = layers.embed_init(ks[3], cfg.vocab, cfg.d_model,
                                               _pdtype(cfg))
        return {"heads": heads, "trunk": trunk}

    def param_specs(self, key=None):
        """Shape/dtype structure of params without allocating (dry-run)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # ------------------------------------------------------------- embedding

    def _embed_owner(self, hp, owner_inputs, owner_index, dtype):
        """Map one owner's raw vertical slice to (B, S_p, d)."""
        cfg = self.cfg
        if cfg.modality == "text":
            return layers.embed_apply(hp["embed"], owner_inputs, dtype)
        if cfg.modality == "vision_text":
            if owner_index == 0:   # vision owner: precomputed patch embeds
                return layers.dense_apply(hp["front_proj"],
                                          owner_inputs.astype(dtype))
            return layers.embed_apply(hp["embed"], owner_inputs, dtype)
        if cfg.modality == "audio_text":
            return layers.dense_apply(hp["front_proj"],
                                      owner_inputs.astype(dtype))
        raise ValueError(cfg.modality)

    def _positions(self, S_p: int, owner: int, offset=0):
        """Global positions of owner ``owner``'s slice (rope input)."""
        cfg = self.cfg
        base = owner * S_p + offset + jnp.arange(S_p)
        if cfg.rope == "mrope":
            if cfg.modality == "vision_text" and owner == 0:
                # vision grid (t=0, h, w): synthetic sqrt grid
                side = max(int(np.sqrt(S_p)), 1)
                h = jnp.arange(S_p) // side
                w = jnp.arange(S_p) % side
                t = jnp.zeros((S_p,), jnp.int32)
                return jnp.stack([t, h, w], axis=-1)
            return jnp.stack([base] * 3, axis=-1)
        return base

    # ------------------------------------------------------------ head pass

    def _head_one(self, hp, owner_inputs, positions, owner_index,
                  caches=None, pos=None, swa_override=None):
        cfg = self.cfg
        x = self._embed_owner(hp, owner_inputs, owner_index, _cdtype(cfg))
        if cfg.rope == "sincos":
            S_p = x.shape[1]
            off = pos if pos is not None else 0
            x = x + layers.sincos_positions(off + jnp.arange(S_p),
                                            cfg.d_model).astype(x.dtype)
        x, new_caches, aux = transformer.stack_apply(
            hp["blocks"], x, cfg=cfg, pattern=self.head_pattern,
            positions=positions, caches=caches, pos=pos,
            swa_override=swa_override,
            bidir=cfg.enc_dec and cfg.enc_bidirectional)
        if cfg.split.cut_dim > 0:
            x = layers.dense_apply(hp["cut_proj"], x)
        return x, new_caches, aux

    def heads_forward(self, heads, owner_inputs, *, caches=None, pos=None,
                      rng=None, swa_override=None):
        """owner_inputs: text: (P, B, S_p) — vmapped over owners.
        vlm/audio: dict with per-owner entries — python loop (asymmetric).
        Returns (cut (P, B, S_p, k), caches, aux)."""
        cfg = self.cfg
        if cfg.modality == "text":
            S_p = owner_inputs.shape[-1]
            positions = jnp.stack(
                [self._positions(S_p, p, 0 if pos is None else pos)
                 for p in range(self.P)])

            def one(hp, ti, po, ca):
                return self._head_one(hp, ti, po, 0, ca, pos, swa_override)

            if caches is None:
                cut, new_caches, aux = jax.vmap(
                    lambda hp, ti, po: one(hp, ti, po, None))(
                        heads, owner_inputs, positions)
            else:
                cut, new_caches, aux = jax.vmap(one)(
                    heads, owner_inputs, positions, caches)
            aux = jnp.sum(aux)
        else:
            # asymmetric modality heads: loop owners (P == ragged inputs)
            cuts, new_caches, aux = [], [], 0.0
            keys = list(owner_inputs.keys())
            for p, name in enumerate(keys):
                hp = jax.tree.map(lambda a: a[p], heads)
                S_p = owner_inputs[name].shape[1]
                positions = self._positions(S_p, p,
                                            0 if pos is None else pos)
                ca = None if caches is None else caches[name]
                c, nc, a = self._head_one(hp, owner_inputs[name], positions,
                                          p, ca, pos, swa_override)
                cuts.append(c)
                new_caches.append(nc)
                aux = aux + a
            cut = jnp.stack(cuts) if len({c.shape for c in cuts}) == 1 \
                else cuts
            new_caches = (None if caches is None
                          else dict(zip(keys, new_caches)))
            return cut, new_caches, aux
        return cut, new_caches, aux

    # ------------------------------------------------------------- combine

    def combine(self, cut, rng=None):
        """The paper's cut-layer combine (data-scientist side).

        cut: (P, B, S_p, k) stacked or list of (B, S_i, k).
        concat: along the sequence (ID-aligned order) -> (B, S, k)
        sum/mean/max: elementwise across owners -> (B, S_p, k)
        """
        sp = self.cfg.split
        if sp.cut_noise_std > 0.0 and rng is not None:
            noise = lambda a: a + sp.cut_noise_std * jax.random.normal(
                rng, a.shape, a.dtype)
            cut = ([noise(c) for c in cut] if isinstance(cut, list)
                   else noise(cut))
        if isinstance(cut, list):
            if sp.combine != "concat":
                raise ValueError("ragged cuts support concat only")
            return jnp.concatenate(cut, axis=1)
        P, B, S_p, k = cut.shape
        if sp.combine == "concat":
            return cut.transpose(1, 0, 2, 3).reshape(B, P * S_p, k)
        if sp.combine == "sum":
            return cut.sum(0)
        if sp.combine == "mean":
            return cut.mean(0)
        if sp.combine == "max":
            return cut.max(0)
        raise ValueError(sp.combine)

    # ---------------------------------------------------------- trunk pass

    def trunk_forward(self, trunk, z, *, caches=None, pos=None, enc_out=None,
                      dec_tokens=None, swa_override=None):
        """z: combined cut (B, S, k) (or enc output for enc_dec).

        enc_dec: trunk is the whisper decoder over ``dec_tokens`` with
        cross-attention to z.  Returns (logits, caches, aux)."""
        cfg = self.cfg
        if cfg.split.cut_dim > 0:
            z = layers.dense_apply(trunk["in_proj"], z)
        if cfg.enc_dec:
            x = layers.embed_apply(trunk["embed"], dec_tokens, _cdtype(cfg))
            off = pos if pos is not None else 0
            S_d = dec_tokens.shape[1]
            x = x + layers.sincos_positions(off + jnp.arange(S_d),
                                            cfg.d_model).astype(x.dtype)
            positions = (pos if pos is not None else 0) + jnp.arange(S_d)
            enc_out = z
        else:
            x = z
            S = x.shape[1]
            off = pos if pos is not None else 0
            base = off + jnp.arange(S)
            positions = (jnp.stack([base] * 3, -1) if cfg.rope == "mrope"
                         else base)
        x, new_caches, aux = transformer.stack_apply(
            trunk["blocks"], x, cfg=cfg, pattern=self.trunk_pattern,
            positions=positions, caches=caches, pos=pos, enc_out=enc_out,
            swa_override=swa_override)
        x = layers.norm_apply(trunk["out_norm"], x, cfg.norm, cfg.norm_eps)
        x = constrain(x, "trunk_hidden")
        logits = layers.dense_apply(trunk["lm_head"],
                                    x.astype(jnp.float32))
        logits = layers.softcap(logits, cfg.logit_softcap)
        logits = constrain(logits, "logits")
        return logits, new_caches, aux

    # ------------------------------------------------------------- forward

    def split_owner_inputs(self, batch):
        """Vertical partition of a global batch into per-owner slices."""
        cfg = self.cfg
        if "owner_tokens" in batch:                   # pre-partitioned (P,B,S_p)
            return batch["owner_tokens"]
        if cfg.modality == "text":
            t = batch["tokens"]                       # (B, S)
            B, S = t.shape
            S_p = S // self.P
            return t.reshape(B, self.P, S_p).transpose(1, 0, 2)
        if cfg.modality == "vision_text":
            return {"patches": batch["patches"], "tokens": batch["tokens"]}
        if cfg.modality == "audio_text":
            return {"frames": batch["frames"]}
        raise ValueError(cfg.modality)

    def forward(self, params, batch, *, rng=None, swa_override=None):
        """Full-sequence forward (train / prefill-no-cache).

        Returns (logits (B, S, vocab), aux)."""
        cfg = self.cfg
        oi = self.split_owner_inputs(batch)
        cut, _, aux_h = self.heads_forward(params["heads"], oi, rng=rng,
                                           swa_override=swa_override)
        if not isinstance(cut, list):
            # the cut tensor is THE protocol traffic (owner -> scientist):
            # pin it to the compute dtype so the cross-pod gather moves
            # bf16, not an upcast (§Perf cut-precision lever)
            cut = constrain(cut.astype(_cdtype(self.cfg)), "cut_stacked")
        z = self.combine(cut, rng=rng)
        z = constrain(z, "combined")
        dec_tokens = batch.get("tokens") if cfg.enc_dec else None
        logits, _, aux_t = self.trunk_forward(
            params["trunk"], z, dec_tokens=dec_tokens,
            swa_override=swa_override)
        return logits, aux_h + aux_t

    @staticmethod
    def ce_loss(logits, labels):
        """Causal LM loss (labels: next-token ids, -100 = masked)."""
        valid = labels >= 0
        lab = jnp.where(valid, labels, 0)
        # vocab-sharding-friendly CE: never gathers the (B, S, V) logits —
        # logsumexp is a sharded reduction and the label logit is picked
        # with an iota comparison (elementwise on the sharded dim).
        lse = jax.scipy.special.logsumexp(logits, axis=-1)        # (B, S)
        vio = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        label_logit = jnp.sum(
            jnp.where(vio == lab[..., None], logits, 0.0), axis=-1)
        ll = label_logit - lse
        n = jnp.maximum(jnp.sum(valid), 1)
        return -jnp.sum(ll * valid) / n

    def loss_fn(self, params, batch, *, rng=None, swa_override=None):
        logits, aux = self.forward(params, batch, rng=rng,
                                   swa_override=swa_override)
        loss = self.ce_loss(logits, batch["labels"])
        return loss + aux, {"loss": loss, "aux": aux}

    # ------------------------------------------------------------ serving

    def cache_init(self, batch_size: int, s_max: int, n_new: int = 8,
                   ring: bool = False, swa_override: int = 0,
                   cache_dtype=None):
        """Decode caches.  Trunk cache covers the combined sequence; head
        caches cover each owner's slice (+ room for generated tokens).
        ``ring``: trim sliding-window layers to ring buffers (§Perf);
        ``cache_dtype``: e.g. float8_e4m3fn KV storage (§Perf)."""
        cfg = self.cfg
        dt = jnp.dtype(cache_dtype) if cache_dtype else _cdtype(cfg)
        kw = dict(ring=ring, swa_override=swa_override)
        if cfg.modality == "text":
            s_head = s_max // self.P + n_new
            one = transformer.stack_cache_init(
                batch_size, cfg, self.n_head_units, s_head,
                self.head_pattern, dt, **kw)
            head_caches = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.P,) + a.shape), one)
        elif cfg.modality == "vision_text":
            s_head = s_max // self.P + n_new
            one = transformer.stack_cache_init(
                batch_size, cfg, self.n_head_units, s_head,
                self.head_pattern, dt, **kw)
            head_caches = {"patches": one, "tokens": jax.tree.map(
                jnp.copy, one)}
        else:   # audio: encoder is cache-free at decode (static enc_out)
            head_caches = None
        s_trunk = s_max + n_new
        trunk_caches = transformer.stack_cache_init(
            batch_size, cfg, self.n_trunk_units, s_trunk,
            self.trunk_pattern, dt, **kw)
        out = {"heads": head_caches, "trunk": trunk_caches}
        if cfg.enc_dec:
            out["enc"] = jnp.zeros((batch_size, s_max // 2, self.k), dt)
        return out

    def prefill(self, params, batch, caches, *, swa_override=None):
        """Process the full context, building caches.  Returns
        (last-token logits, caches)."""
        cfg = self.cfg
        oi = self.split_owner_inputs(batch)
        cut, head_caches, _ = self.heads_forward(
            params["heads"], oi, caches=caches["heads"], pos=0,
            swa_override=swa_override)
        z = self.combine(cut)
        if cfg.enc_dec:
            # encoder output is static: stash it; prefill decoder tokens.
            logits, trunk_caches, _ = self.trunk_forward(
                params["trunk"], z, caches=caches["trunk"], pos=0,
                dec_tokens=batch["tokens"], swa_override=swa_override)
            return logits[:, -1], {"heads": head_caches,
                                   "trunk": trunk_caches, "enc": z}
        logits, trunk_caches, _ = self.trunk_forward(
            params["trunk"], z, caches=caches["trunk"], pos=0,
            swa_override=swa_override)
        return logits[:, -1], {"heads": head_caches, "trunk": trunk_caches}

    # ------------------------------------------- per-segment serving programs
    #
    # prefill/decode_step above run heads + trunk as one program.  When the
    # engine serves through a transport-backed boundary, it uses these
    # split halves instead, so the cut activations are a real wire payload
    # (measured bytes) rather than an internal value.  Text, decoder-only.

    def prefill_heads(self, heads, owner_inputs, head_caches, *,
                      swa_override=None):
        """Owner side of prefill: (cut (P, B, S_p, k), head caches)."""
        cut, hc, _ = self.heads_forward(heads, owner_inputs,
                                        caches=head_caches, pos=0,
                                        swa_override=swa_override)
        return cut, hc

    def prefill_trunk(self, trunk, cut, trunk_caches, *, swa_override=None):
        """Scientist side of prefill: combine the received cut and run the
        trunk.  Returns (last-token logits, trunk caches)."""
        z = self.combine(cut)
        logits, tc, _ = self.trunk_forward(trunk, z, caches=trunk_caches,
                                           pos=0, swa_override=swa_override)
        return logits[:, -1], tc

    def decode_heads(self, heads, token, head_caches, pos_local, *,
                     swa_override=None):
        """Owner side of one decode step: the generation owner's cut slice
        (B, 1, k) plus updated head caches."""
        oi = jnp.broadcast_to(token[None], (self.P,) + token.shape)
        cut, hc, _ = self.heads_forward(heads, oi, caches=head_caches,
                                        pos=pos_local,
                                        swa_override=swa_override)
        return cut[0], hc

    def decode_trunk(self, trunk, z, trunk_caches, pos, *,
                     swa_override=None):
        logits, tc, _ = self.trunk_forward(trunk, z, caches=trunk_caches,
                                           pos=pos,
                                           swa_override=swa_override)
        return logits[:, -1], tc

    def decode_step(self, params, caches, token, pos, pos_local,
                    *, swa_override=None):
        """One new token (B, 1).  The generation owner is owner 0 (the
        paper allows the scientist to also be a data owner).  ``pos``:
        global position in the combined sequence; ``pos_local``: position
        within owner 0's slice/cache."""
        cfg = self.cfg
        if cfg.enc_dec:
            logits, trunk_caches, _ = self.trunk_forward(
                params["trunk"], caches["enc"], caches=caches["trunk"],
                pos=pos, dec_tokens=token, swa_override=swa_override)
            new = dict(caches)
            new["trunk"] = trunk_caches
            return logits[:, -1], new

        if cfg.modality == "text":
            oi = jnp.broadcast_to(token[None], (self.P,) + token.shape)
            cut, head_caches, _ = self.heads_forward(
                params["heads"], oi, caches=caches["heads"], pos=pos_local,
                swa_override=swa_override)
            z = cut[0]                                 # generation owner
        else:   # vlm: route the new token through the text-owner head
            hp = jax.tree.map(lambda a: a[1], params["heads"])
            positions = pos + jnp.arange(1)
            if cfg.rope == "mrope":
                positions = jnp.stack([positions] * 3, -1)
            z, tok_caches, _ = self._head_one(
                hp, token, positions, 1, caches["heads"]["tokens"],
                pos_local, swa_override)
            head_caches = {"patches": caches["heads"]["patches"],
                           "tokens": tok_caches}
        logits, trunk_caches, _ = self.trunk_forward(
            params["trunk"], z, caches=caches["trunk"], pos=pos,
            swa_override=swa_override)
        return logits[:, -1], {"heads": head_caches, "trunk": trunk_caches}
