"""xLSTM blocks: mLSTM (chunked parallel, matrix memory) and sLSTM
(sequential scan, scalar memory with exponential gating) [arXiv:2405.04517].

Both use max-state stabilization of the exponential gates.  The mLSTM is a
gated linear-attention recurrence and is computed chunkwise (one chunk per
scan step), so HLO size and live memory are sequence-length independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.ssm import conv1d_apply

NEG = -2.0 ** 30


# ---------------------------------------------------------------------------
# mLSTM core (chunkwise parallel with (C, n, m) carry)
# ---------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_raw, f_raw, chunk: int, carry=None):
    """q,k,v: (B, S, H, D); i_raw, f_raw: (B, S, H).

    Returns (y (B,S,H,D), carry=(C (B,H,D,D), n (B,H,D), m (B,H))).
    """
    Bb, S, H, D = q.shape
    f32 = jnp.float32
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S

    def padded(a, fill=0.0):
        if pad:
            a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                        constant_values=fill)
        return a.astype(f32)

    qc = padded(q).reshape(Bb, nc, L, H, D).transpose(1, 0, 2, 3, 4)
    kc = padded(k).reshape(Bb, nc, L, H, D).transpose(1, 0, 2, 3, 4)
    vc = padded(v).reshape(Bb, nc, L, H, D).transpose(1, 0, 2, 3, 4)
    # pad f with 0 raw -> logsigmoid(0) ≈ -0.69 decay; pad i with NEG (no input)
    ic = padded(i_raw, NEG).reshape(Bb, nc, L, H).transpose(1, 0, 2, 3)
    fc = padded(f_raw).reshape(Bb, nc, L, H).transpose(1, 0, 2, 3)

    if carry is None:
        C0 = jnp.zeros((Bb, H, D, D), f32)
        n0 = jnp.zeros((Bb, H, D), f32)
        m0 = jnp.full((Bb, H), NEG, f32)
    else:
        C0, n0, m0 = (c.astype(f32) for c in carry)

    scale = D ** -0.5
    tri = jnp.tril(jnp.ones((L, L), bool))

    def body(c, xs):
        Cp, np_, mp = c
        qk_, kk, vk, ik, fk = xs
        logf = jax.nn.log_sigmoid(fk)                    # (B,L,H)
        b = jnp.cumsum(logf, axis=1)                     # inclusive
        # intra log-weights w_ij = b_i - logf_i? standard: decay from j+1..i
        # state after step j carries to i via sum_{t=j+1..i} logf_t = b_i - b_j
        wij = b[:, :, None, :] - b[:, None, :, :] \
            + ik[:, None, :, :]                          # (B,i,j,H)
        wij = jnp.where(tri[None, :, :, None], wij, NEG)
        u = b + mp[:, None, :]                           # (B,L,H) inter weight
        m_new = jnp.maximum(jnp.max(wij, axis=2), u)     # (B,L,H)
        m_new = jnp.maximum(m_new, -m_new * 0 + NEG / 2)  # clamp
        w = jnp.exp(wij - m_new[:, :, None, :])          # (B,i,j,H)
        inter = jnp.exp(u - m_new)                       # (B,L,H)

        s = jnp.einsum("blhd,bmhd->blmh", qk_ * scale, kk)  # (B,i,j,H)
        num = jnp.einsum("blmh,blmh,bmhd->blhd", s, w, vk) \
            + inter[..., None] * jnp.einsum("blhd,bhde->blhe", qk_ * scale, Cp)
        den = jnp.einsum("blmh,blmh->blh", s, w) \
            + inter * jnp.einsum("blhd,bhd->blh", qk_ * scale, np_)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]

        # carry update
        btot = b[:, -1]                                  # (B,H)
        wlast = btot[:, None, :] - b + ik                # (B,L,H)
        m_next = jnp.maximum(btot + mp, jnp.max(wlast, axis=1))
        wl = jnp.exp(wlast - m_next[:, None, :])
        Cn = jnp.exp(btot + mp - m_next)[..., None, None] * Cp \
            + jnp.einsum("blh,blhd,blhe->bhde", wl, kk, vk)
        nn = jnp.exp(btot + mp - m_next)[..., None] * np_ \
            + jnp.einsum("blh,blhd->bhd", wl, kk)
        return (Cn, nn, m_next), y

    (Cf, nf, mf), yc = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bb, nc * L, H, D)[:, :S]
    return y.astype(q.dtype), (Cf, nf, mf)


def mlstm_step(q, k, v, i_raw, f_raw, carry):
    """Single decode step.  q,k,v: (B,1,H,D); carry=(C,n,m)."""
    f32 = jnp.float32
    D = q.shape[-1]
    Cp, np_, mp = (c.astype(f32) for c in carry)
    qf = q[:, 0].astype(f32) * (D ** -0.5)
    kf, vf = k[:, 0].astype(f32), v[:, 0].astype(f32)
    ik, fk = i_raw[:, 0].astype(f32), f_raw[:, 0].astype(f32)
    logf = jax.nn.log_sigmoid(fk)
    m_new = jnp.maximum(logf + mp, ik)
    fdec = jnp.exp(logf + mp - m_new)
    iin = jnp.exp(ik - m_new)
    Cn = fdec[..., None, None] * Cp + iin[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", kf, vf)
    nn = fdec[..., None] * np_ + iin[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, Cn)
    den = jnp.einsum("bhd,bhd->bh", qf, nn)
    y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return y[:, None].astype(q.dtype), (Cn, nn, m_new)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg, dtype=jnp.float32):
    x = cfg.xlstm
    d = cfg.d_model
    d_in = int(x.m_proj_factor * d)
    ks = jax.random.split(key, 8)
    return {
        "up_x": layers.dense_init(ks[0], d, d_in, dtype),
        "up_z": layers.dense_init(ks[1], d, d_in, dtype),
        "conv_w": jax.random.normal(ks[2], (x.conv_width, d_in), dtype) * 0.2,
        "wq": layers.dense_init(ks[3], d_in, d_in, dtype),
        "wk": layers.dense_init(ks[4], d_in, d_in, dtype),
        "wv": layers.dense_init(ks[5], d_in, d_in, dtype),
        "w_if": layers.dense_init(ks[6], d_in, 2 * cfg.n_heads, dtype,
                                  scale=0.02),
        "if_bias": jnp.concatenate([jnp.zeros((cfg.n_heads,), dtype),
                                    jnp.ones((cfg.n_heads,), dtype) * 3.0]),
        "out_norm": layers.norm_init(d_in, "rmsnorm", dtype),
        "down": layers.dense_init(ks[7], d_in, d, dtype),
    }


def mlstm_cache_init(batch: int, cfg, dtype=jnp.float32):
    x = cfg.xlstm
    d_in = int(x.m_proj_factor * cfg.d_model)
    H = cfg.n_heads
    D = d_in // H
    return {"conv": jnp.zeros((batch, x.conv_width - 1, d_in), dtype),
            "C": jnp.zeros((batch, H, D, D), dtype),
            "n": jnp.zeros((batch, H, D), dtype),
            "m": jnp.full((batch, H), NEG, dtype)}


def mlstm_apply(params, x, cfg, cache=None):
    xc = cfg.xlstm
    Bb, S, d = x.shape
    H = cfg.n_heads
    d_in = int(xc.m_proj_factor * d)
    D = d_in // H
    xi = layers.dense_apply(params["up_x"], x)
    z = layers.dense_apply(params["up_z"], x)
    conv_state = cache["conv"] if cache is not None else None
    xconv, new_conv = conv1d_apply(params["conv_w"], xi, conv_state)
    xconv = jax.nn.silu(xconv)
    q = layers.dense_apply(params["wq"], xconv).reshape(Bb, S, H, D)
    k = layers.dense_apply(params["wk"], xconv).reshape(Bb, S, H, D)
    v = layers.dense_apply(params["wv"], xi).reshape(Bb, S, H, D)
    gates = layers.dense_apply(params["w_if"], xconv) \
        + layers.cast(params["if_bias"], x.dtype)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)           # (B,S,H)

    if cache is not None and S == 1:          # decode
        carry = (cache["C"], cache["n"], cache["m"])
        y, (Cf, nf, mf) = mlstm_step(q, k, v, i_raw, f_raw, carry)
    else:                                     # train / prefill
        carry = ((cache["C"], cache["n"], cache["m"])
                 if cache is not None else None)
        y, (Cf, nf, mf) = mlstm_chunked(q, k, v, i_raw, f_raw, xc.chunk_size,
                                        carry=carry)

    y = y.reshape(Bb, S, d_in)
    y = layers.norm_apply(params["out_norm"], y, "rmsnorm")
    y = y * jax.nn.silu(z)
    out = layers.dense_apply(params["down"], y)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv,
                     "C": Cf.astype(cache["C"].dtype),
                     "n": nf.astype(cache["n"].dtype),
                     "m": mf.astype(cache["m"].dtype)}
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM block (true sequential recurrence)
# ---------------------------------------------------------------------------


def slstm_init(key, cfg, dtype=jnp.float32):
    x = cfg.xlstm
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    d_ff = int(x.s_proj_factor * d)
    ks = jax.random.split(key, 4)
    return {
        # 4 gates (i, f, z, o) from input ...
        "w_gates": layers.dense_init(ks[0], d, 4 * d, dtype),
        # ... and per-head recurrent connections from h_{t-1}
        "r_gates": jax.random.normal(ks[1], (H, hd, 4 * hd), dtype)
        / np.sqrt(hd),
        "gate_bias": jnp.zeros((4 * d,), dtype),
        "up": layers.dense_init(ks[2], d, d_ff, dtype),
        "down": layers.dense_init(ks[3], d_ff, d, dtype),
    }


def slstm_cache_init(batch: int, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    z = jnp.zeros((batch, H, hd), dtype)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, H, hd), NEG, dtype)}


def _slstm_cell(gx, state, r_gates):
    """One recurrence step.  gx: (B, H, 4*hd) input-side gate preacts."""
    c, n, h, m = state
    f32 = jnp.float32
    hd = h.shape[-1]
    gr = jnp.einsum("bhd,hde->bhe", h, r_gates)           # (B,H,4*hd)
    g = (gx + gr).astype(f32)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)             # (B,H,hd) each
    m_new = jnp.maximum(gf + m, gi)                       # exp-gate stabilizer
    i = jnp.exp(gi - m_new)
    f = jnp.exp(gf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new.astype(h.dtype), m_new)


def slstm_apply(params, x, cfg, cache=None):
    Bb, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    gx = layers.dense_apply(params["w_gates"], x) \
        + layers.cast(params["gate_bias"], x.dtype)
    gx = gx.reshape(Bb, S, H, 4 * hd)
    r = layers.cast(params["r_gates"], jnp.float32)

    if cache is not None and S == 1:          # decode
        st = (cache["c"].astype(jnp.float32), cache["n"].astype(jnp.float32),
              cache["h"], cache["m"].astype(jnp.float32))
        st = _slstm_cell(gx[:, 0], st, r)
        y = st[2][:, None]                                # (B,1,H,hd)
        new_cache = {"c": st[0].astype(cache["c"].dtype),
                     "n": st[1].astype(cache["n"].dtype),
                     "h": st[2],
                     "m": st[3].astype(cache["m"].dtype)}
    else:                                     # train / prefill
        if cache is not None:
            st0 = (cache["c"].astype(jnp.float32),
                   cache["n"].astype(jnp.float32), cache["h"],
                   cache["m"].astype(jnp.float32))
        else:
            z = jnp.zeros((Bb, H, hd), jnp.float32)
            st0 = (z, z, z.astype(x.dtype),
                   jnp.full((Bb, H, hd), NEG, jnp.float32))

        def body(st, gxt):
            st = _slstm_cell(gxt, st, r)
            return st, st[2]

        stf, ys = jax.lax.scan(body, st0, gx.transpose(1, 0, 2, 3))
        y = ys.transpose(1, 0, 2, 3)                      # (B,S,H,hd)
        new_cache = None
        if cache is not None:
            new_cache = {"c": stf[0].astype(cache["c"].dtype),
                         "n": stf[1].astype(cache["n"].dtype),
                         "h": stf[2],
                         "m": stf[3].astype(cache["m"].dtype)}

    y = y.reshape(Bb, -1, d)
    h = layers.dense_apply(params["up"], y)
    h = jax.nn.gelu(h, approximate=True)
    out = layers.dense_apply(params["down"], h)
    return out, new_cache
