"""MLP variants: SwiGLU / GeGLU (gated), GeLU, squared-ReLU (nemotron)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def mlp_init(key, d: int, d_ff: int, kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_in": layers.dense_init(ks[0], d, d_ff, dtype),
         "w_out": layers.dense_init(ks[1], d_ff, d, dtype)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = layers.dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(params, x, kind: str):
    h = layers.dense_apply(params["w_in"], x)
    if kind == "swiglu":
        h = jax.nn.silu(layers.dense_apply(params["w_gate"], x)) * h
    elif kind == "geglu":
        h = jax.nn.gelu(layers.dense_apply(params["w_gate"], x),
                        approximate=True) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif kind == "relu2":
        r = jax.nn.relu(h)
        h = r * r
    else:
        raise ValueError(kind)
    return layers.dense_apply(params["w_out"], h)
