"""Mixture-of-experts FFN with capacity-based token dispatch.

GShard/Switch-style routing adapted for TPU: top-k routing, per-expert
capacity ``C = ceil(T * k / E * capacity_factor)``, scatter dispatch to an
(E, C, d) buffer, batched expert matmuls (einsum over the expert dim — this
is what expert-parallel sharding over the "model" axis partitions), gather
combine.  Overflowing tokens are dropped (their choice contributes zero),
the standard capacity trade-off.

Also returns the load-balance auxiliary loss (Switch-style f_e * P_e * E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, mlp as mlp_mod
from repro.sharding.specs import constrain


def moe_init(key, d: int, moe_cfg, mlp_kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, de = moe_cfg.n_experts, moe_cfg.d_expert
    import numpy as np
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(de)
    p = {
        "router": layers.dense_init(ks[0], d, e, dtype, scale=0.02),
        "w_in": jax.random.normal(ks[1], (e, d, de), dtype) * s_in,
        "w_out": jax.random.normal(ks[2], (e, de, d), dtype) * s_out,
    }
    if mlp_kind in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(ks[3], (e, d, de), dtype) * s_in
    if moe_cfg.n_shared:
        d_sh = moe_cfg.n_shared * moe_cfg.d_shared
        p["shared"] = mlp_mod.mlp_init(ks[4], d, d_sh, mlp_kind, dtype)
    return p


def capacity(n_tokens: int, moe_cfg) -> int:
    c = int(n_tokens * moe_cfg.top_k / moe_cfg.n_experts
            * moe_cfg.capacity_factor)
    # large capacities round to 2048 so the capacity dim shards cleanly
    # over the 16-way data axis (expert-parallel x capacity-parallel)
    if c > 2048:
        return -(-c // 2048) * 2048
    return max(8, -(-c // 8) * 8)


def moe_apply(params, x, moe_cfg, mlp_kind: str):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if moe_cfg.dispatch_groups > 1:
        return _moe_apply_grouped(params, x, moe_cfg, mlp_kind)
    B, S, d = x.shape
    T = B * S
    E, K = moe_cfg.n_experts, moe_cfg.top_k
    C = capacity(T, moe_cfg)
    xf = x.reshape(T, d)

    logits = (xf @ layers.cast(params["router"]["w"], xf.dtype)
              ).astype(jnp.float32)                     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)              # (T, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # -- position of each (choice, token) within its expert ----------------
    # choice-major order: all first choices, then all second choices, ...
    e_flat = top_e.T.reshape(T * K)                     # (T*K,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos_in_e * onehot, axis=-1)           # (T*K,)
    keep = pos < C
    pos_safe = jnp.where(keep, pos, C)                  # overflow slot C

    # -- dispatch: (E, C+1, d) scatter-add ---------------------------------
    tok = jnp.tile(jnp.arange(T), K)
    buf = jnp.zeros((E, C + 1, d), xf.dtype)
    buf = buf.at[e_flat, pos_safe].add(xf[tok])
    buf = buf[:, :C]                                    # drop overflow slot
    buf = constrain(buf, "moe_buffer")                  # (E/mdl, C/data, d)

    # -- expert compute (the expert-parallel einsums) -----------------------
    h = jnp.einsum("ecd,edf->ecf", buf,
                   layers.cast(params["w_in"], buf.dtype))
    if mlp_kind in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf,
                       layers.cast(params["w_gate"], buf.dtype))
        g = jax.nn.silu(g) if mlp_kind == "swiglu" else jax.nn.gelu(
            g, approximate=True)
        h = g * h
    elif mlp_kind == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif mlp_kind == "relu2":
        r = jax.nn.relu(h)
        h = r * r
    out_buf = jnp.einsum("ecf,efd->ecd", h,
                         layers.cast(params["w_out"], h.dtype))
    out_buf = constrain(out_buf, "moe_buffer")

    # -- combine: gather each kept choice back to its token -----------------
    pos_g = jnp.where(keep, pos, 0)
    gathered = out_buf[e_flat, pos_g]                   # (T*K, d)
    w_flat = (top_w.T.reshape(T * K, 1) * keep[:, None]).astype(gathered.dtype)
    contrib = (gathered * w_flat).reshape(K, T, d).sum(0)
    out = contrib.reshape(B, S, d)

    if moe_cfg.n_shared:
        out = out + mlp_mod.mlp_apply(params["shared"], x, mlp_kind)

    # -- Switch-style load-balance loss -------------------------------------
    f_e = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e) * moe_cfg.aux_loss_weight
    return out, aux


# ---------------------------------------------------------------------------
# Group-local dispatch (§Perf): tokens are dispatched WITHIN G groups that
# align with the data-axis shards, so the (G, E, C_g, d) buffer is sharded
# on G and the scatter never crosses token shards — removing the giant
# cross-shard all-reduce the global scatter induces (the dominant term of
# the baseline MoE roofline).  Capacity is per-group (same drop trade-off
# structure, granularity G-times finer).
# ---------------------------------------------------------------------------


def _moe_apply_grouped(params, x, moe_cfg, mlp_kind: str):
    B, S, d = x.shape
    T = B * S
    G = moe_cfg.dispatch_groups
    E, K = moe_cfg.n_experts, moe_cfg.top_k
    Tg = T // G
    Cg = capacity(Tg, moe_cfg)
    xg = x.reshape(G, Tg, d)

    router_w = layers.cast(params["router"]["w"], x.dtype)

    def route_one(xt):
        """xt: (Tg, d) -> (buf (E, Cg, d), combine metadata)."""
        logits = (xt @ router_w).astype(jnp.float32)          # (Tg, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
        e_flat = top_e.T.reshape(Tg * K)
        onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, -1)
        keep = pos < Cg
        pos_safe = jnp.where(keep, pos, Cg)
        tok = jnp.tile(jnp.arange(Tg), K)
        buf = jnp.zeros((E, Cg + 1, d), xt.dtype)
        buf = buf.at[e_flat, pos_safe].add(xt[tok])
        return (buf[:, :Cg], e_flat, jnp.where(keep, pos, 0),
                (top_w.T.reshape(Tg * K, 1) * keep[:, None]), probs, top_e)

    buf, e_flat, pos_g, w_flat, probs, top_e = jax.vmap(route_one)(xg)
    buf = constrain(buf, "moe_buffer_grouped")            # (G, E, Cg, d)

    h = jnp.einsum("gecd,edf->gecf", buf,
                   layers.cast(params["w_in"], buf.dtype))
    if mlp_kind in ("swiglu", "geglu"):
        g = jnp.einsum("gecd,edf->gecf", buf,
                       layers.cast(params["w_gate"], buf.dtype))
        g = jax.nn.silu(g) if mlp_kind == "swiglu" else jax.nn.gelu(
            g, approximate=True)
        h = g * h
    elif mlp_kind == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif mlp_kind == "relu2":
        r = jax.nn.relu(h)
        h = r * r
    out_buf = jnp.einsum("gecf,efd->gecd", h,
                         layers.cast(params["w_out"], h.dtype))
    out_buf = constrain(out_buf, "moe_buffer_grouped")

    def combine_one(ob, ef, pg, wf):
        gathered = ob[ef, pg]                             # (Tg*K, d)
        return (gathered * wf.astype(gathered.dtype)).reshape(
            K, Tg, d).sum(0)

    out = jax.vmap(combine_one)(out_buf, e_flat, pos_g, w_flat)
    out = out.reshape(B, S, d)

    if moe_cfg.n_shared:
        out = out + mlp_mod.mlp_apply(params["shared"], x, mlp_kind)

    f_e = jnp.mean(jax.nn.one_hot(top_e[..., 0].reshape(-1), E,
                                  dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(f_e * p_e) * moe_cfg.aux_loss_weight
    return out, aux
