"""Minimal optax-style optimizers built on pure JAX.

An optimizer is ``(init, update)``: ``state = init(params)``;
``updates, state = update(grads, state, params, step)``; apply with
``params = apply_updates(params, updates)``.

``multi_segment`` is the PyVertical-specific piece: the paper trains the
data-owner head segments and the data-scientist trunk segment with
*different* optimizers/learning rates (Appendix B: owners 0.01, scientist
0.1), each party updating its own segment independently after receiving
the cut-layer gradient.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable          # (grads, state, params, step) -> (updates, state)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def _as_sched(lr):
    return lr if callable(lr) else constant(lr)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = _as_sched(lr)

    def init(params):
        if momentum == 0.0:
            return ()
        return _tmap(jnp.zeros_like, params)

    def update(grads, state, params, step):
        lr_t = sched(step)
        if momentum == 0.0:
            return _tmap(lambda g: -lr_t * g, grads), state
        new_m = _tmap(lambda m, g: momentum * m + g, state, grads)
        return _tmap(lambda m: -lr_t * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """``state_dtype=jnp.bfloat16`` halves m/v HBM (the §Perf memory-term
    lever for optimizer state); the update math stays fp32."""
    sched = _as_sched(lr)

    def init(params):
        z = _tmap(lambda p: jnp.zeros_like(p, dtype=state_dtype), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z)}

    def update(grads, state, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step - 1.0)
        m = _tmap(lambda m_, g: b1 * m_.astype(jnp.float32)
                  + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_.astype(jnp.float32)
                  + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        bc1 = 1 - b1 ** step
        bc2 = 1 - b2 ** step

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        new_state = {"m": _tmap(lambda a: a.astype(state_dtype), m),
                     "v": _tmap(lambda a: a.astype(state_dtype), v)}
        return _tmap(upd, m, v, params), new_state

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


# ---------------------------------------------------------------------------
# Transforms / composition
# ---------------------------------------------------------------------------


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
        return _tmap(lambda g: g * scale.astype(g.dtype), grads), state

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    """Compose transforms left-to-right; the last one produces updates."""

    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params, step):
        new_state = []
        for o, s in zip(opts, state):
            grads, s = o.update(grads, s, params, step)
            new_state.append(s)
        return grads, tuple(new_state)

    return Optimizer(init, update)


def multi_segment(segment_opts) -> Optimizer:
    """Per-segment optimizers keyed by the top-level param-tree key.

    PyVertical: ``multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})`` —
    each data owner updates its head with its own optimizer; the data
    scientist updates the trunk with another.  Missing keys raise.
    """

    def init(params):
        return {k: segment_opts[k].init(params[k]) for k in params}

    def update(grads, state, params, step):
        updates, new_state = {}, {}
        for k in grads:
            u, s = segment_opts[k].update(grads[k], state[k], params[k], step)
            updates[k], new_state[k] = u, s
        return updates, new_state

    return Optimizer(init, update)


def apply_updates(params, updates):
    return _tmap(lambda p, u: p + u.astype(p.dtype), params, updates)
