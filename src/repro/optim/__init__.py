from repro.optim.optimizers import (adam, adamw, sgd, chain,  # noqa: F401
                                    clip_by_global_norm, apply_updates,
                                    multi_segment, warmup_cosine, constant)
