"""Per-row symmetric int8 quantization as a Pallas TPU kernel.

The cut-layer payload is the only tensor that crosses the party boundary,
so quantizing it on-device before the send is the protocol's bandwidth
lever (transport codec ``int8``).  One grid step handles a (block_m, K)
row block: the row absmax, the scale (absmax / 127), and the rounded int8
values are all produced in a single VMEM pass — the f32 activation never
returns to HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import compiler_params


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (bm, K)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (bm, 1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _quantize_pack_kernel(x_ref, out_ref):
    """Quantize a (bm, K) row block AND lay it out wire-ready in the same
    VMEM pass: ``out[:, :K]`` are the int8 values bitcast to uint8,
    ``out[:, K:K+4]`` are the per-row f32 scales bitcast to their four
    (little-endian) bytes.  The float activation never returns to HBM and
    no second packing pass touches the quantized values."""
    x = x_ref[...].astype(jnp.float32)                    # (bm, K)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (bm, 1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    k = q.shape[-1]
    out_ref[:, :k] = jax.lax.bitcast_convert_type(q, jnp.uint8)
    sbytes = jax.lax.bitcast_convert_type(
        scale.astype(jnp.float32), jnp.uint8)              # (bm, 1, 4)
    out_ref[:, k:] = sbytes.reshape(sbytes.shape[0], 4)


def quantize_int8_raw(x, *, block_m: int = 256, interpret: bool = False):
    """x: (T, K) float.  Returns (values int8 (T, K), scales f32 (T, 1))
    with per-row symmetric scaling: ``x ~= values * scales``."""
    T, K = x.shape
    bm = min(block_m, T)
    nm = -(-T // bm)
    if nm * bm - T:
        x = jnp.pad(x, ((0, nm * bm - T), (0, 0)))
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nm * bm, K), jnp.int8),
                   jax.ShapeDtypeStruct((nm * bm, 1), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return q[:T], s[:T]


def quantize_pack_int8_raw(x, *, block_m: int = 256,
                           interpret: bool = False):
    """x: (T, K) float.  Returns the wire frame: a uint8 (T, K+4) array
    whose first K columns are the per-row symmetric int8 values and whose
    trailing 4 columns are the little-endian bytes of the f32 row scale —
    quantization and wire packing fused into one pass (the transport's
    ``int8`` codec ships this buffer as-is)."""
    T, K = x.shape
    bm = min(block_m, T)
    nm = -(-T // bm)
    if nm * bm - T:
        x = jnp.pad(x, ((0, nm * bm - T), (0, 0)))
    out = pl.pallas_call(
        _quantize_pack_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, K + 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, K + 4), jnp.uint8),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return out[:T]
