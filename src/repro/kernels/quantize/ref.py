"""Pure-jnp oracle for per-row symmetric int8 quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_int8_ref(x):
    """x: (T, K).  Returns (values int8 (T, K), scales f32 (T, 1))."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q, scale):
    return q.astype(jnp.float32) * scale
