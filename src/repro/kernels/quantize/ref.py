"""Pure-jnp oracle for per-row symmetric int8 quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_int8_ref(x):
    """x: (T, K).  Returns (values int8 (T, K), scales f32 (T, 1))."""
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_pack_int8_ref(x):
    """Oracle for the fused quantize+pack kernel: uint8 (T, K+4) wire
    frame — int8 values bitcast to uint8 plus the 4 little-endian bytes
    of the f32 row scale."""
    import jax
    q, scale = quantize_int8_ref(x)
    qb = jax.lax.bitcast_convert_type(q, jnp.uint8)
    sb = jax.lax.bitcast_convert_type(
        scale.astype(jnp.float32), jnp.uint8).reshape(q.shape[0], 4)
    return jnp.concatenate([qb, sb], axis=-1)


def unpack_int8_ref(packed):
    """Inverse of the wire frame: (values int8 (T, K), scales f32 (T, 1))."""
    import numpy as np
    packed = np.asarray(packed)
    k = packed.shape[-1] - 4
    q = packed[:, :k].view(np.int8)
    scale = np.ascontiguousarray(packed[:, k:]).view("<f4")
    return q, scale
