"""jit'd wrapper for the cut-payload int8 quantizer.

``interpret=None`` (the default) resolves to interpreter mode off-TPU so
the transport codec works identically on CPU CI and real hardware.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.quantize.kernel import (quantize_int8_raw,
                                           quantize_pack_int8_raw)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _quantize_jit(x, *, block_m: int, interpret: bool):
    return quantize_int8_raw(x, block_m=block_m, interpret=interpret)


def quantize_int8(x, *, block_m: int = 256, interpret=None):
    """x: (T, K) float.  Returns (values int8 (T, K), scales f32 (T, 1))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _quantize_jit(x, block_m=block_m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _quantize_pack_jit(x, *, block_m: int, interpret: bool):
    return quantize_pack_int8_raw(x, block_m=block_m, interpret=interpret)


def quantize_pack_int8(x, *, block_m: int = 256, interpret=None):
    """x: (T, K) float.  Returns the uint8 (T, K+4) wire frame: int8
    values + bitcast little-endian f32 row scale, fused in one kernel
    pass (no separate pack step touches the quantized buffer)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _quantize_pack_jit(x, block_m=block_m, interpret=interpret)
