from repro.kernels.quantize.ops import (quantize_int8,  # noqa: F401
                                        quantize_pack_int8)
from repro.kernels.quantize.ref import (dequantize_int8_ref,  # noqa: F401
                                        quantize_int8_ref,
                                        quantize_pack_int8_ref,
                                        unpack_int8_ref)
