"""Flash-style attention Pallas kernel (TPU target).

Grid: (batch * n_q_heads, n_q_blocks, n_kv_blocks); the kv dim is the
innermost, sequential axis — the online-softmax state (m, l, acc) lives in
VMEM scratch and persists across kv iterations, the standard TPU flash
pattern.  GQA is resolved by the ops wrapper (kv heads broadcast to q
heads via the BlockSpec index_map, no materialized repeat).

VMEM working set per grid step:
    q (1, Bq, hd) + k,v (1, Bk, hd) + acc (Bq, hd) f32 + s (Bq, Bk) f32
with Bq = Bk = 128, hd <= 256 -> ~0.6 MB: comfortably inside the ~16 MB
VMEM budget; all matmul dims are 128-multiples (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 kind: str, window: int, softcap: float, scale: float,
                 block_q: int, block_k: int, seq_q: int, seq_kv: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # structural skip of fully-masked kv blocks (the sparsity that makes
    # owner-local/sliding-window heads sub-quadratic)
    first_q = qb * block_q
    last_q = first_q + block_q - 1
    first_k = kb * block_k
    if kind == "causal":
        live = first_k <= last_q
    elif kind == "local":
        live = (first_k <= last_q) & (first_k + block_k > first_q - window)
    else:
        live = first_k >= 0  # always true, but keeps a traced bool

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (Bq, hd)
        k = k_ref[0].astype(jnp.float32)                   # (Bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = first_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = first_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = (q_pos < seq_q) & (k_pos < seq_kv)
        if kind == "causal":
            mask &= k_pos <= q_pos
        elif kind == "local":
            mask &= (k_pos <= q_pos) & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev, acc = m_ref[...], l_ref[...], acc_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kb == n_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_flat(q, k, v, *, kind: str = "causal", window: int = 0,
                         softcap: float = 0.0, scale=None, group: int = 1,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (B*nh, Sq, hd); k, v: (B*nkv, Skv, hd) with nh = group * nkv.

    The kv index_map folds GQA: q row ``b`` reads kv row ``b // group``.
    """
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    nq = -(-Sq // bq)
    nk = -(-Skv // bk)
    if nq * bq - Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0)))
    if nk * bk - Skv:
        k = jnp.pad(k, ((0, 0), (0, nk * bk - Skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * bk - Skv), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, kind=kind, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_k=bk, seq_q=Sq, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, hd),
                         lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
