from repro.kernels.block_attention.ops import block_attention  # noqa: F401
