"""Pure-jnp oracle for the block_attention kernel: the model's own
chunked-softmax attention (repro.models.attention.attention), which every
architecture's forward pass uses on CPU and which the Pallas kernel must
match to float tolerance."""
from __future__ import annotations

from repro.models.attention import attention


def attention_ref(q, k, v, *, kind: str = "causal", window: int = 0,
                  softcap: float = 0.0):
    """q: (B, Sq, nh, hd); k, v: (B, Skv, nkv, hd)."""
    return attention(q, k, v, kind=kind, window=window, softcap=softcap)
