"""jit'd wrapper: model-layout (B, S, H, hd) GQA attention on the Pallas
flash kernel.  ``interpret=True`` executes the kernel body on CPU (how the
tests validate it); on TPU the same call lowers to Mosaic."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_attention.kernel import flash_attention_flat


@functools.partial(jax.jit, static_argnames=(
    "kind", "window", "softcap", "block_q", "block_k", "interpret"))
def block_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, nh, hd); k, v: (B, Skv, nkv, hd) -> (B, Sq, nh, hd)."""
    B, Sq, nh, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * nh, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * nkv, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * nkv, Skv, hd)
    out = flash_attention_flat(qf, kf, vf, kind=kind, window=window,
                               softcap=softcap, group=group,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out.reshape(B, nh, Sq, hd).transpose(0, 2, 1, 3)
