"""Version-compat shims for Pallas TPU API drift.

jax has renamed the TPU compiler-params dataclass across releases
(``pltpu.CompilerParams`` <-> ``pltpu.TPUCompilerParams``).  All kernels
construct it through :func:`compiler_params`, which resolves whichever
name the installed jax ships.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def compiler_params(**kwargs):
    """Build the TPU compiler-params object for ``pl.pallas_call``."""
    return CompilerParams(**kwargs)
