"""Chunked SSD (Mamba2) scan as a Pallas kernel.

Grid: (batch, ssd_heads, n_chunks); the chunk axis is the innermost,
sequential axis and the inter-chunk SSM state (N, P) f32 lives in VMEM
scratch, carried across chunk iterations — the same sequential-grid +
VMEM-carry structure the flash kernel uses, which is how the recurrence
maps onto the TPU (no HBM round-trip for the state between chunks).

Per-step VMEM: x (L, P) + B,C (L, N) + decay matrix (L, L) f32 + state
(N, P) f32; with L = 128, N = 64, P = 64: ~0.2 MB.  The (L, L) intra-chunk
quadratic term and the (N, P) state updates are MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _ssd_kernel(x_ref, a_ref, dt_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, L: int):
    c = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    a = a_ref[0, 0, :, 0]                        # (L,)  = dt * A  (<= 0)
    dt = dt_ref[0, 0, :, 0]                      # (L,)
    Bv = b_ref[0, 0].astype(jnp.float32)         # (L, N)
    Cv = c_ref[0, 0].astype(jnp.float32)         # (L, N)

    cum = jnp.cumsum(a)                          # (L,)
    total = cum[L - 1]
    # intra-chunk: M_ij = (C_i . B_j) exp(cum_i - cum_j) dt_j,  j <= i
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # mask before exp (j > i diffs are positive and would overflow)
    ldec = jnp.exp(jnp.where(jj <= ii, diff, -jnp.inf))
    scores = jax.lax.dot_general(Cv, Bv, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * ldec * dt[None, :]
    y = jax.lax.dot(M, x, preferred_element_type=jnp.float32)
    # inter-chunk: y += (C_i exp(cum_i)) @ state
    y += jax.lax.dot(Cv * jnp.exp(cum)[:, None], state_ref[...],
                     preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: S <- exp(total) S + sum_j exp(total - cum_j) dt_j B_j x_j
    w = jnp.exp(total - cum) * dt                # (L,)
    upd = jax.lax.dot_general(Bv * w[:, None], x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(total) * state_ref[...] + upd

    @pl.when(c == n_c - 1)
    def _fin():
        state_out_ref[0, 0] = state_ref[...]


def ssd_scan_raw(x, a, dt, B_in, C_in, *, chunk: int = 128,
                 interpret: bool = False):
    """x: (B, H, S, P); a = dt*A: (B, H, S, 1); dt: (B, H, S, 1);
    B_in, C_in: (B, G, S, N) — G groups, head h reads group h // (H//G).

    Returns (y (B, H, S, P), final_state (B, H, N, P) f32)."""
    Bb, H, S, P = x.shape
    G, N = B_in.shape[1], B_in.shape[3]
    rep = H // G
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        zp = ((0, 0), (0, 0), (0, pad), (0, 0))
        x, a, dt = jnp.pad(x, zp), jnp.pad(a, zp), jnp.pad(dt, zp)
        B_in, C_in = jnp.pad(B_in, zp), jnp.pad(C_in, zp)

    kernel = functools.partial(_ssd_kernel, L=L)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0)),
            pl.BlockSpec((1, 1, L, N),
                         lambda b, h, c, r=rep: (b, h // r, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc * L, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, dt, B_in, C_in)
    return y[:, :, :S], state
