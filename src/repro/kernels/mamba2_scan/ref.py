"""Pure-jnp oracle: the model's own chunked SSD implementation."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked


def ssd_ref(x, dt, A, B_in, C_in, chunk: int = 128):
    """Model layout: x (B, S, H, P), dt (B, S, H), A (H,),
    B_in/C_in (B, S, G, N).  Returns (y, final_state (B, H, N, P))."""
    return ssd_chunked(x, dt, A, B_in, C_in, chunk)
