"""jit'd wrapper: model-layout SSD scan on the Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan.kernel import ssd_scan_raw


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan(x, dt, A, B_in, C_in, *, chunk: int = 128,
                interpret: bool = False):
    """Model layout: x (B, S, H, P), dt (B, S, H) (positive), A (H,)
    (negative rates), B_in/C_in (B, S, G, N).

    Returns (y (B, S, H, P), final_state (B, H, N, P))."""
    xk = x.transpose(0, 2, 1, 3)                         # (B, H, S, P)
    dtk = dt.transpose(0, 2, 1)[..., None].astype(jnp.float32)
    ak = dtk * A.astype(jnp.float32)[None, :, None, None]
    Bk = B_in.transpose(0, 2, 1, 3)                      # (B, G, S, N)
    Ck = C_in.transpose(0, 2, 1, 3)
    y, state = ssd_scan_raw(xk, ak, dtk, Bk, Ck, chunk=chunk,
                            interpret=interpret)
    return y.transpose(0, 2, 1, 3), state
