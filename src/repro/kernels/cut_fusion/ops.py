"""jit'd wrapper for the fused cut-layer combine+projection."""
from __future__ import annotations

import functools

import jax

from repro.kernels.cut_fusion.kernel import cut_fusion_raw


@functools.partial(jax.jit, static_argnames=(
    "combine", "block_m", "block_n", "block_k", "interpret"))
def cut_fusion(z, w, *, combine: str = "concat", block_m: int = 128,
               block_n: int = 128, block_k: int = 128,
               interpret: bool = False):
    """z: (P, T, k) owner cut activations; w: (P, k, d) trunk projection
    block-rows.  Returns combine(z) @ W: (T, d)."""
    return cut_fusion_raw(z, w, combine=combine, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          interpret=interpret)
