"""Pure-jnp oracle: materialize the combine, then matmul."""
from __future__ import annotations

import jax.numpy as jnp


def cut_fusion_ref(z, w, *, combine: str = "concat"):
    """z: (P, T, k); w: (P, k, d).  Returns (T, d)."""
    P = z.shape[0]
    zf = z.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if combine == "concat":
        # concat over features == sum of per-owner block-row matmuls
        out = sum(zf[p] @ wf[p] for p in range(P))
    elif combine == "sum":
        out = zf.sum(0) @ wf[0]
    elif combine == "mean":
        out = zf.mean(0) @ wf[0]
    else:
        raise ValueError(combine)
    return out.astype(z.dtype)
