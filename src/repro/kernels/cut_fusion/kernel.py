"""The PyVertical cut layer as one fused Pallas kernel.

The data scientist combines the owners' cut activations and feeds them to
the trunk's input projection:

    concat:   out = concat_feat(z_0 .. z_{P-1}) @ W,  W: (P*k, d)
              = sum_p  z_p @ W_p                      (block-row matmul)
    sum/mean: out = (sum_p z_p) @ W_0  [/ P]

Fusing the combine into the matmul means the (T, P*k) concatenated
representation is never materialized in HBM — on TPU the owner dim folds
into the contraction loop.

Grid: (M_tiles, N_tiles, P * K_tiles); the last axis is sequential and
accumulates into a VMEM f32 scratch tile; owner index p = c // K_tiles
selects both the z block row and the W block row via the index_maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _cut_kernel(z_ref, w_ref, o_ref, acc_ref, *, combine: str, n_owners: int,
                inv_p: float):
    c = pl.program_id(2)
    n_c = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = z_ref[0]                                  # (Bm, Bk)
    if combine == "mean":
        z = z * inv_p
    acc_ref[...] += jax.lax.dot(z.astype(jnp.float32),
                                w_ref[0].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(c == n_c - 1)
    def _fin():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def cut_fusion_raw(z, w, *, combine: str = "concat",
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = False):
    """z: (P, T, k) stacked owner cut activations; w: (P, k, d) block rows
    of the trunk input projection (all owners share W_0 for sum/mean).

    Returns (T, d) = combine(z) @ W without materializing the combine.
    """
    P, T, K = z.shape
    D = w.shape[-1]
    bm, bn, bk = min(block_m, T), min(block_n, D), min(block_k, K)
    nm, nn, nk = -(-T // bm), -(-D // bn), -(-K // bk)
    if nm * bm - T or nk * bk - K:
        z = jnp.pad(z, ((0, 0), (0, nm * bm - T), (0, nk * bk - K)))
    if nk * bk - K or nn * bn - D:
        w = jnp.pad(w, ((0, 0), (0, nk * bk - K), (0, nn * bn - D)))

    kernel = functools.partial(_cut_kernel, combine=combine, n_owners=P,
                               inv_p=1.0 / P)
    out = pl.pallas_call(
        kernel,
        grid=(nm, nn, P * nk),
        in_specs=[
            # z block: owner p = c // nk, k block = c % nk
            pl.BlockSpec((1, bm, bk),
                         lambda i, j, c, nk=nk: (c // nk, i, c % nk)),
            # W block row for that owner (sum/mean read row 0)
            pl.BlockSpec((1, bk, bn),
                         (lambda i, j, c, nk=nk: (0, c % nk, j))
                         if combine in ("sum", "mean") else
                         (lambda i, j, c, nk=nk: (c // nk, c % nk, j))),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), z.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(z, w[:1] if combine in ("sum", "mean") else w)
    return out[:T, :D]
