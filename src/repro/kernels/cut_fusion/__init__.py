from repro.kernels.cut_fusion.ops import cut_fusion  # noqa: F401
