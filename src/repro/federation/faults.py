"""Programmable, deterministic fault injection for the federation runtime.

PR 6 introduced a single-shot chaos hook — ``REPRO_CHAOS_PARTY=
"<party>:<action>"`` crashed or wedged one party's actor on its first
forward/PSI message.  This module generalizes it into a *plan*: a
picklable list of :class:`Fault` s, each targeting a party × message
kind × occurrence index (or an exact training step), with five actions:

  ``crash``           the actor raises before handling the message
  ``wedge``           the actor sleeps for an hour (liveness test)
  ``drop_frame``      the frame is silently lost on the wire
  ``corrupt_frame``   a blob byte is flipped after the CRC is computed
                      (the receiver raises ``transport.FrameCorrupt``)
  ``delay``           the frame's delivery deadline is pushed back

Plans serialize through the *same* env channel (``REPRO_CHAOS_PARTY``,
inherited by spawned workers): legacy single tokens and comma-separated
multi-party tokens round-trip losslessly (``owner0:crash_fwd,
owner1:wedge_psi``); anything richer rides a ``json:`` prefix.  The
legacy parser in ``runtime._chaos_action`` now delegates here, so a
one-fault plan *is* the old hook.

Determinism: every fault carries an occurrence index counted per
matching event and a worker ``gen``eration — a respawned worker is
armed with ``generation=1+``, so a fault bound to generation 0 (the
default, matching the legacy hook) fires once and never again, which is
what lets the recovery property tests crash a worker deterministically
and then prove the rerun is fault-free.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass
from typing import Iterable, List, Optional, Tuple

__all__ = ["Fault", "FaultPlan", "FaultInjector", "arm_actor",
           "arm_endpoint", "plan_from_env", "CHAOS_ENV", "ACTIONS"]

#: the env channel chaos plans ride into spawned workers (PR 6's name)
CHAOS_ENV = "REPRO_CHAOS_PARTY"

ACTIONS = ("crash", "wedge", "drop_frame", "corrupt_frame", "delay")
_ACTOR_ACTIONS = ("crash", "wedge")
_WIRE_ACTIONS = ("drop_frame", "corrupt_frame", "delay")

#: legacy single-token spellings (PR 6) -> (action, message kind)
_LEGACY = {
    "crash_fwd": ("crash", "head_fwd"),
    "wedge_fwd": ("wedge", "head_fwd"),
    "crash_psi": ("crash", "psi_blind_chunk"),
    "wedge_psi": ("wedge", "psi_blind_chunk"),
}
_LEGACY_INV = {v: k for k, v in _LEGACY.items()}


@dataclass(frozen=True)
class Fault:
    """One scheduled fault.  ``occurrence`` indexes the matching events
    (0 = first message of ``kind`` seen by this party, ``None`` = every
    one); ``step`` additionally pins the message's ``seq``; ``gen``
    restricts the fault to one worker generation (``None`` = all —
    respawned workers are armed with generation 1+)."""

    party: str
    action: str
    kind: str = "head_fwd"
    occurrence: Optional[int] = 0
    step: Optional[int] = None
    gen: Optional[int] = 0
    delay_s: float = 0.0

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"known: {ACTIONS}")


class FaultPlan:
    """An ordered, picklable collection of :class:`Fault` s with a
    lossless round-trip through the ``REPRO_CHAOS_PARTY`` env string."""

    def __init__(self, faults: Iterable[Fault] = ()):
        self.faults: Tuple[Fault, ...] = tuple(faults)

    def __len__(self) -> int:
        return len(self.faults)

    def __iter__(self):
        return iter(self.faults)

    def __bool__(self) -> bool:
        return bool(self.faults)

    def __eq__(self, other) -> bool:
        return (isinstance(other, FaultPlan)
                and self.faults == other.faults)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.faults)!r})"

    def for_party(self, party: str) -> List[Fault]:
        return [f for f in self.faults if f.party == party]

    def to_env(self) -> str:
        """Serialize for the env channel.  Plans expressible in the
        legacy grammar emit comma-separated ``<party>:<action>`` tokens
        (back-compat: a one-fault plan is byte-identical to the PR 6
        hook); anything richer emits ``json:[...]``."""
        toks = []
        for f in self.faults:
            key = (f.action, f.kind)
            legacy = (key in _LEGACY_INV and f.occurrence == 0
                      and f.step is None and f.gen == 0
                      and f.delay_s == 0.0)
            if not legacy:
                return "json:" + json.dumps(
                    [dataclasses.asdict(x) for x in self.faults])
            toks.append(f"{f.party}:{_LEGACY_INV[key]}")
        return ",".join(toks)

    @classmethod
    def from_env(cls, spec: str) -> "FaultPlan":
        spec = (spec or "").strip()
        if not spec:
            return cls()
        if spec.startswith("json:"):
            return cls(Fault(**d) for d in json.loads(spec[5:]))
        faults = []
        for tok in spec.split(","):
            tok = tok.strip()
            if not tok:
                continue
            who, _, action = tok.partition(":")
            if action in _LEGACY:           # unknown tokens are inert,
                act, kind = _LEGACY[action]  # matching the old hook
                faults.append(Fault(who, act, kind))
        return cls(faults)


def plan_from_env() -> FaultPlan:
    """The plan currently riding the env channel (empty when unset)."""
    return FaultPlan.from_env(os.environ.get(CHAOS_ENV, ""))


class FaultInjector:
    """A party's armed view of a plan: per-fault occurrence counters,
    filtered to one worker generation.  ``actor_fault`` drives the
    crash/wedge wrap; ``wire_fault`` drives the transport send hook."""

    def __init__(self, plan: FaultPlan, party: str, generation: int = 0):
        mine = [f for f in plan.for_party(party)
                if f.gen is None or f.gen == generation]
        self.party, self.generation = party, generation
        self._actor = [f for f in mine if f.action in _ACTOR_ACTIONS]
        self._wire = [f for f in mine if f.action in _WIRE_ACTIONS]
        self._hits = {id(f): 0 for f in mine}

    @property
    def has_actor_faults(self) -> bool:
        return bool(self._actor)

    @property
    def has_wire_faults(self) -> bool:
        return bool(self._wire)

    def _fire(self, fault: Fault, kind: str, seq: int) -> bool:
        if fault.kind != kind:
            return False
        if fault.step is not None and seq != fault.step:
            return False
        n = self._hits[id(fault)]
        self._hits[id(fault)] = n + 1
        return fault.occurrence is None or n == fault.occurrence

    def actor_fault(self, kind: str, seq: int = 0) -> Optional[str]:
        """``"crash"`` / ``"wedge"`` when a fault fires on this message,
        else ``None``."""
        for f in self._actor:
            if self._fire(f, kind, seq):
                return f.action
        return None

    def wire_fault(self, kind: str, seq: int = 0
                   ) -> Optional[Tuple[str, float]]:
        """``(action, delay_s)`` when a wire fault fires on this frame,
        else ``None``."""
        for f in self._wire:
            if self._fire(f, kind, seq):
                return (f.action, f.delay_s)
        return None


def arm_actor(actor, party: str, *, generation: int = 0,
              plan: Optional[FaultPlan] = None):
    """Wrap ``actor.handle`` with this party's crash/wedge faults (plan
    defaults to the env channel).  Preserves the legacy failure shape:
    crash raises ``chaos: injected crash in <party> on <kind>`` and
    wedge sleeps an hour mid-protocol."""
    plan = plan_from_env() if plan is None else plan
    inj = FaultInjector(plan, party, generation)
    if not inj.has_actor_faults:
        return actor
    orig = actor.handle

    def handle(msg):
        action = inj.actor_fault(msg.kind, msg.seq)
        if action == "crash":
            raise RuntimeError(
                f"chaos: injected crash in {party} on {msg.kind}")
        if action == "wedge":
            time.sleep(3600.0)
        return orig(msg)

    actor.handle = handle
    return actor


def arm_endpoint(ep, party: str, *, generation: int = 0,
                 plan: Optional[FaultPlan] = None):
    """Install this party's wire faults (drop/corrupt/delay) as the
    transport-layer send hook.  On a queue :class:`transport.Endpoint`
    the hook lands on both underlying channels (each protocol kind is
    sent by exactly one side, so occurrence counters never double-fire);
    on a :class:`process_transport.ProcessEndpoint` it lands on the
    endpoint itself — arm the end that *sends* the targeted kind."""
    plan = plan_from_env() if plan is None else plan
    inj = FaultInjector(plan, party, generation)
    if not inj.has_wire_faults:
        return ep
    if hasattr(ep, "outbox"):
        ep.outbox.fault_hook = inj.wire_fault
        ep.inbox.fault_hook = inj.wire_fault
    else:
        ep.fault_hook = inj.wire_fault
    return ep
