"""Process-boundary transport: the queue backend's semantics over a real
OS pipe between party *processes*.

The thread-backed ``queue`` backend made the party boundary real at the
message level — serialized frames, measured bytes, injected transit time
— but every party still shared one interpreter, so the GIL serialized
owner compute against the scientist and "multi-headed" meant threads.
:class:`ProcessEndpoint` is the same duplex endpoint surface
(``send`` / ``recv`` / ``recv_kind`` / ``sent_stats`` / ``recv_stats`` /
``tap``) over a ``multiprocessing.connection.Connection``, so
``OwnerComputeEndpoint`` and ``PSIServerEndpoint`` run unchanged inside
spawned worker processes (``federation/runtime.py``) and owner head
compute genuinely overlaps the scientist on multi-core hosts.

Design notes:

  * **One socket per party, multiplexed.**  All protocol kinds for a
    party share one duplex ``Pipe`` (a Unix socketpair on Linux); the
    per-message kind rides a small transport header in front of the
    payload frame.  ``recv_kind``'s stash provides the same any-kind
    interleaving tolerance the queue backend's Endpoint has.
  * **Identical wire accounting.**  The payload frame is the *exact*
    ``transport._pack`` blob the queue backend serializes, and
    ``wire_bytes`` counts that blob alone (the transport header plays
    the role of the in-process ``Message`` envelope, which the queue
    backend doesn't count either) — so per-kind byte stats are
    bit-identical across backends (gated in ``BENCH_parties.json``).
  * **Latency across the boundary.**  The sender stamps a delivery
    deadline (``latency_s + wire_bytes / bandwidth_bps`` past send time)
    into the header; the receiver honors it with the same hybrid
    sleep+spin wait.  ``time.monotonic`` is CLOCK_MONOTONIC, which is
    system-wide on Linux, so the deadline is meaningful cross-process.
  * **Non-blocking sends.**  A per-endpoint writer thread drains an
    unbounded outbox into the pipe, so a full OS socket buffer (both
    parties mid-burst) can never deadlock the protocol — the pipe
    applies backpressure to the writer thread, not to the party.
  * **Crash surfacing.**  A dying worker emits a final
    ``__worker_error__`` frame carrying its traceback (the poison pill);
    the peer's next ``recv`` raises it as a ``RuntimeError``, and an
    unclean death without the pill surfaces as EOF on the pipe.
"""
from __future__ import annotations

import queue as _queue
import struct
import threading
import time
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.federation.transport import (FrameCorrupt, Message, _pack,
                                        _payload_nbytes, _unpack,
                                        _wait_until, spin_wait_s)

__all__ = ["ProcessEndpoint", "process_endpoint_pair", "POISON_KIND",
           "HEADER_FMT", "FrameCorrupt"]

#: the worker-lifecycle poison-pill frame (docs/WIRE_PROTOCOL.md §5)
POISON_KIND = "__worker_error__"

#: transport header preceding every payload frame on the pipe:
#: [u16 kind_len][kind utf-8][i64 seq][f64 not_before][i64 payload_bytes]
#: [u32 crc32-of-blob] — the CRC makes corruption on the real OS
#: boundary (or injected via faults.arm_endpoint) a loud FrameCorrupt
#: instead of a silent bad gradient.  Header bytes stay uncounted, so
#: wire accounting is still bit-identical to the queue backend.
HEADER_FMT = "<qdqI"
_HEADER_LEN = struct.calcsize(HEADER_FMT)

_CLOSE = object()          # writer-thread shutdown sentinel


def _new_stats() -> Dict[str, object]:
    return {"messages": 0, "payload_bytes": 0, "wire_bytes": 0,
            "by_kind": {}}


def _account(stats: Dict[str, object], kind: str, payload_bytes: int,
             wire_bytes: int) -> None:
    stats["messages"] += 1
    stats["payload_bytes"] += payload_bytes
    stats["wire_bytes"] += wire_bytes
    k = stats["by_kind"].setdefault(
        kind, {"count": 0, "payload_bytes": 0, "wire_bytes": 0})
    k["count"] += 1
    k["payload_bytes"] += payload_bytes
    k["wire_bytes"] += wire_bytes


class ProcessEndpoint:
    """One party's end of a duplex process boundary.

    Same protocol surface as :class:`transport.Endpoint`; ``recv`` raises
    ``queue.Empty`` on timeout (the poll contract the session's
    owner-crash surfacing loops rely on) and ``RuntimeError`` when the
    peer died (poison pill or EOF)."""

    def __init__(self, name: str, peer: str, conn, *,
                 latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 spin_s: Optional[float] = None, tap=None,
                 dedup: bool = False):
        self.name, self.peer = name, peer
        self.conn = conn
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        self.spin_s = spin_wait_s() if spin_s is None else spin_s
        self.tap = tap
        # fault hook: fault_hook(kind, seq) -> (action, delay_s) | None,
        # installed by faults.arm_endpoint (drop/corrupt/delay)
        self.fault_hook = None
        # opt-in seq-based duplicate drop: a reconnecting peer may
        # replay its last frame per kind; with dedup on, a frame whose
        # seq equals the last delivered seq for its kind is dropped
        # (protocol seqs only — negative control seqs are exempt).  Off
        # by default: serving reuses per-tick seqs legitimately.
        self._dedup = dedup
        self._last_seq: Dict[str, int] = {}
        self.sent_stats = _new_stats()
        self.recv_stats = _new_stats()
        #: the peer's poison pill, once seen (checked by WorkerHandle)
        self.peer_error: Optional[BaseException] = None
        self._stash: list = []
        # corrupt frames routed to the kind that owns them (recv_kind)
        self._corrupt: Dict[str, FrameCorrupt] = {}
        self._lock = threading.Lock()
        # stash + pipe-read serialization: multiplexed serving sessions
        # may block in recv_kind on one shared endpoint concurrently
        # (same discipline as transport.Endpoint)
        self._rlock = threading.RLock()
        self._outq: "_queue.SimpleQueue" = _queue.SimpleQueue()
        self._send_error: Optional[BaseException] = None
        self._writer = threading.Thread(
            target=self._write_loop, daemon=True,
            name=f"pt-writer-{name}->{peer}")
        self._writer.start()
        self._closed = False

    # -- sending -----------------------------------------------------------
    def _write_loop(self) -> None:
        while True:
            frame = self._outq.get()
            if frame is _CLOSE:
                return
            try:
                self.conn.send_bytes(frame)
            except (OSError, ValueError) as e:
                # peer gone; remember why and drain silently so the
                # party's send path never blocks on a dead pipe
                if self._send_error is None:
                    self._send_error = e

    def send(self, kind: str, payload: Dict[str, np.ndarray], *,
             seq: int = 0) -> Message:
        if self._closed:
            raise RuntimeError(
                f"{self.name}: endpoint to {self.peer} is closed")
        pb = _payload_nbytes(payload)
        blob = _pack(payload)
        wb = len(blob)
        crc = zlib.crc32(blob) & 0xFFFFFFFF
        msg = Message(self.name, self.peer, kind, {"__blob__": blob},
                      seq=seq, payload_bytes=pb, wire_bytes=wb, crc=crc)
        if self.tap is not None:
            self.tap(msg, blob)
        fault = (self.fault_hook(kind, seq)
                 if self.fault_hook is not None else None)
        transit = self.latency_s + (
            wb / self.bandwidth_bps if self.bandwidth_bps else 0.0)
        if fault is not None and fault[0] == "delay":
            transit += fault[1]
        not_before = 0.0
        if transit:
            not_before = time.monotonic() + transit
            msg.not_before = not_before
        with self._lock:
            _account(self.sent_stats, kind, pb, wb)
        if fault is not None:
            action = fault[0]
            if action == "drop_frame":
                with self._lock:
                    self.sent_stats["dropped_frames"] = \
                        self.sent_stats.get("dropped_frames", 0) + 1
                return msg                     # lost on the wire
            if action == "corrupt_frame":
                # flip one blob byte AFTER the crc was taken: the far
                # side's integrity check raises FrameCorrupt
                bad = bytearray(blob)
                bad[len(bad) // 2] ^= 0xFF
                blob = bytes(bad)
        kb = kind.encode()
        frame = (struct.pack("<H", len(kb)) + kb
                 + struct.pack(HEADER_FMT, seq, not_before, pb, crc)
                 + blob)
        self._outq.put(frame)
        return msg

    def send_error(self, exc: BaseException, tb: str = "") -> None:
        """Ship the poison pill: the worker's terminal exception +
        traceback, as the last frame before the pipe closes."""
        try:
            self.send(POISON_KIND, {
                "error": np.frombuffer(
                    f"{type(exc).__name__}: {exc}".encode(), np.uint8),
                "traceback": np.frombuffer(tb.encode(), np.uint8)})
        except RuntimeError:
            pass

    # -- receiving ---------------------------------------------------------
    def _recv_frame(self, timeout: Optional[float]) -> Message:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            try:
                if not self.conn.poll(timeout):
                    raise _queue.Empty
                frame = self.conn.recv_bytes()
            except (EOFError, ConnectionResetError, BrokenPipeError,
                    OSError) as e:
                raise RuntimeError(
                    f"{self.name}: connection to {self.peer!r} closed "
                    f"({type(e).__name__})") from (
                        self.peer_error if self.peer_error is not None
                        else e)
            (klen,) = struct.unpack_from("<H", frame, 0)
            kind = frame[2:2 + klen].decode()
            seq, not_before, pb, crc = struct.unpack_from(
                HEADER_FMT, frame, 2 + klen)
            blob = frame[2 + klen + _HEADER_LEN:]
            if kind == POISON_KIND:
                pl = _unpack(blob)
                err = bytes(pl["error"].tobytes()).decode()
                tb = bytes(pl["traceback"].tobytes()).decode()
                self.peer_error = RuntimeError(
                    f"party {self.peer!r} died: {err}"
                    + (f"\n--- remote traceback ---\n{tb}" if tb else ""))
                raise self.peer_error
            if (zlib.crc32(blob) & 0xFFFFFFFF) != crc:
                raise FrameCorrupt(kind, int(seq), self.peer, self.name)
            if self._dedup and seq >= 0:
                if self._last_seq.get(kind) == int(seq):
                    with self._lock:
                        self.recv_stats["dup_dropped"] = \
                            self.recv_stats.get("dup_dropped", 0) + 1
                    if deadline is not None:
                        timeout = max(0.0, deadline - time.monotonic())
                    continue                   # replayed frame: drop
                self._last_seq[kind] = int(seq)
            with self._lock:
                _account(self.recv_stats, kind, int(pb), len(blob))
            if not_before:
                _wait_until(not_before, self.spin_s)
            msg = Message(self.peer, self.name, kind, _unpack(blob),
                          seq=int(seq), payload_bytes=int(pb),
                          wire_bytes=len(blob), not_before=not_before,
                          crc=int(crc))
            if self.tap is not None:
                self.tap(msg, blob)
            return msg

    def reset_dedup(self) -> None:
        """Forget per-kind last-delivered seqs — called after a rollback
        so the replayed step's frames (which legitimately reuse seqs)
        are not mistaken for duplicates."""
        self._last_seq.clear()

    _POLL_S = 0.05

    def recv(self, timeout: Optional[float] = None) -> Message:
        with self._rlock:
            if self._stash:
                return self._stash.pop(0)
            if self.peer_error is not None:
                raise self.peer_error
            return self._recv_frame(timeout)

    def recv_kind(self, kind: str, timeout: Optional[float] = None
                  ) -> Message:
        """Next message of ``kind``; earlier-arriving other kinds are
        stashed, exactly like :class:`transport.Endpoint`.  Short-poll
        under the lock so concurrent sessions sharing this endpoint
        each end up with their own frames."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._rlock:
                if kind in self._corrupt:
                    raise self._corrupt.pop(kind)
                for i, m in enumerate(self._stash):
                    if m.kind == kind:
                        return self._stash.pop(i)
                try:
                    msg = self._recv_frame(self._POLL_S)
                except _queue.Empty:
                    msg = None
                except FrameCorrupt as e:
                    if e.kind == kind:
                        raise
                    self._corrupt[e.kind] = e    # another kind's problem
                    continue
                if msg is not None:
                    if msg.kind == kind:
                        return msg
                    self._stash.append(msg)
                    continue
            if deadline is not None and time.monotonic() >= deadline:
                raise _queue.Empty

    def flush_pending(self) -> None:
        """Discard stashed out-of-kind messages and routed corrupt
        markers (see ``transport.Endpoint.flush_pending``)."""
        with self._rlock:
            self._stash.clear()
            self._corrupt.clear()

    def empty(self) -> bool:
        return not self._stash and not self.conn.poll(0)

    # -- lifecycle ---------------------------------------------------------
    def close(self, drain_s: float = 5.0) -> None:
        """Flush the outbox, stop the writer, close the pipe."""
        if self._closed:
            return
        self._closed = True
        self._outq.put(_CLOSE)
        self._writer.join(timeout=drain_s)
        try:
            self.conn.close()
        except OSError:
            pass


def process_endpoint_pair(a: str, b: str, *, latency_s: float = 0.0,
                          bandwidth_bps: Optional[float] = None,
                          spin_s: Optional[float] = None, tap=None,
                          dedup: bool = False
                          ) -> Tuple[ProcessEndpoint, ProcessEndpoint]:
    """Both ends of a process boundary in the *current* process — the
    unit-test / single-process harness analogue of ``channel_pair``
    (real worker spawning builds the far end inside the child; see
    ``federation/runtime.py``).  ``tap`` observes endpoint ``a``'s
    traffic in both directions; ``dedup`` enables seq-based duplicate
    drop on endpoint ``a``'s receive path."""
    import multiprocessing as mp
    c1, c2 = mp.Pipe(duplex=True)
    ep_a = ProcessEndpoint(a, b, c1, latency_s=latency_s,
                           bandwidth_bps=bandwidth_bps, spin_s=spin_s,
                           tap=tap, dedup=dedup)
    ep_b = ProcessEndpoint(b, a, c2, latency_s=latency_s,
                           bandwidth_bps=bandwidth_bps, spin_s=spin_s)
    return ep_a, ep_b
