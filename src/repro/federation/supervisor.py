"""Liveness supervision for party workers: heartbeats, failure
attribution, and restart budgeting.

Every party is an independent failure domain (the paper's premise —
owner devices are independently operated), so the trusted runtime needs
to *notice* a dead or wedged party without waiting for a protocol
timeout.  :class:`Supervisor` runs a daemon thread that, every
``heartbeat_s`` seconds, ships a tiny ``heartbeat`` frame to each
attached party over its existing transport endpoint and drains
``heartbeat_ack`` replies (actors answer inline between protocol
messages — ``OwnerComputeEndpoint`` and ``PSIServerEndpoint`` both
handle the kind).  A party is marked failed when

  * its worker handle surfaces an error (poison pill / exit code),
  * its endpoint refuses the send (closed pipe), or
  * no ack lands for ``miss_limit`` consecutive periods (a wedged actor
    stops answering long before a protocol receive times out).

Failures land in :attr:`Supervisor.failed` — detection only; *recovery*
(rollback + respawn, ``session.fit(supervise=True)``) is driven by the
session, which consults :meth:`plan_restart` for the bounded-backoff /
max-restart budget.

Heartbeats never touch model state, so a supervised run's training
arithmetic is byte-for-byte the unsupervised run's — the extra frames
only show up in message counts.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

__all__ = ["OwnerFailure", "Supervisor"]


class OwnerFailure(RuntimeError):
    """A protocol failure attributed to one party.  Subclasses
    ``RuntimeError`` with the legacy message strings, so existing
    callers matching on those keep working; ``.party`` names the failure
    domain so the recovery path knows *whom* to restart."""

    def __init__(self, message: str, *, party: str):
        super().__init__(message)
        self.party = party


class Supervisor:
    """Heartbeat monitor + restart budget for a set of party endpoints.

    ``attach(name, ep, worker)`` registers a party (``worker`` optional:
    thread actors have no handle); ``start()``/``stop()`` bound the
    monitor thread's life.  ``failed`` maps party name -> the exception
    that condemned it.  ``plan_restart(name)`` sleeps the bounded
    exponential backoff and raises once the per-party budget is spent.
    """

    def __init__(self, *, heartbeat_s: float = 0.5, miss_limit: int = 8,
                 max_restarts: int = 2, backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0):
        self.heartbeat_s = heartbeat_s
        self.miss_limit = miss_limit
        self.max_restarts = max_restarts
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.failed: Dict[str, BaseException] = {}
        self.stats = {"heartbeats_sent": 0, "heartbeat_acks": 0,
                      "suspected": 0, "respawns": 0}
        self._parties: Dict[str, tuple] = {}
        self._last_ack: Dict[str, float] = {}
        self._restarts: Dict[str, int] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- membership --------------------------------------------------------
    def attach(self, name: str, ep, worker=None) -> None:
        with self._lock:
            self._parties[name] = (ep, worker)
            self._last_ack[name] = time.monotonic()
            self.failed.pop(name, None)

    def detach(self, name: str) -> None:
        with self._lock:
            self._parties.pop(name, None)
            self._last_ack.pop(name, None)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, daemon=True, name="supervisor-heartbeat")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        th, self._thread = self._thread, None
        if th is not None:
            th.join(timeout=5.0)

    # -- the monitor -------------------------------------------------------
    def _condemn(self, name: str, exc: BaseException) -> None:
        if name not in self.failed:
            self.failed[name] = exc
            self.stats["suspected"] += 1

    def _tick(self, n: int) -> None:
        with self._lock:
            parties = list(self._parties.items())
        for name, (ep, worker) in parties:
            if name in self.failed:
                continue
            err = getattr(worker, "error", None) if worker else None
            if err is not None:
                self._condemn(name, err)
                continue
            try:
                ep.send("heartbeat", {}, seq=n)
                self.stats["heartbeats_sent"] += 1
            except RuntimeError as e:
                self._condemn(name, e)
                continue
            try:
                ep.recv_kind("heartbeat_ack", timeout=0.02)
                self._last_ack[name] = time.monotonic()
                self.stats["heartbeat_acks"] += 1
            except Exception:
                # no ack this period (queue.Empty) or the pipe died
                # mid-drain; staleness below decides
                pass
            stale = time.monotonic() - self._last_ack.get(
                name, time.monotonic())
            if stale > self.miss_limit * self.heartbeat_s:
                self._condemn(name, RuntimeError(
                    f"party {name!r} unresponsive: no heartbeat ack for "
                    f"{stale:.1f}s ({self.miss_limit} periods)"))

    def _loop(self) -> None:
        n = 0
        while not self._stop.wait(self.heartbeat_s):
            n += 1
            self._tick(n)

    # -- restart budget ----------------------------------------------------
    def restarts(self, name: str) -> int:
        return self._restarts.get(name, 0)

    def plan_restart(self, name: str) -> float:
        """Charge one restart for ``name``: raises ``RuntimeError`` once
        the per-party budget is spent, else sleeps the bounded
        exponential backoff and returns the delay slept.  Clears the
        party's failed mark so the monitor re-adopts it on re-attach."""
        n = self._restarts.get(name, 0)
        if n >= self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted for party {name!r} "
                f"({self.max_restarts} restarts)") from self.failed.get(name)
        self._restarts[name] = n + 1
        self.stats["respawns"] += 1
        delay = min(self.backoff_base_s * (2 ** n), self.backoff_cap_s)
        time.sleep(delay)
        self.failed.pop(name, None)
        self._last_ack[name] = time.monotonic()
        return delay
