"""The transport layer: what actually crosses the party boundary.

Until this module existed, "communication" in the repro was an analytic
estimate (``core.splitnn.cut_layer_traffic``) layered over one joint
autodiff program.  This module makes the boundary real: parties exchange
:class:`Message` objects over :class:`Channel` s, and everything the
session reports about traffic is *measured* from the wire.

Two backends:

  * ``direct``  — in-process handoff.  Payload pytrees move by reference
    (zero-copy, *zero host sync*: codecs pass device arrays through
    untouched, so nothing forces a device->host round-trip per step).
    This is the fast path for same-process simulation and serving.
  * ``queue``   — a simulated network.  Every payload is serialized to a
    single preallocated wire frame (``_pack``/``_unpack``), byte counts
    are taken from the actual blob, and delivery can be delayed by a
    configurable ``latency_s`` plus ``wire_bytes / bandwidth_bps``.
    Channels are thread-safe: owner compute endpoints run on their own
    threads (``federation/parties.OwnerComputeEndpoint``), so pipelined
    schedules overlap owner and scientist compute in real wall-clock.

The wire frame is one contiguous buffer: a first pass sizes the frame,
the arrays are then copied straight into a per-channel scratch buffer
(reused across sends — no per-array ``tobytes`` allocations), and the
receiver unpacks zero-copy ``np.frombuffer`` views into the immutable
blob.  Delivery deadlines are honored with a hybrid sleep+spin wait
(``SPIN_WAIT_S``): a plain ``time.sleep`` overshoots by 1-3 ms on a
shared box, which is the same order as the per-step budget the pipelined
schedule is trying to protect at LAN latencies.

Cut-payload codecs live here too (``get_codec``): the only bytes that
cross the boundary are cut activations and cut gradients, so shrinking
them is the protocol's one compression lever (Secure Forward Aggregation,
Cai et al. 2022, quantizes the same tensor).  ``fp16`` is a plain
down-cast; ``int8`` is per-row symmetric quantization fused with wire
packing in one Pallas kernel pass (``repro/kernels/quantize``): the wire
payload is a single ``(rows, K+4)`` byte frame, values + bitcast scale.
"""
from __future__ import annotations

import os
import queue
import struct
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["Message", "Channel", "Endpoint", "ScopedEndpoint",
           "channel_pair", "Codec", "get_codec", "CODECS", "SPIN_WAIT_S",
           "spin_wait_s", "FrameCorrupt"]


class FrameCorrupt(RuntimeError):
    """A serialized frame failed its CRC32 integrity check.  Raised by
    the receive path of both the queue and process backends; carries the
    frame's protocol ``kind`` and ``seq`` so multiplexed receivers can
    route the failure to the session that owns the frame."""

    def __init__(self, kind: str, seq: int, sender: str, receiver: str):
        super().__init__(
            f"frame corrupt: {kind!r} seq {seq} from {sender!r} to "
            f"{receiver!r} (crc32 mismatch)")
        self.kind, self.seq = kind, seq
        self.sender, self.receiver = sender, receiver

# Hybrid-wait margin: sleep until this close to a delivery deadline, then
# spin on the monotonic clock.  ``time.sleep`` alone overshoots by the
# kernel timer slack (measured 1.5 ms mean / 3 ms p90 here), which would
# put milliseconds of scheduling noise on every simulated-latency hop.
SPIN_WAIT_S = 3e-3

#: single-core default: a long spin can't reclaim precision when the
#: sender needs the same core to make progress — it only burns the GIL
#: quantum the peer was waiting for, so CI boxes pinned to one core get
#: a much shorter spin window by default.
SPIN_WAIT_SINGLE_CORE_S = 5e-4


def _effective_cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):       # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def spin_wait_s() -> float:
    """The spin-wait margin in effect: the ``REPRO_SPIN_WAIT_S`` env var
    when set to a valid non-negative float, else ``SPIN_WAIT_S``
    (``SPIN_WAIT_SINGLE_CORE_S`` on hosts with one effective core).
    Read at channel construction, so tests and deployments tune it
    without touching code."""
    raw = os.environ.get("REPRO_SPIN_WAIT_S")
    if raw is not None:
        try:
            v = float(raw)
            if v >= 0.0:
                return v
        except ValueError:
            pass
    return (SPIN_WAIT_S if _effective_cores() > 1
            else SPIN_WAIT_SINGLE_CORE_S)


def _wait_until(deadline: float, spin_s: float = SPIN_WAIT_S) -> None:
    """Block until ``time.monotonic() >= deadline`` with sub-0.1 ms
    precision: coarse sleep for the bulk, spin for the last ``spin_s``
    seconds.  The spin yields the GIL every iteration (``sleep(0)``) —
    a bare busy-loop would hold it for the interpreter's full 5 ms
    switch interval and serialize the owner threads against the
    scientist on small hosts."""
    while True:
        rem = deadline - time.monotonic()
        if rem <= 0.0:
            return
        if rem > spin_s:
            time.sleep(rem - spin_s)
        else:
            while time.monotonic() < deadline:
                time.sleep(0)
            return


# ---------------------------------------------------------------------------
# Wire format: one preallocated frame of named arrays
# ---------------------------------------------------------------------------
#
# Frame layout:  [u32 n_entries] then per entry
#   [u16 name_len][name][u16 dtype_len][dtype.name][u8 ndim][i64 dims...]
#   [i64 nbytes][raw buffer]
# ``dtype.name`` (not ``.str``) so the ml_dtypes extension types (bfloat16
# cut activations) round-trip.  The frame is sized in a first pass and the
# array buffers are copied directly into one scratch bytearray — no
# per-array ``tobytes`` allocation, no list-of-parts join.


def _frame_entries(payload: Dict[str, np.ndarray]):
    """Normalize payload values and precompute the exact frame size."""
    entries = []
    size = 4
    for name, arr in payload.items():
        arr = np.ascontiguousarray(np.asarray(arr))
        nb, dt = name.encode(), arr.dtype.name.encode()
        size += 2 + len(nb) + 2 + len(dt) + 1 + 8 * arr.ndim + 8 + arr.nbytes
        entries.append((nb, dt, arr))
    return entries, size


def _pack_into(payload: Dict[str, np.ndarray], buf: bytearray) -> int:
    """Pack ``{name: array}`` into ``buf`` (grown as needed), returning
    the number of bytes used.  ``buf`` is reusable scratch: callers
    snapshot the used prefix before the next send."""
    entries, size = _frame_entries(payload)
    if len(buf) < size:
        buf.extend(b"\0" * (size - len(buf)))
    struct.pack_into("<I", buf, 0, len(entries))
    off = 4
    for nb, dt, arr in entries:
        struct.pack_into("<H", buf, off, len(nb))
        off += 2
        buf[off:off + len(nb)] = nb
        off += len(nb)
        struct.pack_into("<H", buf, off, len(dt))
        off += 2
        buf[off:off + len(dt)] = dt
        off += len(dt)
        struct.pack_into("<B", buf, off, arr.ndim)
        off += 1
        struct.pack_into(f"<{arr.ndim}q", buf, off, *arr.shape)
        off += 8 * arr.ndim
        struct.pack_into("<q", buf, off, arr.nbytes)
        off += 8
        # via a flat uint8 view: the ml_dtypes extension types (bf16 cut
        # activations) expose no buffer protocol of their own
        buf[off:off + arr.nbytes] = memoryview(arr.reshape(-1).view(np.uint8))
        off += arr.nbytes
    return off


def _pack(payload: Dict[str, np.ndarray]) -> bytes:
    """Serialize ``{name: array}`` to one immutable blob."""
    buf = bytearray()
    used = _pack_into(payload, buf)
    return bytes(memoryview(buf)[:used])


def _unpack(blob: bytes) -> Dict[str, np.ndarray]:
    """Inverse of ``_pack``.  The returned arrays are zero-copy
    (read-only) views into ``blob`` — the receive buffer is the blob
    itself, shared for the message's lifetime instead of re-sliced into
    per-array copies."""
    out: Dict[str, np.ndarray] = {}
    off = 0
    (n,) = struct.unpack_from("<I", blob, off)
    off += 4
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", blob, off)
        off += 2
        name = blob[off:off + ln].decode()
        off += ln
        (ld,) = struct.unpack_from("<H", blob, off)
        off += 2
        dtype = np.dtype(blob[off:off + ld].decode())
        off += ld
        (ndim,) = struct.unpack_from("<B", blob, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", blob, off)
        off += 8 * ndim
        (nbytes,) = struct.unpack_from("<q", blob, off)
        off += 8
        count = nbytes // dtype.itemsize if dtype.itemsize else 0
        out[name] = np.frombuffer(blob, dtype=dtype, count=count,
                                  offset=off).reshape(shape)
        off += nbytes
    return out


def _payload_nbytes(payload: Dict[str, np.ndarray]) -> int:
    # jax and numpy arrays both expose .nbytes — no materialization
    return sum(getattr(a, "nbytes", None) or np.asarray(a).nbytes
               for a in payload.values())


# ---------------------------------------------------------------------------
# Messages and channels
# ---------------------------------------------------------------------------


@dataclass
class Message:
    sender: str
    receiver: str
    kind: str
    payload: Dict[str, np.ndarray]
    seq: int = 0
    payload_bytes: int = 0         # sum of array buffers (the protocol data)
    wire_bytes: int = 0            # serialized blob incl. headers (queue)
    not_before: float = 0.0        # simulated-network delivery time
    crc: Optional[int] = None      # crc32 of the blob (serialized backends)


class Channel:
    """One direction of a party boundary, with measured byte accounting.

    ``serialize=True`` (the ``queue`` backend) round-trips every payload
    through the wire format and models transit time; ``serialize=False``
    (the ``direct`` backend) hands the pytree over by reference.  Both are
    thread-safe FIFO queues, so message *order* is the protocol's
    happens-before edge (an owner applies the step-``t`` gradient before
    it sees the step-``t+1`` forward request).
    """

    def __init__(self, sender: str, receiver: str, *,
                 serialize: bool = True, latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 spin_s: Optional[float] = None, tap=None):
        self.sender, self.receiver = sender, receiver
        self.serialize = serialize
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        self.spin_s = spin_wait_s() if spin_s is None else spin_s
        # observation hook: tap(msg, blob) per send, with the serialized
        # frame (None on the direct backend).  The privacy-on-the-wire
        # tests capture full transcripts through this without touching
        # the send path's behavior.
        self.tap = tap
        # fault hook: fault_hook(kind, seq) -> (action, delay_s) | None,
        # installed by faults.arm_endpoint (drop/corrupt/delay)
        self.fault_hook = None
        self._q: "queue.Queue[Message]" = queue.Queue()
        self._lock = threading.Lock()
        # serializes access to the shared pack scratch: multiplexed
        # serving sessions send on one channel from several threads
        self._send_lock = threading.Lock()
        self._sendbuf = bytearray()     # reusable pack scratch
        self.stats: Dict[str, object] = {
            "messages": 0, "payload_bytes": 0, "wire_bytes": 0,
            "by_kind": {}}

    def _account(self, kind: str, payload_bytes: int, wire_bytes: int):
        with self._lock:
            st = self.stats
            st["messages"] += 1
            st["payload_bytes"] += payload_bytes
            st["wire_bytes"] += wire_bytes
            k = st["by_kind"].setdefault(
                kind, {"count": 0, "payload_bytes": 0, "wire_bytes": 0})
            k["count"] += 1
            k["payload_bytes"] += payload_bytes
            k["wire_bytes"] += wire_bytes

    def send(self, kind: str, payload: Dict[str, np.ndarray], *,
             seq: int = 0) -> Message:
        pb = _payload_nbytes(payload)
        blob = None
        crc = None
        if self.serialize:
            with self._send_lock:
                used = _pack_into(payload, self._sendbuf)
                blob = bytes(memoryview(self._sendbuf)[:used])
            wb = used
            crc = zlib.crc32(blob) & 0xFFFFFFFF
            payload = {"__blob__": blob}           # only bytes travel
        else:
            wb = pb                                # by-reference handoff
        msg = Message(self.sender, self.receiver, kind, payload, seq=seq,
                      payload_bytes=pb, wire_bytes=wb, crc=crc)
        if self.tap is not None:
            self.tap(msg, blob)
        fault = (self.fault_hook(kind, seq)
                 if self.fault_hook is not None else None)
        transit = self.latency_s + (wb / self.bandwidth_bps
                                    if self.bandwidth_bps else 0.0)
        if fault is not None and fault[0] == "delay":
            transit += fault[1]
        if transit:
            msg.not_before = time.monotonic() + transit
        self._account(kind, pb, wb)
        if fault is not None:
            action = fault[0]
            if action == "drop_frame":
                with self._lock:
                    self.stats["dropped_frames"] = self.stats.get(
                        "dropped_frames", 0) + 1
                return msg                         # lost on the wire
            if action == "corrupt_frame" and blob is not None:
                # flip one byte AFTER the crc was taken: the receiver's
                # integrity check fails loudly (FrameCorrupt)
                bad = bytearray(blob)
                bad[len(bad) // 2] ^= 0xFF
                msg.payload = {"__blob__": bytes(bad)}
        self._q.put(msg)
        return msg

    def recv(self, timeout: Optional[float] = None) -> Message:
        msg = self._q.get(timeout=timeout)
        if msg.not_before:
            _wait_until(msg.not_before, self.spin_s)
        if self.serialize:
            blob = msg.payload["__blob__"]
            if msg.crc is not None and (
                    zlib.crc32(blob) & 0xFFFFFFFF) != msg.crc:
                raise FrameCorrupt(msg.kind, msg.seq, self.sender,
                                   self.receiver)
            msg.payload = _unpack(blob)
        return msg

    def empty(self) -> bool:
        return self._q.empty()


class Endpoint:
    """A party's end of a duplex boundary: an outbox + an inbox channel.

    ``recv_kind`` stashes messages of other kinds instead of dropping
    them — in a pipelined schedule the next step's cut activations can
    already be in flight when the scientist waits for a barrier ack.
    The stash is lock-protected with a short-poll receive loop, so
    several multiplexed serving sessions can block in ``recv_kind`` on
    one shared endpoint concurrently: whichever thread drains a frame
    either consumes it or stashes it for the session it belongs to."""

    _POLL_S = 0.05

    def __init__(self, name: str, peer: str, outbox: Channel, inbox: Channel):
        self.name, self.peer = name, peer
        self.outbox, self.inbox = outbox, inbox
        self._stash: list = []
        # corrupt frames routed to the kind that owns them: a session
        # draining a shared endpoint must not die on another session's
        # corruption (see recv_kind)
        self._corrupt: Dict[str, FrameCorrupt] = {}
        self._rlock = threading.RLock()

    def send(self, kind: str, payload: Dict[str, np.ndarray], *,
             seq: int = 0) -> Message:
        return self.outbox.send(kind, payload, seq=seq)

    def recv(self, timeout: Optional[float] = None) -> Message:
        with self._rlock:
            if self._stash:
                return self._stash.pop(0)
        return self.inbox.recv(timeout=timeout)

    def recv_kind(self, kind: str, timeout: Optional[float] = None
                  ) -> Message:
        """Receive the next message of protocol kind ``kind``, keeping
        any earlier-arriving messages of other kinds for later.  Raises
        ``queue.Empty`` when ``timeout`` elapses first."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._rlock:
                if kind in self._corrupt:
                    raise self._corrupt.pop(kind)
                for i, m in enumerate(self._stash):
                    if m.kind == kind:
                        return self._stash.pop(i)
                try:
                    msg = self.inbox.recv(timeout=self._POLL_S)
                except queue.Empty:
                    msg = None
                except FrameCorrupt as e:
                    if e.kind == kind:
                        raise
                    self._corrupt[e.kind] = e    # another kind's problem
                    continue
                if msg is not None:
                    if msg.kind == kind:
                        return msg
                    self._stash.append(msg)
                    continue
            if deadline is not None and time.monotonic() >= deadline:
                raise queue.Empty

    def flush_pending(self) -> None:
        """Discard every stashed out-of-kind message and routed corrupt
        marker.  The supervised fit's post-rollback drain uses this:
        FIFO order means everything a party sent *before* its
        ``rollback_ack`` is stale, and the ack was just consumed."""
        with self._rlock:
            self._stash.clear()
            self._corrupt.clear()

    @property
    def sent_stats(self) -> Dict[str, object]:
        return self.outbox.stats

    @property
    def recv_stats(self) -> Dict[str, object]:
        return self.inbox.stats


class ScopedEndpoint:
    """A kind-prefixed view of a shared endpoint — session multiplexing.

    Many serving sessions share one owner<->scientist boundary; each
    session's frames ride the same channel with the session scope
    (e.g. ``"s3:"``) prepended to the protocol kind.  Works over both
    :class:`Endpoint` and ``process_transport.ProcessEndpoint`` (the
    kind already travels in the multiplex header on the pipe), and the
    base endpoint's locked stash absorbs cross-session interleaving.
    ``sent_stats``/``recv_stats`` are the prefix-filtered slice of the
    shared totals, with the scope stripped from ``by_kind`` keys — a
    session sees exactly its own traffic."""

    def __init__(self, base, scope: str):
        self.base, self.scope = base, scope
        self.name = getattr(base, "name", "?")
        self.peer = getattr(base, "peer", "?")

    def send(self, kind: str, payload: Dict[str, np.ndarray], *,
             seq: int = 0) -> Message:
        return self.base.send(self.scope + kind, payload, seq=seq)

    def recv_kind(self, kind: str, timeout: Optional[float] = None
                  ) -> Message:
        return self.base.recv_kind(self.scope + kind, timeout)

    def empty(self) -> bool:
        return self.base.empty()

    def _filter(self, stats: Dict[str, object]) -> Dict[str, object]:
        out = {"messages": 0, "payload_bytes": 0, "wire_bytes": 0,
               "by_kind": {}}
        for k, v in stats["by_kind"].items():
            if k.startswith(self.scope):
                out["by_kind"][k[len(self.scope):]] = v
                out["messages"] += v["count"]
                out["payload_bytes"] += v["payload_bytes"]
                out["wire_bytes"] += v["wire_bytes"]
        return out

    @property
    def sent_stats(self) -> Dict[str, object]:
        return self._filter(self.base.sent_stats)

    @property
    def recv_stats(self) -> Dict[str, object]:
        return self._filter(self.base.recv_stats)


def channel_pair(a: str, b: str, *, backend: str = "queue",
                 latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None,
                 spin_s: Optional[float] = None, tap=None
                 ) -> Tuple[Endpoint, Endpoint]:
    """Build the duplex boundary between parties ``a`` and ``b``.
    Returns ``(endpoint_a, endpoint_b)``.  ``tap`` observes every send
    on both directions (see :class:`Channel`)."""
    if backend not in ("queue", "direct"):
        raise ValueError(f"unknown transport backend {backend!r}")
    ser = backend == "queue"
    ab = Channel(a, b, serialize=ser, latency_s=latency_s,
                 bandwidth_bps=bandwidth_bps, spin_s=spin_s, tap=tap)
    ba = Channel(b, a, serialize=ser, latency_s=latency_s,
                 bandwidth_bps=bandwidth_bps, spin_s=spin_s, tap=tap)
    return Endpoint(a, b, ab, ba), Endpoint(b, a, ba, ab)


# ---------------------------------------------------------------------------
# Cut-payload codecs
# ---------------------------------------------------------------------------


class Codec:
    """Quantize-dequantize transform for cut payloads.  ``encode`` maps a
    float array to the wire payload dict; ``decode`` inverts it (lossy
    for fp16/int8).  The lossless codec preserves the model's own cut
    dtype on the wire — bf16 LM activations ship as 2 bytes/el, exactly
    what ``cut_layer_traffic`` accounts.  Encode/decode keep device
    arrays as device arrays: on the ``direct`` backend nothing here
    forces a host round-trip (serialization, when it happens, lives in
    ``Channel.send``)."""

    name = "none"

    def encode(self, arr) -> Dict[str, np.ndarray]:
        return {"x": arr}

    def decode(self, payload: Dict[str, np.ndarray]):
        return payload["x"]


class FP16Codec(Codec):
    name = "fp16"

    def encode(self, arr):
        return {"h": arr.astype(np.float16)}

    def decode(self, payload):
        return payload["h"].astype(np.float32)


class Int8Codec(Codec):
    """Per-row symmetric int8 (scale = absmax/127 over the last axis),
    quantized *and* wire-packed in one Pallas pass
    (``repro/kernels/quantize.quantize_pack_int8``): the payload is a
    single ``(rows, K+4)`` uint8 frame — K int8 values plus the
    little-endian f32 scale bitcast into the trailing 4 bytes of each
    row.  Decodes to float32 (consumers cast to their compute dtype)."""

    name = "int8"

    def encode(self, arr):
        from repro.kernels.quantize import quantize_pack_int8
        import jax.numpy as jnp
        a = jnp.asarray(arr).astype(jnp.float32)
        rows = a.reshape(-1, a.shape[-1])
        packed = quantize_pack_int8(rows)
        return {"qp": packed.reshape(a.shape[:-1] + (packed.shape[-1],))}

    def decode(self, payload):
        qp = np.asarray(payload["qp"])
        k = qp.shape[-1] - 4
        q = qp[..., :k].view(np.int8)
        scale = np.ascontiguousarray(qp[..., k:]).view("<f4")
        return q.astype(np.float32) * scale


CODECS = {c.name: c for c in (Codec, FP16Codec, Int8Codec)}


def get_codec(name: Optional[str]) -> Codec:
    key = name or "none"
    if key not in CODECS:
        raise ValueError(f"unknown compression {name!r}; "
                         f"known: {sorted(CODECS)}")
    return CODECS[key]()
