"""The transport layer: what actually crosses the party boundary.

Until this module existed, "communication" in the repro was an analytic
estimate (``core.splitnn.cut_layer_traffic``) layered over one joint
autodiff program.  This module makes the boundary real: parties exchange
:class:`Message` objects over :class:`Channel` s, and everything the
session reports about traffic is *measured* from the wire.

Two backends:

  * ``direct``  — in-process handoff.  Payload pytrees move by reference
    (zero-copy); bytes are still accounted from the array buffers.  This
    is the fast path for same-process simulation and serving.
  * ``queue``   — a simulated network.  Every payload is serialized to a
    length-prefixed wire format (``_pack``/``_unpack``), byte counts are
    taken from the actual blob, and delivery can be delayed by a
    configurable ``latency_s`` plus ``wire_bytes / bandwidth_bps``.
    Channels are thread-safe: owner compute endpoints run on their own
    threads (``federation/parties.OwnerComputeEndpoint``), so pipelined
    schedules overlap owner and scientist compute in real wall-clock.

Cut-payload codecs live here too (``get_codec``): the only bytes that
cross the boundary are cut activations and cut gradients, so shrinking
them is the protocol's one compression lever (Secure Forward Aggregation,
Cai et al. 2022, quantizes the same tensor).  ``fp16`` is a plain
down-cast; ``int8`` is per-row symmetric quantization through the Pallas
kernel in ``repro/kernels/quantize``.
"""
from __future__ import annotations

import queue
import struct
import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["Message", "Channel", "Endpoint", "channel_pair",
           "Codec", "get_codec", "CODECS"]


# ---------------------------------------------------------------------------
# Wire format: length-prefixed named arrays
# ---------------------------------------------------------------------------


def _pack(payload: Dict[str, np.ndarray]) -> bytes:
    """Serialize ``{name: array}`` to one blob.  Per entry:
    [u16 name_len][name][u16 dtype_len][dtype.name][u8 ndim][i64 dims...]
    [i64 nbytes][raw buffer].  ``dtype.name`` (not ``.str``) so the
    ml_dtypes extension types (bfloat16 cut activations) round-trip."""
    parts = [struct.pack("<I", len(payload))]
    for name, arr in payload.items():
        arr = np.ascontiguousarray(arr)
        nb, dt = name.encode(), arr.dtype.name.encode()
        parts.append(struct.pack("<H", len(nb)))
        parts.append(nb)
        parts.append(struct.pack("<H", len(dt)))
        parts.append(dt)
        parts.append(struct.pack("<B", arr.ndim))
        parts.append(struct.pack(f"<{arr.ndim}q", *arr.shape))
        body = arr.tobytes()
        parts.append(struct.pack("<q", len(body)))
        parts.append(body)
    return b"".join(parts)


def _unpack(blob: bytes) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    off = 0
    (n,) = struct.unpack_from("<I", blob, off)
    off += 4
    for _ in range(n):
        (ln,) = struct.unpack_from("<H", blob, off)
        off += 2
        name = blob[off:off + ln].decode()
        off += ln
        (ld,) = struct.unpack_from("<H", blob, off)
        off += 2
        dtype = np.dtype(blob[off:off + ld].decode())
        off += ld
        (ndim,) = struct.unpack_from("<B", blob, off)
        off += 1
        shape = struct.unpack_from(f"<{ndim}q", blob, off)
        off += 8 * ndim
        (nbytes,) = struct.unpack_from("<q", blob, off)
        off += 8
        out[name] = np.frombuffer(
            blob[off:off + nbytes], dtype=dtype).reshape(shape)
        off += nbytes
    return out


def _payload_nbytes(payload: Dict[str, np.ndarray]) -> int:
    # jax and numpy arrays both expose .nbytes — no materialization
    return sum(getattr(a, "nbytes", None) or np.asarray(a).nbytes
               for a in payload.values())


# ---------------------------------------------------------------------------
# Messages and channels
# ---------------------------------------------------------------------------


@dataclass
class Message:
    sender: str
    receiver: str
    kind: str
    payload: Dict[str, np.ndarray]
    seq: int = 0
    payload_bytes: int = 0         # sum of array buffers (the protocol data)
    wire_bytes: int = 0            # serialized blob incl. headers (queue)
    not_before: float = 0.0        # simulated-network delivery time


class Channel:
    """One direction of a party boundary, with measured byte accounting.

    ``serialize=True`` (the ``queue`` backend) round-trips every payload
    through the wire format and models transit time; ``serialize=False``
    (the ``direct`` backend) hands the pytree over by reference.  Both are
    thread-safe FIFO queues, so message *order* is the protocol's
    happens-before edge (an owner applies the step-``t`` gradient before
    it sees the step-``t+1`` forward request).
    """

    def __init__(self, sender: str, receiver: str, *,
                 serialize: bool = True, latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None):
        self.sender, self.receiver = sender, receiver
        self.serialize = serialize
        self.latency_s = latency_s
        self.bandwidth_bps = bandwidth_bps
        self._q: "queue.Queue[Message]" = queue.Queue()
        self._lock = threading.Lock()
        self.stats: Dict[str, object] = {
            "messages": 0, "payload_bytes": 0, "wire_bytes": 0,
            "by_kind": {}}

    def _account(self, kind: str, payload_bytes: int, wire_bytes: int):
        with self._lock:
            st = self.stats
            st["messages"] += 1
            st["payload_bytes"] += payload_bytes
            st["wire_bytes"] += wire_bytes
            k = st["by_kind"].setdefault(
                kind, {"count": 0, "payload_bytes": 0, "wire_bytes": 0})
            k["count"] += 1
            k["payload_bytes"] += payload_bytes
            k["wire_bytes"] += wire_bytes

    def send(self, kind: str, payload: Dict[str, np.ndarray], *,
             seq: int = 0) -> Message:
        pb = _payload_nbytes(payload)
        if self.serialize:
            blob = _pack({k: np.asarray(v) for k, v in payload.items()})
            wb = len(blob)
            payload = {"__blob__": blob}           # only bytes travel
        else:
            wb = pb                                # by-reference handoff
        msg = Message(self.sender, self.receiver, kind, payload, seq=seq,
                      payload_bytes=pb, wire_bytes=wb)
        if self.latency_s or self.bandwidth_bps:
            transit = self.latency_s + (wb / self.bandwidth_bps
                                        if self.bandwidth_bps else 0.0)
            msg.not_before = time.monotonic() + transit
        self._account(kind, pb, wb)
        self._q.put(msg)
        return msg

    def recv(self, timeout: Optional[float] = None) -> Message:
        msg = self._q.get(timeout=timeout)
        if msg.not_before:
            delay = msg.not_before - time.monotonic()
            if delay > 0:
                time.sleep(delay)
        if self.serialize:
            msg.payload = _unpack(msg.payload["__blob__"])
        return msg

    def empty(self) -> bool:
        return self._q.empty()


class Endpoint:
    """A party's end of a duplex boundary: an outbox + an inbox channel.

    ``recv_kind`` stashes messages of other kinds instead of dropping
    them — in a pipelined schedule the next step's cut activations can
    already be in flight when the scientist waits for a barrier ack."""

    def __init__(self, name: str, peer: str, outbox: Channel, inbox: Channel):
        self.name, self.peer = name, peer
        self.outbox, self.inbox = outbox, inbox
        self._stash: list = []

    def send(self, kind: str, payload: Dict[str, np.ndarray], *,
             seq: int = 0) -> Message:
        return self.outbox.send(kind, payload, seq=seq)

    def recv(self, timeout: Optional[float] = None) -> Message:
        if self._stash:
            return self._stash.pop(0)
        return self.inbox.recv(timeout=timeout)

    def recv_kind(self, kind: str, timeout: Optional[float] = None
                  ) -> Message:
        """Receive the next message of protocol kind ``kind``, keeping
        any earlier-arriving messages of other kinds for later."""
        for i, m in enumerate(self._stash):
            if m.kind == kind:
                return self._stash.pop(i)
        while True:
            msg = self.inbox.recv(timeout=timeout)
            if msg.kind == kind:
                return msg
            self._stash.append(msg)

    @property
    def sent_stats(self) -> Dict[str, object]:
        return self.outbox.stats

    @property
    def recv_stats(self) -> Dict[str, object]:
        return self.inbox.stats


def channel_pair(a: str, b: str, *, backend: str = "queue",
                 latency_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None
                 ) -> Tuple[Endpoint, Endpoint]:
    """Build the duplex boundary between parties ``a`` and ``b``.
    Returns ``(endpoint_a, endpoint_b)``."""
    if backend not in ("queue", "direct"):
        raise ValueError(f"unknown transport backend {backend!r}")
    ser = backend == "queue"
    ab = Channel(a, b, serialize=ser, latency_s=latency_s,
                 bandwidth_bps=bandwidth_bps)
    ba = Channel(b, a, serialize=ser, latency_s=latency_s,
                 bandwidth_bps=bandwidth_bps)
    return Endpoint(a, b, ab, ba), Endpoint(b, a, ba, ab)


# ---------------------------------------------------------------------------
# Cut-payload codecs
# ---------------------------------------------------------------------------


class Codec:
    """Quantize-dequantize transform for cut payloads.  ``encode`` maps a
    float array to the wire payload dict; ``decode`` inverts it (lossy
    for fp16/int8).  The lossless codec preserves the model's own cut
    dtype on the wire — bf16 LM activations ship as 2 bytes/el, exactly
    what ``cut_layer_traffic`` accounts."""

    name = "none"

    def encode(self, arr) -> Dict[str, np.ndarray]:
        return {"x": np.asarray(arr)}

    def decode(self, payload: Dict[str, np.ndarray]) -> np.ndarray:
        return np.asarray(payload["x"])


class FP16Codec(Codec):
    name = "fp16"

    def encode(self, arr):
        return {"h": np.asarray(arr).astype(np.float16)}

    def decode(self, payload):
        return payload["h"].astype(np.float32)


class Int8Codec(Codec):
    """Per-row symmetric int8 (scale = absmax/127 over the last axis),
    computed by the Pallas kernel in ``repro/kernels/quantize``.
    Decodes to float32 (consumers cast to their compute dtype)."""

    name = "int8"

    def encode(self, arr):
        from repro.kernels.quantize import quantize_int8
        a = np.asarray(arr).astype(np.float32)
        rows = a.reshape(-1, a.shape[-1])
        q, scale = quantize_int8(rows)
        return {"q": np.asarray(q).reshape(a.shape),
                "s": np.asarray(scale).reshape(a.shape[:-1] + (1,))}

    def decode(self, payload):
        return (payload["q"].astype(np.float32) *
                payload["s"].astype(np.float32))


CODECS = {c.name: c for c in (Codec, FP16Codec, Int8Codec)}


def get_codec(name: Optional[str]) -> Codec:
    key = name or "none"
    if key not in CODECS:
        raise ValueError(f"unknown compression {name!r}; "
                         f"known: {sorted(CODECS)}")
    return CODECS[key]()
