"""Model registry: one ``session.build(config)`` for every split model.

A config object (``MLPSplitConfig``, ``ArchConfig``, or anything a later
PR registers) dispatches to an *adapter* that gives the session a uniform
surface: ``init``, ``loss_fn``, batch assembly in the right layout
(``federation/batching.py``), default per-segment optimizers, and —
where supported — the serving engine.  New architectures and combine
strategies land as a registry entry + config, not a new training script.

Adapters with ``supports_split = True`` additionally expose the
per-segment surface that true split execution (``fit(mode="split")``)
runs over the transport layer:

  ``owner_programs(p)``      -> (head_fwd, head_bwd) jitted owner programs
  ``trunk_program()``        -> jitted scientist step
                                 (trunk_params, cut, labels) ->
                                 (metrics, trunk_grads, cut_grads)
  ``owner_param_slice`` / ``stack_head_params``
                             -> move one owner's head segment in/out of
                                the joint param tree
  ``owner_optimizer`` / ``trunk_optimizer``
                             -> the per-party update rules (the joint
                                ``default_optimizer`` split at the same
                                boundary)

Adapters with ``supports_microbatch = True`` add the GPipe surface used
by ``fit(..., microbatches=M)``:

  ``trunk_microbatch_programs()`` -> (cutgrad, weightgrad) per-chunk
                                 scientist programs (sum/denom seeding)
  ``gather_program()``       -> jitted device-side row gather
                                 (feats, idx) -> rows, so the dispatch
                                 loop never blocks on a host transfer
  ``owner_update_rule(lr)`` / ``trunk_update_rule(lr)``
                             -> (optimizer, jitted update+apply with
                                buffer donation), cached so the split
                                workers and the microbatched joint
                                oracle run the *same* compiled programs

Every program accessor is cached on the adapter: the microbatched joint
oracle and the transport-backed split schedule must execute identical
compiled programs for the bit-for-bit equivalence contract to be about
the *protocol* rather than about XLA codegen stability.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.federation import batching
from repro.optim import (adam, apply_updates, chain, clip_by_global_norm,
                         multi_segment, sgd)


class _ProgramCache:
    """Mixin: build-once accessors for jitted segment programs."""

    def _cached(self, key, builder):
        cache = getattr(self, "_progs", None)
        if cache is None:
            cache = self._progs = {}
        if key not in cache:
            cache[key] = builder()
        return cache[key]

    def gather_program(self):
        """Device-side row gather: (staged feature array, idx) -> rows.
        One jitted program shared by owner workers and the joint oracle —
        feature matrices are staged on device once, so per-step batch
        assembly never round-trips through the host."""
        return self._cached(
            "gather", lambda: jax.jit(lambda feats, idx: feats[idx]))

    def _update_rule(self, key, optimizer):
        def build():
            def upd(params, state, grads, step):
                updates, state_ = optimizer.update(grads, state, params,
                                                   step)
                return apply_updates(params, updates), state_
            return optimizer, jax.jit(upd, donate_argnums=(0, 1))
        return self._cached(key, build)

    def owner_update_rule(self, owner_lr: Optional[float] = None):
        """(optimizer, jitted update+apply) for one owner's head segment.
        update+apply compile together — the joint step's fusion
        granularity (bit-for-bit equivalence depends on it) — and donate
        the param/state buffers."""
        return self._update_rule(("owner_upd", owner_lr),
                                 self.owner_optimizer(owner_lr))

    def trunk_update_rule(self, scientist_lr: Optional[float] = None):
        return self._update_rule(("trunk_upd", scientist_lr),
                                 self.trunk_optimizer(scientist_lr))

    def owner_tail_rule(self, owner_lr: Optional[float] = None,
                        owner_index: int = 0):
        """The owner's latency-critical tail as ONE compiled program:
        backward for the step's final gradient chunk (+ fold into the
        accumulated grads when microbatched), the optimizer update, and
        the forward for the *next* step's first chunk.  One dispatch
        instead of three and no host sync between segments — and
        bitwise-identical to the separate programs (property-tested).
        ``acc`` may be ``None`` (single-chunk steps add nothing — not
        even a zeros-tree, which would flip -0.0 gradient signs)."""
        head_fwd, head_bwd = self.owner_programs(owner_index)
        optimizer = self.owner_optimizer(owner_lr)
        key = ("owner_tail", owner_lr, id(head_fwd))

        def build():
            def tail(p, s, acc, x, g, step, x_next):
                gr = head_bwd(p, x, g)
                if acc is not None:
                    gr = jax.tree.map(lambda a, b: a + b, acc, gr)
                updates, s2 = optimizer.update(gr, s, p, step)
                p2 = apply_updates(p, updates)
                return p2, s2, head_fwd(p2, x_next)

            return jax.jit(tail, donate_argnums=(0, 1))

        return self._cached(key, build)

_BUILDERS: Dict[type, Callable] = {}


def register_model(*cfg_types: type):
    """Class decorator: dispatch ``session.build(cfg)`` on ``type(cfg)``
    (subclasses included) to the decorated adapter."""
    def deco(adapter_cls):
        for t in cfg_types:
            _BUILDERS[t] = adapter_cls
        return adapter_cls
    return deco


def build_adapter(cfg):
    for t in type(cfg).__mro__:
        if t in _BUILDERS:
            return _BUILDERS[t](cfg)
    raise TypeError(
        f"no split-model adapter registered for {type(cfg).__name__}; "
        f"known: {[t.__name__ for t in _BUILDERS]}")


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------

from repro.configs.base import ArchConfig
from repro.configs.pyvertical_mnist import MLPSplitConfig
from repro.core.splitnn import MLPSplitNN
from repro.models.model import SplitModel


@register_model(MLPSplitConfig)
class MLPAdapter(_ProgramCache):
    """The paper's Appendix-B dual-headed MLP on feature-split data."""

    layout = "feature"
    supports_serving = False

    def __init__(self, cfg: MLPSplitConfig):
        self.cfg = cfg
        self.model = MLPSplitNN(cfg)
        self.loss_fn = self.model.loss_fn

    def init(self, key):
        return self.model.init(key)

    def make_batch(self, owner_arrays: Sequence[np.ndarray],
                   labels: Optional[np.ndarray], idx=None):
        return batching.feature_batch(owner_arrays, labels, idx)

    def _segment_opts(self, owner_lr: Optional[float] = None,
                      scientist_lr: Optional[float] = None):
        """THE per-segment update rules (Appendix B) — the joint
        ``default_optimizer`` and the split-mode per-party optimizers
        are both derived from this one definition."""
        sp = self.cfg.split
        return {
            "heads": sgd(owner_lr if owner_lr is not None
                         else sp.owner_lr),
            "trunk": sgd(scientist_lr if scientist_lr is not None
                         else sp.scientist_lr)}

    def default_optimizer(self, owner_lr: Optional[float] = None,
                          scientist_lr: Optional[float] = None):
        return multi_segment(self._segment_opts(owner_lr, scientist_lr))

    def cut_shape(self, batch_size: int,
                  feature_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        """Per-owner cut activation shape: (B, k) — NOT the raw width."""
        return (batch_size, self.model.k)

    # ------------------------------------------------- split execution
    supports_split = True
    supports_microbatch = True
    supports_nopeek = True

    def owner_programs(self, owner_index: int):
        from repro.core.splitnn import make_mlp_head_programs
        # one shape-polymorphic program pair serves every owner; the
        # NoPeek weight is baked into the backward at trace time, so it
        # keys the cache
        w = float(self.cfg.split.nopeek_weight)
        return self._cached(("head_progs", w),
                            lambda: make_mlp_head_programs(self.model, w))

    def trunk_program(self):
        from repro.core.splitnn import make_mlp_trunk_program
        return self._cached("trunk_prog",
                            lambda: make_mlp_trunk_program(self.model))

    def trunk_microbatch_programs(self):
        from repro.core.splitnn import make_mlp_trunk_microbatch_programs
        return self._cached(
            "trunk_micro",
            lambda: make_mlp_trunk_microbatch_programs(self.model))

    # ------------------------------------- secure forward aggregation
    @property
    def supports_masked(self) -> bool:
        """masked_sum rides the sum combine: the scientist only ever
        needs ``sum_p cut_p``, which the ring fold reconstructs."""
        return self.cfg.split.combine == "sum"

    def quant_program(self):
        from repro.core import masking
        return self._cached("quant_prog", masking.make_quant_program)

    def masked_trunk_program(self):
        from repro.core.splitnn import make_mlp_masked_trunk_program
        return self._cached(
            "masked_trunk_prog",
            lambda: make_mlp_masked_trunk_program(self.model))

    def masked_trunk_microbatch_programs(self):
        from repro.core.splitnn import \
            make_mlp_masked_trunk_microbatch_programs
        return self._cached(
            "masked_trunk_micro",
            lambda: make_mlp_masked_trunk_microbatch_programs(self.model))

    def owner_param_slice(self, params, p: int):
        if self.model.symmetric:
            return jax.tree.map(lambda a: a[p], params["heads"])
        return params["heads"][p]

    def stack_head_params(self, slices: Sequence):
        if self.model.symmetric:
            return jax.tree.map(lambda *xs: jnp.stack(xs), *slices)
        return list(slices)

    def owner_batch(self, owner_array: np.ndarray, idx):
        return jnp.asarray(owner_array[idx])

    def owner_optimizer(self, owner_lr: Optional[float] = None):
        # plain SGD is elementwise, so one owner's slice of the joint
        # stacked-heads update IS this update (bit-for-bit equivalence)
        return self._segment_opts(owner_lr=owner_lr)["heads"]

    def trunk_optimizer(self, scientist_lr: Optional[float] = None):
        return self._segment_opts(scientist_lr=scientist_lr)["trunk"]


@register_model(ArchConfig)
class SplitLMAdapter(_ProgramCache):
    """Sequence-split language models (`SplitModel`) — text modality."""

    layout = "sequence"
    supports_serving = True

    def __init__(self, cfg: ArchConfig):
        if cfg.modality != "text":
            raise ValueError(
                f"VerticalSession drives text archs; {cfg.name} is "
                f"{cfg.modality} (see examples/ for vlm/audio training)")
        if float(getattr(cfg.split, "nopeek_weight", 0.0)) > 0.0:
            # refuse rather than silently train undefended: the LM head
            # has no NoPeek program (token inputs have no meaningful
            # euclidean geometry for the dcor penalty)
            raise ValueError(
                "SplitConfig.nopeek_weight > 0 is not supported by the "
                "sequence-split LM adapter (supports_nopeek=False); use "
                "cut_noise_std / grad-side defences instead")
        self.cfg = cfg
        self.model = SplitModel(cfg)
        self.loss_fn = self.model.loss_fn

    def init(self, key):
        return self.model.init(key)

    def make_batch(self, owner_arrays: Sequence[np.ndarray],
                   labels: Optional[np.ndarray], idx=None):
        return batching.sequence_batch(owner_arrays, labels, idx)

    def _segment_opts(self, owner_lr: Optional[float] = None,
                      scientist_lr: Optional[float] = None):
        """THE per-segment update rules, shared by the joint and split
        paths.  NOTE the clip scope differs by construction: jointly the
        "heads" rule sees every owner's grads (one global norm), while
        split mode applies the same rule to one owner's slice — the
        honest federated analogue (an owner cannot see peers' grads)."""
        return {
            "heads": chain(clip_by_global_norm(1.0),
                           adam(owner_lr if owner_lr is not None
                                else 1e-3)),
            "trunk": chain(clip_by_global_norm(1.0),
                           adam(scientist_lr if scientist_lr is not None
                                else 1e-3))}

    def default_optimizer(self, owner_lr: Optional[float] = None,
                          scientist_lr: Optional[float] = None):
        return multi_segment(self._segment_opts(owner_lr, scientist_lr))

    def cut_shape(self, batch_size: int,
                  feature_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        """(B, S_p, k): sequence-slice cut activations."""
        return (batch_size, feature_shape[0], self.model.k)

    def make_engine(self, params, **engine_kw):
        from repro.launch.engine import ServingEngine   # avoid import cycle
        return ServingEngine(self.model, params, **engine_kw)

    # ------------------------------------------------- split execution
    supports_split = True
    supports_microbatch = True
    # LM cuts are sequence-sliced then concat-combined (and cast to
    # compute dtype per owner) — no sum combine, so no ring aggregation
    supports_masked = False
    supports_nopeek = False

    def owner_programs(self, owner_index: int):
        """Owner ``owner_index``'s jitted segment programs.  The head
        forward embeds + runs the head blocks on the owner's sequence
        slice (global rope positions for that slice), returning ``(cut,
        aux)`` — the scalar aux rides along so split-mode metrics match
        the joint path's heads+trunk aux; the backward is an explicit
        VJP seeded with the received cut gradient plus a unit cotangent
        on that owner-local aux loss (MoE balance gradients never need
        to cross the boundary)."""
        model = self.model

        def build():
            def head_apply(hp, tokens):
                S_p = tokens.shape[-1]
                positions = model._positions(S_p, owner_index)
                cut, _, aux = model._head_one(hp, tokens, positions, 0)
                return cut, aux

            def head_fwd(hp, tokens):
                return head_apply(hp, tokens)

            def head_bwd(hp, tokens, g):
                (cut, aux), vjp = jax.vjp(
                    lambda q: head_apply(q, tokens), hp)
                return vjp((g.astype(cut.dtype),
                            jnp.ones((), aux.dtype)))[0]

            return jax.jit(head_fwd), jax.jit(head_bwd)

        return self._cached(("head_progs", owner_index), build)

    def trunk_program(self):
        model = self.model
        cdt = jnp.dtype(model.cfg.compute_dtype)

        def build():
            def trunk_step(tp, cut, labels):
                def f(tp_, cut_):
                    z = model.combine(cut_.astype(cdt))
                    logits, _, aux_t = model.trunk_forward(tp_, z)
                    ce = model.ce_loss(logits, labels)
                    return ce + aux_t, {"loss": ce, "aux": aux_t}

                (_, metrics), (tg, cg) = jax.value_and_grad(
                    f, argnums=(0, 1), has_aux=True)(tp, cut)
                return metrics, tg, cg

            return jax.jit(trunk_step)

        return self._cached("trunk_prog", build)

    def trunk_microbatch_programs(self):
        """Per-chunk scientist programs (GPipe).  The chunk CE is scaled
        ``bm / denom`` (= chunk mean re-weighted to the full-batch mean)
        and the trunk aux loss contributes ``aux / n_micro``, so summing
        metric parts and grads across chunks reproduces full-batch
        semantics; per-owner clipping already makes the LM path
        tolerance- (not bit-) equivalent to the fused joint program."""
        model = self.model
        cdt = jnp.dtype(model.cfg.compute_dtype)

        def build():
            def chunk_loss(tp, cuts, labels, denom, inv_micro):
                z = model.combine(jnp.stack(cuts).astype(cdt))
                logits, _, aux_t = model.trunk_forward(tp, z)
                ce = model.ce_loss(logits, labels) \
                    * labels.shape[0] / denom
                aux = aux_t * inv_micro
                return ce + aux, {"loss": ce, "aux": aux}

            def cutgrad(tp, cuts, labels, denom, inv_micro):
                (_, parts), cg = jax.value_and_grad(
                    lambda c: chunk_loss(tp, c, labels, denom, inv_micro),
                    has_aux=True)(tuple(cuts))
                return cg, parts

            def weightgrad(tp, cuts, labels, denom, inv_micro):
                return jax.grad(
                    lambda p: chunk_loss(p, tuple(cuts), labels, denom,
                                         inv_micro)[0])(tp)

            return jax.jit(cutgrad), jax.jit(weightgrad)

        return self._cached("trunk_micro", build)

    def owner_param_slice(self, params, p: int):
        return jax.tree.map(lambda a: a[p], params["heads"])

    def stack_head_params(self, slices: Sequence):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *slices)

    def owner_batch(self, owner_array: np.ndarray, idx):
        return jnp.asarray(owner_array[idx])

    def owner_optimizer(self, owner_lr: Optional[float] = None):
        return self._segment_opts(owner_lr=owner_lr)["heads"]

    def trunk_optimizer(self, scientist_lr: Optional[float] = None):
        return self._segment_opts(scientist_lr=scientist_lr)["trunk"]
