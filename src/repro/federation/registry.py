"""Model registry: one ``session.build(config)`` for every split model.

A config object (``MLPSplitConfig``, ``ArchConfig``, or anything a later
PR registers) dispatches to an *adapter* that gives the session a uniform
surface: ``init``, ``loss_fn``, batch assembly in the right layout
(``federation/batching.py``), default per-segment optimizers, and —
where supported — the serving engine.  New architectures and combine
strategies land as a registry entry + config, not a new training script.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence, Tuple, Type

import numpy as np

from repro.federation import batching
from repro.optim import adam, chain, clip_by_global_norm, multi_segment, sgd

_BUILDERS: Dict[type, Callable] = {}


def register_model(*cfg_types: type):
    """Class decorator: dispatch ``session.build(cfg)`` on ``type(cfg)``
    (subclasses included) to the decorated adapter."""
    def deco(adapter_cls):
        for t in cfg_types:
            _BUILDERS[t] = adapter_cls
        return adapter_cls
    return deco


def build_adapter(cfg):
    for t in type(cfg).__mro__:
        if t in _BUILDERS:
            return _BUILDERS[t](cfg)
    raise TypeError(
        f"no split-model adapter registered for {type(cfg).__name__}; "
        f"known: {[t.__name__ for t in _BUILDERS]}")


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------

from repro.configs.base import ArchConfig
from repro.configs.pyvertical_mnist import MLPSplitConfig
from repro.core.splitnn import MLPSplitNN
from repro.models.model import SplitModel


@register_model(MLPSplitConfig)
class MLPAdapter:
    """The paper's Appendix-B dual-headed MLP on feature-split data."""

    layout = "feature"
    supports_serving = False

    def __init__(self, cfg: MLPSplitConfig):
        self.cfg = cfg
        self.model = MLPSplitNN(cfg)
        self.loss_fn = self.model.loss_fn

    def init(self, key):
        return self.model.init(key)

    def make_batch(self, owner_arrays: Sequence[np.ndarray],
                   labels: Optional[np.ndarray], idx=None):
        return batching.feature_batch(owner_arrays, labels, idx)

    def default_optimizer(self, owner_lr: Optional[float] = None,
                          scientist_lr: Optional[float] = None):
        sp = self.cfg.split
        return multi_segment({
            "heads": sgd(owner_lr if owner_lr is not None else sp.owner_lr),
            "trunk": sgd(scientist_lr if scientist_lr is not None
                         else sp.scientist_lr)})

    def cut_shape(self, batch_size: int,
                  feature_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        """Per-owner cut activation shape: (B, k) — NOT the raw width."""
        return (batch_size, self.model.k)


@register_model(ArchConfig)
class SplitLMAdapter:
    """Sequence-split language models (`SplitModel`) — text modality."""

    layout = "sequence"
    supports_serving = True

    def __init__(self, cfg: ArchConfig):
        if cfg.modality != "text":
            raise ValueError(
                f"VerticalSession drives text archs; {cfg.name} is "
                f"{cfg.modality} (see examples/ for vlm/audio training)")
        self.cfg = cfg
        self.model = SplitModel(cfg)
        self.loss_fn = self.model.loss_fn

    def init(self, key):
        return self.model.init(key)

    def make_batch(self, owner_arrays: Sequence[np.ndarray],
                   labels: Optional[np.ndarray], idx=None):
        return batching.sequence_batch(owner_arrays, labels, idx)

    def default_optimizer(self, owner_lr: Optional[float] = None,
                          scientist_lr: Optional[float] = None):
        return multi_segment({
            "heads": chain(clip_by_global_norm(1.0),
                           adam(owner_lr if owner_lr is not None else 1e-3)),
            "trunk": chain(clip_by_global_norm(1.0),
                           adam(scientist_lr if scientist_lr is not None
                                else 1e-3))})

    def cut_shape(self, batch_size: int,
                  feature_shape: Tuple[int, ...]) -> Tuple[int, ...]:
        """(B, S_p, k): sequence-slice cut activations."""
        return (batch_size, feature_shape[0], self.model.k)

    def make_engine(self, params, **engine_kw):
        from repro.launch.engine import ServingEngine   # avoid import cycle
        return ServingEngine(self.model, params, **engine_kw)
