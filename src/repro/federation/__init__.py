# The paper's party-centric API: DataOwner / DataScientist objects with a
# structural visibility contract, and the VerticalSession facade unifying
# PSI resolution, SplitNN training, evaluation, and split-inference
# serving.  Every workflow (examples/, launch/) is a thin client of this
# package; batch partitioning lives exclusively in federation.batching.
from repro.federation.parties import (DataOwner, DataScientist,  # noqa
                                      OwnerComputeEndpoint, PrivacyError,
                                      feature_parties, sequence_parties)
from repro.federation.registry import build_adapter, register_model  # noqa
from repro.federation.session import VerticalSession  # noqa: F401
from repro.federation import batching  # noqa: F401
from repro.federation import psi_transport  # noqa: F401
from repro.federation import transport  # noqa: F401
