# The paper's party-centric API: DataOwner / DataScientist objects with a
# structural visibility contract, and the VerticalSession facade unifying
# PSI resolution, SplitNN training, evaluation, and split-inference
# serving.  Every workflow (examples/, launch/) is a thin client of this
# package; batch partitioning lives exclusively in federation.batching.
#
# Re-exports are lazy (PEP 562, the same discipline as ``repro.core``):
# importing the wire-level stack (``transport`` / ``process_transport`` /
# ``psi_transport`` / ``runtime``) must NOT pull in jax — spawned PSI
# worker processes (``runtime.psi_worker_main``) run the jax-free PSI
# protocol in a numpy-light interpreter, and eager session/parties
# imports here would drag the ~300 MB XLA image into every one of them.
import importlib

_EXPORTS = {
    "DataOwner": "parties",
    "DataScientist": "parties",
    "OwnerComputeEndpoint": "parties",
    "PrivacyError": "parties",
    "feature_parties": "parties",
    "sequence_parties": "parties",
    "build_adapter": "registry",
    "register_model": "registry",
    "VerticalSession": "session",
}
_SUBMODULES = ("batching", "parties", "process_transport", "psi_transport",
               "registry", "runtime", "session", "transport")

__all__ = sorted(list(_EXPORTS) + list(_SUBMODULES))


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(
            f"repro.federation.{_EXPORTS[name]}")
        return getattr(mod, name)
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.federation.{name}")
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(list(globals()) + __all__))
