"""Wire-native PSI — entity resolution over the transport layer.

Until this module existed, ``session.resolve`` ran the PSI rounds as
direct Python calls between the party objects (``core/psi.py``): correct
and streamed, but nothing actually *crossed* the party boundary the way
training and serving traffic does.  This module frames every leg of both
protocol variants as :class:`~repro.federation.transport.Message` s over
a ``channel_pair``, so the full lifecycle (resolve -> fit -> serve) runs
over the same measured wire: byte counts come from serialized frames,
latency injection applies to every chunk, and tests can assert privacy
properties on the *observed traffic* rather than on code structure.

Cast:

  * :class:`PSIServerEndpoint` — the data owner's actor.  Runs on its
    own thread (the resolve analogue of ``parties.OwnerComputeEndpoint``)
    holding a :class:`~repro.core.psi.PSIServer`; everything it does is a
    reaction to inbox messages, and a crash surfaces on the scientist's
    side through the same short-poll pattern split training uses.
  * :func:`wire_psi_round` — the data scientist's driver.  Sends the
    hello + blinded upload, then consumes the server's legs as they
    arrive, feeding each chunk's lift/unblind ``pow_chunk`` task through
    a ``ModexpPool`` so receive, compute, and the server's own modexp
    work all overlap.

Protocol (kinds in ``WIRE_KINDS``; frame layouts golden-tested in
``tests/test_psi_transport.py``):

  client -> server:
    ``psi_hello``         group/mode/n_items/chunk_size/nb + a 16-byte
                          ``blind_tag`` (sha256 prefix of the packed
                          blinded set) the server uses to skip a
                          re-upload it has already seen.
    ``psi_blind_chunk``   packed A_i = H(x_i)^α, ``seq`` = chunk index,
                          ``base`` = element offset.  All chunks are
                          sent without waiting: chunk k+1 rides the wire
                          while the server exponentiates chunk k.
    ``psi_stop``          shuts the actor down.

  server -> client:
    ``psi_hello_ack``       blind_cached flag + server-set leg geometry
                            (chunk count, or bloom shard parameters).
    ``psi_server_set_chunk``packed { H(y_j)^β } (noinv; deduplicated +
                            secret-shuffled before it leaves).
    ``psi_bloom_shard``     one ShardedBloom shard bitmap (bloom).
    ``psi_double_chunk``    packed B_i = A_i^β, mirrors the blind seq.
    ``psi_done``            end-of-round marker (chunk count echoed).

Ordering: within each kind, chunks are strictly sequential (``seq`` is
verified on both sides — a reordered or dropped chunk fails loudly with
a "PSI protocol desync" error, never a silently wrong intersection).
*Across* kinds the client tolerates any interleaving via the endpoint's
``recv_kind`` stash, which is what lets the server's double-blind
responses overtake its own server-set stream under latency.

The blinded upload is memoized at both levels: the client computes the
packed blind once per session (PR 4 behavior, reused against every
owner), and each server actor caches the uploaded bytes by
``blind_tag`` — a repeat round with the same owner transfers **zero**
``psi_blind_chunk`` bytes (asserted on measured channel stats in the
tests and the ``BENCH_psi.json`` wire gate).

Bit-identity: the chunk kernels are the exact per-chunk compute of the
in-process engine (``psi_round``), so for any (mode, chunk_size,
parallelism, latency) the intersection list — order, duplicates and all
— equals the in-process result (property-tested).
"""
from __future__ import annotations

import hashlib
import queue as _queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bloom import BloomFilter, ShardedBloom
from repro.core.modexp import ModexpPool, pow_chunk
from repro.core.psi import (DEFAULT_CHUNK, PSIClient, PSIServer,
                            _chunk_slices)

__all__ = ["PSIServerEndpoint", "wire_psi_round", "serve_psi",
           "WIRE_KINDS", "CLIENT_KINDS", "SERVER_KINDS", "blind_tag"]

#: scientist -> owner message kinds
CLIENT_KINDS = ("psi_hello", "psi_blind_chunk", "psi_stop")
#: owner -> scientist message kinds
SERVER_KINDS = ("psi_hello_ack", "psi_server_set_chunk", "psi_bloom_shard",
                "psi_double_chunk", "psi_done")
WIRE_KINDS = CLIENT_KINDS + SERVER_KINDS

#: recv poll granularity / default round deadline (mirrors the split
#: loop's owner-crash surfacing: a dead actor raises within ~1 s)
POLL_S = 1.0
DEFAULT_TIMEOUT_S = 120.0


def _u8(blob: bytes) -> np.ndarray:
    """Zero-copy uint8 view of a packed byte blob (the frame payload)."""
    return np.frombuffer(blob, np.uint8)


def _scalar(x) -> int:
    """Payload scalar -> int.  Scalars ride the wire as shape-(1,)
    arrays (``ascontiguousarray`` promotes 0-d), so plain ``int()`` is
    deprecated on them."""
    return int(np.asarray(x).reshape(-1)[0])


def blind_tag(blinded_packed: bytes) -> bytes:
    """16-byte content tag of the packed blinded upload.  Derived from
    already-blinded group elements, so it reveals nothing the upload
    itself doesn't; equal uploads get equal tags, which is what lets a
    server skip a byte-identical re-upload."""
    return hashlib.sha256(blinded_packed).digest()[:16]


def _desync(kind: str, got, want) -> RuntimeError:
    return RuntimeError(
        f"PSI protocol desync: {kind} seq {got} != expected {want}")


class PSIServerEndpoint:
    """A data owner's PSI actor: one thread, one transport endpoint, one
    :class:`PSIServer`.  Persistent across rounds — β-side memoization
    (blinded own set / sharded bloom) and the client-upload cache live
    as long as the actor, so repeat rounds get cheaper in both compute
    and bytes.

    ``handle`` processes one inbox message and returns False on
    ``psi_stop``; ``run`` is the thread target, parking any exception in
    ``self.error`` for the scientist's receive poll to surface (the
    owner-crash contract split training established)."""

    def __init__(self, name: str, server: PSIServer, endpoint, *,
                 chunk_kernel_pool: Optional[ModexpPool] = None,
                 blind_cache: Optional[Dict[bytes, bytes]] = None):
        self.name = name
        self.server = server
        self.endpoint = endpoint
        self.pool = chunk_kernel_pool or ModexpPool(0)
        self.error: Optional[BaseException] = None
        self.rounds_served = 0
        # client-upload cache by content tag; an owner passes its own
        # dict here so the byte saving survives actor re-creation
        self._blind_cache = blind_cache if blind_cache is not None else {}
        self._pending: Optional[dict] = None

    # -- per-message protocol ----------------------------------------------
    def handle(self, msg) -> bool:
        if msg.kind == "psi_stop":
            return False
        if msg.kind == "psi_hello":
            self._on_hello(msg)
            return True
        if msg.kind == "psi_blind_chunk":
            self._on_blind_chunk(msg)
            return True
        if msg.kind == "heartbeat":
            # liveness probe (federation/supervisor.py)
            self.endpoint.send("heartbeat_ack", {}, seq=msg.seq)
            return True
        raise RuntimeError(
            f"PSI owner {self.name}: unknown message kind {msg.kind!r}")

    def _on_hello(self, msg) -> None:
        pl = msg.payload
        mode = bytes(pl["mode"]).decode()
        group = bytes(pl["group"]).decode()
        srv = self.server
        if group != srv.group:
            raise RuntimeError(f"PSI group mismatch: client {group!r} "
                               f"!= owner {self.name} {srv.group!r}")
        if mode not in ("noinv", "bloom"):
            raise RuntimeError(f"unknown PSI mode {mode!r}")
        nb = srv._nb
        if _scalar(pl["nb"]) != nb:
            raise RuntimeError(f"PSI element width mismatch: client "
                               f"{_scalar(pl['nb'])} != owner {nb}")
        n_items = _scalar(pl["n_items"])
        chunk_size = _scalar(pl["chunk_size"])
        if chunk_size <= 0:
            raise RuntimeError(f"chunk_size must be positive: {chunk_size}")
        tag = bytes(pl["blind_tag"].tobytes())
        cached = self._blind_cache.get(tag)
        ep = self.endpoint

        # ack + the server-set leg (variant-specific, streamed)
        ack = {"blind_cached": np.uint8(cached is not None),
               "n_server_items": np.int64(len(srv.items))}
        if mode == "noinv":
            own = srv.own_blinded_packed(self.pool, chunk_size)
            cb = chunk_size * nb
            n_srv = -(-len(own) // cb) if own else 0
            ack["n_server_chunks"] = np.int64(n_srv)
            ep.send("psi_hello_ack", ack, seq=0)
            for k in range(n_srv):
                ep.send("psi_server_set_chunk",
                        {"data": _u8(own[k * cb:(k + 1) * cb]),
                         "base": np.int64(k * chunk_size)}, seq=k)
        else:
            bloom = srv.build_bloom(self.pool, chunk_size)
            ack["n_shards"] = np.int64(bloom.n_shards)
            ack["shard_n_bits"] = np.int64(bloom.shards[0].m)
            ack["shard_n_hashes"] = np.int64(bloom.shards[0].k)
            ep.send("psi_hello_ack", ack, seq=0)
            for k, frame in enumerate(bloom.shard_frames()):
                ep.send("psi_bloom_shard", {"data": _u8(frame)}, seq=k)

        n_chunks = -(-n_items // chunk_size) if n_items else 0
        if cached is not None:
            # the client skips its upload; replay the double-blind leg
            # from the cached bytes (β memoized on the PSIServer too)
            self._respond_all(cached, chunk_size)
        else:
            self._pending = {"tag": tag, "chunk_size": chunk_size,
                             "remaining": n_chunks, "next_seq": 0,
                             "parts": []}
            if n_chunks == 0:
                self._finish_upload()

    def _on_blind_chunk(self, msg) -> None:
        pend = self._pending
        if pend is None:
            raise RuntimeError("PSI protocol desync: blind chunk outside "
                               "an upload (no hello, or already done)")
        if int(msg.seq) != pend["next_seq"]:
            raise _desync("psi_blind_chunk", int(msg.seq),
                          pend["next_seq"])
        want_base = pend["next_seq"] * pend["chunk_size"]
        if _scalar(msg.payload["base"]) != want_base:
            raise _desync("psi_blind_chunk base", _scalar(msg.payload["base"]),
                          want_base)
        blob = msg.payload["data"].tobytes()
        self.endpoint.send("psi_double_chunk",
                           {"data": _u8(self.server.respond_chunk(blob)),
                            "base": np.int64(want_base)},
                           seq=pend["next_seq"])
        pend["parts"].append(blob)
        pend["next_seq"] += 1
        pend["remaining"] -= 1
        if pend["remaining"] == 0:
            self._finish_upload()

    def _finish_upload(self) -> None:
        pend, self._pending = self._pending, None
        self._blind_cache[pend["tag"]] = b"".join(pend["parts"])
        self.endpoint.send("psi_done",
                           {"n_chunks": np.int64(pend["next_seq"])},
                           seq=pend["next_seq"])
        self.rounds_served += 1

    def _respond_all(self, blob: bytes, chunk_size: int) -> None:
        nb = self.server._nb
        cb = chunk_size * nb
        n_chunks = -(-len(blob) // cb) if blob else 0
        for k in range(n_chunks):
            self.endpoint.send(
                "psi_double_chunk",
                {"data": _u8(self.server.respond_chunk(
                    blob[k * cb:(k + 1) * cb])),
                 "base": np.int64(k * chunk_size)}, seq=k)
        self.endpoint.send("psi_done", {"n_chunks": np.int64(n_chunks)},
                           seq=n_chunks)
        self.rounds_served += 1

    # -- thread target -----------------------------------------------------
    def run(self) -> None:
        try:
            while self.handle(self.endpoint.recv()):
                pass
        except BaseException as e:          # noqa: BLE001 — surfaced by
            self.error = e                  # the client's recv poll


def _recv_kind(ep, kind: str, worker: Optional[PSIServerEndpoint],
               timeout: float):
    """Receive the next ``kind`` message, surfacing a dead owner actor
    within ~1 s (short poll) instead of after the full timeout."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            return ep.recv_kind(kind, timeout=POLL_S)
        except _queue.Empty:
            if worker is not None and worker.error is not None:
                raise RuntimeError(
                    f"PSI owner worker {worker.name!r} failed"
                ) from worker.error
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"timed out waiting for {kind!r}"
                    + (f" from {worker.name!r}" if worker else ""))


def wire_psi_round(client: PSIClient, ep, *,
                   worker: Optional[PSIServerEndpoint] = None,
                   pool: Optional[ModexpPool] = None,
                   chunk_size: int = DEFAULT_CHUNK,
                   timeout: float = DEFAULT_TIMEOUT_S
                   ) -> Tuple[List[str], dict]:
    """One full PSI round driven from the scientist's endpoint ``ep``.

    Pipelining: the memoized blinded upload goes out in one burst (chunk
    k+1 is on the wire while the server exponentiates chunk k), then the
    server's two response streams are consumed as they arrive, with the
    client chunk kernels running through ``pool.imap`` so client-side
    lifting overlaps both the wire and the server's thread.  Wall-clock
    under injected one-way latency L is therefore ``compute + O(L)``,
    not ``n_chunks * 2L + compute`` (gated in ``BENCH_psi.json``).

    Returns ``(intersection, stats)`` — the intersection is bit-identical
    to the in-process ``psi_round`` for the same party item lists, and
    ``stats`` carries the same protocol-byte keys plus the wire flags
    (``upload_skipped``)."""
    pool = pool or ModexpPool(0)
    nb, p = client._nb, client._p
    n_items = len(client.items)
    n_chunks = -(-n_items // chunk_size) if n_items else 0
    blind_was_cached = client._blinded_packed is not None
    blinded = client.blind_packed(pool, chunk_size)

    ep.send("psi_hello", {
        "mode": _u8(client.mode.encode()),
        "group": _u8(client.group.encode()),
        "blind_tag": _u8(blind_tag(blinded)),
        "n_items": np.int64(n_items),
        "chunk_size": np.int64(chunk_size),
        "nb": np.int64(nb),
    }, seq=0)
    ack = _recv_kind(ep, "psi_hello_ack", worker, timeout)
    upload_skipped = bool(_scalar(ack.payload["blind_cached"]))
    n_server_items = _scalar(ack.payload["n_server_items"])

    if not upload_skipped:
        for k, (lo, hi) in enumerate(_chunk_slices(n_items, chunk_size)):
            ep.send("psi_blind_chunk",
                    {"data": _u8(blinded[lo * nb:hi * nb]),
                     "base": np.int64(lo)}, seq=k)

    stats = {
        "mode": client.mode,
        "client_upload_bytes": len(blinded),
        "blind_cached": blind_was_cached,
        "upload_skipped": upload_skipped,
        "chunk_size": chunk_size,
        "n_chunks": max(1, n_chunks),
        "peak_inflight_elements": min(n_items, chunk_size * pool.inflight),
        "parallelism": pool.parallelism if pool.is_parallel else 0,
        "uncompressed_server_set_bytes": nb * n_server_items,
    }

    if client.mode == "noinv":
        # server-set stream, lifted to the double-blinded domain as it
        # arrives (imap: receive / lift / server-respond all overlap)
        n_srv = _scalar(ack.payload["n_server_chunks"])

        def _srv_chunks():
            for k in range(n_srv):
                m = _recv_kind(ep, "psi_server_set_chunk", worker, timeout)
                if int(m.seq) != k:
                    raise _desync("psi_server_set_chunk", int(m.seq), k)
                yield (m.payload["data"].tobytes(), client._blind_exp,
                       p, nb)

        t_blob = b"".join(pool.imap(pow_chunk, _srv_chunks()))

        d_parts: List[bytes] = []
        for k in range(n_chunks):
            m = _recv_kind(ep, "psi_double_chunk", worker, timeout)
            if int(m.seq) != k:
                raise _desync("psi_double_chunk", int(m.seq), k)
            d_parts.append(m.payload["data"].tobytes())
        d_blob = b"".join(d_parts)
        inter = client.match_double_blinded(d_blob, t_blob)
        stats["server_set_bytes"] = len(t_blob)
        stats["server_response_bytes"] = len(d_blob) + len(t_blob)
    else:
        n_shards = _scalar(ack.payload["n_shards"])
        m_bits = _scalar(ack.payload["shard_n_bits"])
        k_hashes = _scalar(ack.payload["shard_n_hashes"])
        shards = []
        for k in range(n_shards):
            m = _recv_kind(ep, "psi_bloom_shard", worker, timeout)
            if int(m.seq) != k:
                raise _desync("psi_bloom_shard", int(m.seq), k)
            shards.append(BloomFilter.from_bytes(
                m.payload["data"].tobytes(), m_bits, k_hashes))
        bloom = ShardedBloom(shards) if shards else None

        bases: List[int] = []

        def _dbl_chunks():
            for k in range(n_chunks):
                m = _recv_kind(ep, "psi_double_chunk", worker, timeout)
                if int(m.seq) != k:
                    raise _desync("psi_double_chunk", int(m.seq), k)
                bases.append(_scalar(m.payload["base"]))
                yield (m.payload["data"].tobytes(), client.unblind_exp,
                       p, nb)

        inter = []
        for unb in pool.imap(pow_chunk, _dbl_chunks()):
            inter.extend(client.match_bloom_chunk(unb, bloom,
                                                  bases.pop(0)))
        stats["bloom_bytes"] = bloom.nbytes() if bloom else 0
        stats["bloom_shards"] = n_shards
        stats["server_response_bytes"] = (len(blinded)
                                          + stats["bloom_bytes"])

    done = _recv_kind(ep, "psi_done", worker, timeout)
    if _scalar(done.payload["n_chunks"]) != n_chunks:
        raise _desync("psi_done n_chunks",
                      _scalar(done.payload["n_chunks"]), n_chunks)
    return inter, stats


def serve_psi(name: str, server: PSIServer, endpoint
              ) -> Tuple[PSIServerEndpoint, threading.Thread]:
    """Spawn a PSI server actor on its own daemon thread (the owner-side
    analogue of the split loop's worker threads).  Returns
    ``(worker, thread)``; send ``psi_stop`` on the peer endpoint and
    join to shut down."""
    worker = PSIServerEndpoint(name, server, endpoint)
    th = threading.Thread(target=worker.run, daemon=True,
                          name=f"psi-{name}")
    th.start()
    return worker, th
