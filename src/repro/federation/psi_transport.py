"""Wire-native PSI — entity resolution over the transport layer.

Until this module existed, ``session.resolve`` ran the PSI rounds as
direct Python calls between the party objects (``core/psi.py``): correct
and streamed, but nothing actually *crossed* the party boundary the way
training and serving traffic does.  This module frames every leg of both
protocol variants as :class:`~repro.federation.transport.Message` s over
a ``channel_pair``, so the full lifecycle (resolve -> fit -> serve) runs
over the same measured wire: byte counts come from serialized frames,
latency injection applies to every chunk, and tests can assert privacy
properties on the *observed traffic* rather than on code structure.

Cast:

  * :class:`PSIServerEndpoint` — the data owner's actor.  Runs on its
    own thread (the resolve analogue of ``parties.OwnerComputeEndpoint``)
    holding a :class:`~repro.core.psi.PSIServer`; everything it does is a
    reaction to inbox messages, and a crash surfaces on the scientist's
    side through the same short-poll pattern split training uses.
  * :func:`wire_psi_round` — the data scientist's driver.  Sends the
    hello + blinded upload, then consumes the server's legs as they
    arrive, feeding each chunk's lift/unblind ``pow_chunk`` task through
    a ``ModexpPool`` so receive, compute, and the server's own modexp
    work all overlap.

Protocol (kinds in ``WIRE_KINDS``; frame layouts golden-tested in
``tests/test_psi_transport.py``):

  client -> server:
    ``psi_hello``         group/mode/n_items/chunk_size/nb + three
                          16-byte content tags: ``blind_tag`` (packed
                          blinded upload — lets the server skip a
                          re-upload it has seen), ``base_tag`` (the
                          cached base a delta splices against; zeros =
                          no delta offered), ``server_tag`` (the
                          response leg the client already holds; zeros
                          = none) and a ``have_resp`` flag (the client
                          holds the full match artifacts for this
                          (blind_tag, server_tag) pair).
    ``psi_blind_chunk``   packed A_i = H(x_i)^α, ``seq`` = chunk index,
                          ``base`` = element offset.  All chunks are
                          sent without waiting: chunk k+1 rides the wire
                          while the server exponentiates chunk k.
    ``psi_delta_chunk``   the O(Δ) upload: removal tombstones (positions
                          into the cached base upload) + the packed
                          blinded *added* elements.  The server splices
                          its cached copy and verifies the result
                          against ``blind_tag`` — a stale or corrupt
                          base fails loudly, never silently misaligns.
    ``psi_lift_chunk``    hidden mode only: the server's own set lifted
                          into the double-blinded domain by the client,
                          returned so the *owner* can match.
    ``psi_stop``          shuts the actor down.

  server -> client:
    ``psi_hello_ack``       blind_cached/delta_ok/server_cached flags,
                            the current response-leg ``server_tag``, and
                            the leg geometry (chunk count, or bloom
                            shard parameters).
    ``psi_server_set_chunk``packed { H(y_j)^β } (noinv/hidden;
                            deduplicated + secret-shuffled before it
                            leaves).  Skipped when ``server_cached``.
    ``psi_bloom_shard``     one ShardedBloom shard bitmap (bloom).
                            Skipped when ``server_cached``.
    ``psi_double_chunk``    packed B_i = A_i^β, mirrors the blind seq
                            (noinv/bloom; never sent in hidden mode —
                            the products stay with the owner).
    ``psi_delta_ack``       the O(Δ) response: double-blinds of the
                            added elements only (empty in hidden mode).
    ``psi_keep_mask``       hidden mode: the padded keep-set — sorted
                            client positions (members + deterministic
                            decoys, padded to a quantum) and the owner
                            row each aligns to.  No frame distinguishes
                            a member entry from a decoy entry.
    ``psi_done``            end-of-round marker: double-chunk count +
                            the server's modexp-op count for the round.

Ordering: within each kind, chunks are strictly sequential (``seq`` is
verified on both sides — a reordered or dropped chunk fails loudly with
a "PSI protocol desync" error, never a silently wrong intersection).
*Across* kinds the client tolerates any interleaving via the endpoint's
``recv_kind`` stash, which is what lets the server's double-blind
responses overtake its own server-set stream under latency.

Caching — every heavy leg is memoized by content tag, so a repeat round
with an unchanged population is **O(hello) wire bytes and zero modexp**:

  * blinded upload: computed once per client session, cached by the
    server under ``blind_tag`` (PR 5) — repeat rounds ship zero
    ``psi_blind_chunk`` bytes;
  * response leg: the client caches the server set / bloom under
    ``server_tag`` and advertises it, so an unchanged owner never
    re-ships ``psi_server_set_chunk``/``psi_bloom_shard`` bytes;
  * double-blind leg: the server keeps a response cache keyed by upload
    tag (and, in hidden mode, a lift cache keyed by its leg tag); with
    ``have_resp`` the whole leg is skipped.

After ±Δ churn (``PSIClient.update_items``) the round degrades to O(Δ):
one ``psi_delta_chunk`` / ``psi_delta_ack`` exchange, Δ modexp on each
side (exact-gated in ``BENCH_psi.json``'s ``delta_gate``).

Bit-identity: the chunk kernels are the exact per-chunk compute of the
in-process engine (``psi_round``), so for any (mode, chunk_size,
parallelism, latency) the intersection list — order, duplicates and all
— equals the in-process result (property-tested).
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bloom import BloomFilter, ShardedBloom
from repro.core.modexp import ModexpPool, pow_chunk
from repro.core.psi import (DEFAULT_CHUNK, MODES, PSIClient, PSIServer,
                            _chunk_slices, blind_tag)

__all__ = ["PSIServerEndpoint", "wire_psi_round", "serve_psi",
           "WIRE_KINDS", "CLIENT_KINDS", "SERVER_KINDS", "blind_tag"]

#: scientist -> owner message kinds
CLIENT_KINDS = ("psi_hello", "psi_blind_chunk", "psi_delta_chunk",
                "psi_lift_chunk", "psi_stop")
#: owner -> scientist message kinds
SERVER_KINDS = ("psi_hello_ack", "psi_server_set_chunk", "psi_bloom_shard",
                "psi_double_chunk", "psi_delta_ack", "psi_keep_mask",
                "psi_done")
WIRE_KINDS = CLIENT_KINDS + SERVER_KINDS

#: recv poll granularity / default round deadline (mirrors the split
#: loop's owner-crash surfacing: a dead actor raises within ~1 s)
POLL_S = 1.0
DEFAULT_TIMEOUT_S = 120.0

#: the all-zeros content tag: "I hold nothing" in the hello handshake
ZERO_TAG = b"\x00" * 16

#: per-tag cache bound (blind / response / lift caches): tags are
#: content-addressed, so old entries are only ever a byte saving — cap
#: them so churn cycles can't grow owner memory without bound
_CACHE_CAP = 8


def _u8(blob: bytes) -> np.ndarray:
    """Zero-copy uint8 view of a packed byte blob (the frame payload)."""
    return np.frombuffer(blob, np.uint8)


def _scalar(x) -> int:
    """Payload scalar -> int.  Scalars ride the wire as shape-(1,)
    arrays (``ascontiguousarray`` promotes 0-d), so plain ``int()`` is
    deprecated on them."""
    return int(np.asarray(x).reshape(-1)[0])


def _i64list(x) -> List[int]:
    """Payload int64 array -> list of python ints."""
    return [int(v) for v in np.asarray(x).reshape(-1)]


def _cache_put(cache: Dict[bytes, object], key: bytes, value,
               cap: int = _CACHE_CAP) -> None:
    cache[key] = value
    while len(cache) > cap:
        cache.pop(next(iter(cache)))


def _desync(kind: str, got, want) -> RuntimeError:
    return RuntimeError(
        f"PSI protocol desync: {kind} seq {got} != expected {want}")


class PSIServerEndpoint:
    """A data owner's PSI actor: one thread, one transport endpoint, one
    :class:`PSIServer`.  Persistent across rounds — β-side memoization
    (blinded own set / sharded bloom), the client-upload cache, the
    response-leg cache and (hidden mode) the lift cache live as long as
    the actor, so repeat rounds get cheaper in both compute and bytes.

    ``handle`` processes one inbox message and returns False on
    ``psi_stop``; ``run`` is the thread target, parking any exception in
    ``self.error`` for the scientist's receive poll to surface (the
    owner-crash contract split training established)."""

    def __init__(self, name: str, server: PSIServer, endpoint, *,
                 chunk_kernel_pool: Optional[ModexpPool] = None,
                 blind_cache: Optional[Dict[bytes, bytes]] = None,
                 resp_cache: Optional[Dict[bytes, bytes]] = None,
                 lift_cache: Optional[Dict[bytes, bytes]] = None):
        self.name = name
        self.server = server
        self.endpoint = endpoint
        self.pool = chunk_kernel_pool or ModexpPool(0)
        self.error: Optional[BaseException] = None
        self.rounds_served = 0
        # content-tag caches; an owner passes its own dicts here so the
        # byte/compute savings survive actor re-creation
        self._blind_cache = blind_cache if blind_cache is not None else {}
        self._resp_cache = resp_cache if resp_cache is not None else {}
        self._lift_cache = lift_cache if lift_cache is not None else {}
        self._round: Optional[dict] = None
        self._pending: Optional[dict] = None
        self._lift_pending: Optional[dict] = None

    # -- per-message protocol ----------------------------------------------
    def handle(self, msg) -> bool:
        if msg.kind == "psi_stop":
            return False
        if msg.kind == "psi_hello":
            self._on_hello(msg)
            return True
        if msg.kind == "psi_blind_chunk":
            self._on_blind_chunk(msg)
            return True
        if msg.kind == "psi_delta_chunk":
            self._on_delta_chunk(msg)
            return True
        if msg.kind == "psi_lift_chunk":
            self._on_lift_chunk(msg)
            return True
        if msg.kind == "heartbeat":
            # liveness probe (federation/supervisor.py)
            self.endpoint.send("heartbeat_ack", {}, seq=msg.seq)
            return True
        raise RuntimeError(
            f"PSI owner {self.name}: unknown message kind {msg.kind!r}")

    def _on_hello(self, msg) -> None:
        pl = msg.payload
        mode = bytes(pl["mode"]).decode()
        group = bytes(pl["group"]).decode()
        srv = self.server
        if group != srv.group:
            raise RuntimeError(f"PSI group mismatch: client {group!r} "
                               f"!= owner {self.name} {srv.group!r}")
        if mode not in MODES:
            raise RuntimeError(f"unknown PSI mode {mode!r}")
        nb = srv._nb
        if _scalar(pl["nb"]) != nb:
            raise RuntimeError(f"PSI element width mismatch: client "
                               f"{_scalar(pl['nb'])} != owner {nb}")
        n_items = _scalar(pl["n_items"])
        chunk_size = _scalar(pl["chunk_size"])
        if chunk_size <= 0:
            raise RuntimeError(f"chunk_size must be positive: {chunk_size}")
        tag = bytes(pl["blind_tag"].tobytes())
        base_tag = bytes(pl["base_tag"].tobytes())
        client_leg_tag = bytes(pl["server_tag"].tobytes())
        have_resp = bool(_scalar(pl["have_resp"]))
        ops0 = srv.ops
        cached = self._blind_cache.get(tag)
        # delta splice needs the cached base upload; hidden mode also
        # needs the base's double-blinds (they never went to the client)
        delta_ok = (cached is None and base_tag != ZERO_TAG
                    and base_tag in self._blind_cache
                    and (mode != "hidden"
                         or base_tag in self._resp_cache))
        leg_tag = srv.server_leg_tag(mode, self.pool, chunk_size)
        # the response leg can be skipped iff the client holds the
        # *current* leg (hidden mode additionally needs the lift of this
        # exact leg — the owner can't match without it)
        server_cached = (client_leg_tag == leg_tag
                         and (mode != "hidden"
                              or leg_tag in self._lift_cache))
        ep = self.endpoint

        ack = {"blind_cached": np.uint8(cached is not None),
               "delta_ok": np.uint8(delta_ok),
               "server_cached": np.uint8(server_cached),
               "server_tag": _u8(leg_tag),
               "n_server_items": np.int64(len(srv.items))}
        if mode == "bloom":
            bloom = srv.build_bloom(self.pool, chunk_size)
            ack["n_shards"] = np.int64(bloom.n_shards)
            ack["shard_n_bits"] = np.int64(bloom.shards[0].m)
            ack["shard_n_hashes"] = np.int64(bloom.shards[0].k)
            ep.send("psi_hello_ack", ack, seq=0)
            if not server_cached:
                for k, frame in enumerate(bloom.shard_frames()):
                    ep.send("psi_bloom_shard", {"data": _u8(frame)},
                            seq=k)
            n_srv = 0
        else:
            own = srv.own_blinded_packed(self.pool, chunk_size)
            cb = chunk_size * nb
            n_srv = -(-len(own) // cb) if own else 0
            ack["n_server_chunks"] = np.int64(n_srv)
            ep.send("psi_hello_ack", ack, seq=0)
            if not server_cached:
                for k in range(n_srv):
                    ep.send("psi_server_set_chunk",
                            {"data": _u8(own[k * cb:(k + 1) * cb]),
                             "base": np.int64(k * chunk_size)}, seq=k)

        self._round = {"mode": mode, "chunk_size": chunk_size,
                       "tag": tag, "leg_tag": leg_tag, "ops0": ops0,
                       "doubles": 0, "upload_done": False}
        if mode == "hidden":
            if server_cached:
                self._lift_pending = None
            else:
                self._lift_pending = {"remaining": n_srv, "next_seq": 0,
                                      "parts": []}
        else:
            self._lift_pending = None

        n_chunks = -(-n_items // chunk_size) if n_items else 0
        if cached is not None:
            self._pending = None
            # skip the whole double-blind leg when the client holds the
            # match artifacts for exactly this (upload, response leg)
            if mode == "hidden" or (have_resp
                                    and client_leg_tag == leg_tag):
                self._round["upload_done"] = True
            else:
                self._respond_all(tag, cached, chunk_size)
                self._round["upload_done"] = True
            self._maybe_finish()
        elif delta_ok:
            self._pending = {"kind": "delta", "tag": tag,
                             "base_tag": base_tag,
                             "chunk_size": chunk_size}
        else:
            self._pending = {"kind": "full", "tag": tag,
                             "chunk_size": chunk_size,
                             "remaining": n_chunks, "next_seq": 0,
                             "parts": [], "d_parts": []}
            if n_chunks == 0:
                self._finish_upload()

    def _on_blind_chunk(self, msg) -> None:
        pend = self._pending
        if pend is None or pend["kind"] != "full":
            raise RuntimeError("PSI protocol desync: blind chunk outside "
                               "an upload (no hello, or already done)")
        if int(msg.seq) != pend["next_seq"]:
            raise _desync("psi_blind_chunk", int(msg.seq),
                          pend["next_seq"])
        want_base = pend["next_seq"] * pend["chunk_size"]
        if _scalar(msg.payload["base"]) != want_base:
            raise _desync("psi_blind_chunk base", _scalar(msg.payload["base"]),
                          want_base)
        blob = msg.payload["data"].tobytes()
        double = self.server.respond_chunk(blob)
        if self._round["mode"] != "hidden":
            self.endpoint.send("psi_double_chunk",
                               {"data": _u8(double),
                                "base": np.int64(want_base)},
                               seq=pend["next_seq"])
            self._round["doubles"] += 1
        pend["parts"].append(blob)
        pend["d_parts"].append(double)
        pend["next_seq"] += 1
        pend["remaining"] -= 1
        if pend["remaining"] == 0:
            self._finish_upload()

    def _on_delta_chunk(self, msg) -> None:
        pend = self._pending
        if pend is None or pend["kind"] != "delta":
            raise RuntimeError("PSI protocol desync: delta chunk without "
                               "an acknowledged delta offer")
        if int(msg.seq) != 0:
            raise _desync("psi_delta_chunk", int(msg.seq), 0)
        srv = self.server
        nb = srv._nb
        base = self._blind_cache[pend["base_tag"]]
        rows = np.frombuffer(base, np.uint8).reshape(-1, nb)
        removed = _i64list(msg.payload["removed"])
        added = msg.payload["data"].tobytes()
        n_retained = _scalar(msg.payload["n_retained"])
        rem = set(removed)
        if len(rem) != len(removed) or any(
                r < 0 or r >= len(rows) for r in rem):
            raise RuntimeError("PSI delta: invalid removal tombstones")
        keep_idx = [i for i in range(len(rows)) if i not in rem]
        if len(keep_idx) != n_retained:
            raise _desync("psi_delta_chunk n_retained", n_retained,
                          len(keep_idx))
        kept = rows[keep_idx].tobytes() if keep_idx else b""
        new_blob = kept + added
        # integrity: the splice must reproduce the advertised upload —
        # a stale or corrupt base fails loudly here, never misaligns
        if blind_tag(new_blob) != pend["tag"]:
            raise RuntimeError(
                f"PSI owner {self.name}: delta splice does not match "
                f"blind_tag (stale base upload?)")
        _cache_put(self._blind_cache, pend["tag"], new_blob)
        d_added = srv.respond_chunk(added) if added else b""
        base_resp = self._resp_cache.get(pend["base_tag"])
        if base_resp is not None:
            rrows = np.frombuffer(base_resp, np.uint8).reshape(-1, nb)
            rkept = rrows[keep_idx].tobytes() if keep_idx else b""
            _cache_put(self._resp_cache, pend["tag"], rkept + d_added)
        mode = self._round["mode"]
        self.endpoint.send(
            "psi_delta_ack",
            {"data": _u8(b"" if mode == "hidden" else d_added),
             "n_total": np.int64(len(new_blob) // nb)}, seq=0)
        self._pending = None
        self._round["upload_done"] = True
        self._maybe_finish()

    def _on_lift_chunk(self, msg) -> None:
        lp = self._lift_pending
        if lp is None:
            raise RuntimeError("PSI protocol desync: lift chunk outside "
                               "a hidden-mode round")
        if int(msg.seq) != lp["next_seq"]:
            raise _desync("psi_lift_chunk", int(msg.seq), lp["next_seq"])
        lp["parts"].append(msg.payload["data"].tobytes())
        lp["next_seq"] += 1
        lp["remaining"] -= 1
        if lp["remaining"] == 0:
            self._maybe_finish()

    def _finish_upload(self) -> None:
        pend, self._pending = self._pending, None
        _cache_put(self._blind_cache, pend["tag"],
                   b"".join(pend["parts"]))
        _cache_put(self._resp_cache, pend["tag"],
                   b"".join(pend["d_parts"]))
        self._round["upload_done"] = True
        self._maybe_finish()

    def _respond_all(self, tag: bytes, blob: bytes,
                     chunk_size: int) -> None:
        """Replay the double-blind leg for a cached upload — from the
        response cache when possible (zero modexp), else recomputed and
        cached."""
        d_blob = self._resp_for(tag, blob, chunk_size)
        nb = self.server._nb
        cb = chunk_size * nb
        n_chunks = -(-len(d_blob) // cb) if d_blob else 0
        for k in range(n_chunks):
            self.endpoint.send(
                "psi_double_chunk",
                {"data": _u8(d_blob[k * cb:(k + 1) * cb]),
                 "base": np.int64(k * chunk_size)}, seq=k)
        self._round["doubles"] = n_chunks

    def _resp_for(self, tag: bytes, blob: bytes,
                  chunk_size: int) -> bytes:
        d_blob = self._resp_cache.get(tag)
        if d_blob is None:
            nb = self.server._nb
            cb = chunk_size * nb
            d_blob = b"".join(
                self.server.respond_chunk(blob[o:o + cb])
                for o in range(0, len(blob), cb))
            _cache_put(self._resp_cache, tag, d_blob)
        return d_blob

    def _maybe_finish(self) -> None:
        r = self._round
        if r is None or not r["upload_done"]:
            return
        if r["mode"] == "hidden":
            lp = self._lift_pending
            if lp is not None and lp["remaining"] > 0:
                return
            if lp is None:
                t_blob = self._lift_cache[r["leg_tag"]]
            else:
                t_blob = b"".join(lp["parts"])
                _cache_put(self._lift_cache, r["leg_tag"], t_blob)
                self._lift_pending = None
            srv = self.server
            blob = self._blind_cache[r["tag"]]
            d_blob = self._resp_for(r["tag"], blob, r["chunk_size"])
            keep, rows = srv.hidden_match(d_blob, t_blob)
            self.endpoint.send(
                "psi_keep_mask",
                {"keep": np.asarray(keep, np.int64),
                 "rows": np.asarray(rows, np.int64)}, seq=0)
        self.endpoint.send(
            "psi_done",
            {"n_chunks": np.int64(r["doubles"]),
             "modexp_ops": np.int64(self.server.ops - r["ops0"])},
            seq=r["doubles"])
        self._round = None
        self.rounds_served += 1

    # -- thread target -----------------------------------------------------
    def run(self) -> None:
        try:
            while self.handle(self.endpoint.recv()):
                pass
        except BaseException as e:          # noqa: BLE001 — surfaced by
            self.error = e                  # the client's recv poll


def _recv_kind(ep, kind: str, worker: Optional[PSIServerEndpoint],
               timeout: float):
    """Receive the next ``kind`` message, surfacing a dead owner actor
    within ~1 s (short poll) instead of after the full timeout."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            return ep.recv_kind(kind, timeout=POLL_S)
        except _queue.Empty:
            if worker is not None and worker.error is not None:
                raise RuntimeError(
                    f"PSI owner worker {worker.name!r} failed"
                ) from worker.error
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"timed out waiting for {kind!r}"
                    + (f" from {worker.name!r}" if worker else ""))


def wire_psi_round(client: PSIClient, ep, *,
                   worker: Optional[PSIServerEndpoint] = None,
                   pool: Optional[ModexpPool] = None,
                   chunk_size: int = DEFAULT_CHUNK,
                   timeout: float = DEFAULT_TIMEOUT_S,
                   peer: Optional[str] = None
                   ) -> Tuple[List, dict]:
    """One full PSI round driven from the scientist's endpoint ``ep``.

    Pipelining: the memoized blinded upload goes out in one burst (chunk
    k+1 is on the wire while the server exponentiates chunk k), then the
    server's response streams are consumed as they arrive, with the
    client chunk kernels running through ``pool.imap`` so client-side
    lifting overlaps both the wire and the server's thread.  Wall-clock
    under injected one-way latency L is therefore ``compute + O(L)``,
    not ``n_chunks * 2L + compute`` (gated in ``BENCH_psi.json``).

    ``peer`` keys the client's per-owner round cache (defaults to the
    endpoint's peer name): on success the round's artifacts (response
    leg, double-blinds, intersection) are stored under it, which is what
    the repeat-round and delta fast paths splice against.  The cache is
    only written after a fully verified round — a crashed or desynced
    round leaves it untouched.

    Returns ``(intersection, stats)`` — for ``noinv``/``bloom`` the
    intersection is the item list, bit-identical to the in-process
    ``psi_round``; for ``hidden`` it is the padded keep-set of client
    row positions (``stats["hidden_rows"]`` maps each to an owner row).
    ``stats`` carries the in-process byte keys plus the wire flags
    (``upload_skipped``/``delta_used``/``resp_skipped``/
    ``server_leg_skipped``) and both sides' modexp-op counts."""
    pool = pool or ModexpPool(0)
    nb, p = client._nb, client._p
    n_items = len(client.items)
    n_chunks = -(-n_items // chunk_size) if n_items else 0
    blind_was_cached = client._blinded_packed is not None
    ops0 = client.ops
    blinded = client.blind_packed(pool, chunk_size)
    tag = blind_tag(blinded)
    peer = peer or getattr(ep, "peer", None) or "server"
    rc = client.round_cache.get(peer)
    delta = client._delta

    # offer the delta only when the splice actually applies: the advert
    # must match the current upload, and (noinv) the cached per-owner
    # double-blinds must be for the delta's base
    use_delta = (delta is not None and delta["tag"] == tag
                 and (client.mode == "hidden"
                      or (client.mode == "noinv" and rc is not None
                          and rc.get("tag") == delta["base_tag"])))
    # advertise the response leg we hold (with its artifacts)
    server_tag_known = ZERO_TAG
    if rc is not None and rc.get("server_tag"):
        if client.mode == "hidden" or (
                "t_blob" in rc if client.mode == "noinv"
                else "bloom" in rc):
            server_tag_known = rc["server_tag"]
    have_resp = bool(client.mode != "hidden" and rc is not None
                     and rc.get("tag") == tag
                     and server_tag_known != ZERO_TAG
                     and "inter" in rc)

    ep.send("psi_hello", {
        "mode": _u8(client.mode.encode()),
        "group": _u8(client.group.encode()),
        "blind_tag": _u8(tag),
        "base_tag": _u8(delta["base_tag"] if use_delta else ZERO_TAG),
        "server_tag": _u8(server_tag_known),
        "have_resp": np.uint8(have_resp),
        "n_items": np.int64(n_items),
        "chunk_size": np.int64(chunk_size),
        "nb": np.int64(nb),
    }, seq=0)
    ack = _recv_kind(ep, "psi_hello_ack", worker, timeout)
    upload_skipped = bool(_scalar(ack.payload["blind_cached"]))
    delta_used = bool(_scalar(ack.payload["delta_ok"]))
    server_leg_skipped = bool(_scalar(ack.payload["server_cached"]))
    leg_tag = bytes(ack.payload["server_tag"].tobytes())
    n_server_items = _scalar(ack.payload["n_server_items"])
    resp_skipped = bool(upload_skipped and have_resp
                        and server_tag_known == leg_tag
                        and client.mode != "hidden")

    if upload_skipped:
        pass
    elif delta_used:
        ep.send("psi_delta_chunk", {
            "data": _u8(delta["added_packed"]),
            "removed": np.asarray(delta["removed"], np.int64),
            "n_retained": np.int64(len(delta["retained"]))}, seq=0)
    else:
        for k, (lo, hi) in enumerate(_chunk_slices(n_items, chunk_size)):
            ep.send("psi_blind_chunk",
                    {"data": _u8(blinded[lo * nb:hi * nb]),
                     "base": np.int64(lo)}, seq=k)

    stats = {
        "mode": client.mode,
        "client_upload_bytes": len(blinded),
        "blind_cached": blind_was_cached,
        "upload_skipped": upload_skipped,
        "delta_used": delta_used,
        "resp_skipped": resp_skipped,
        "server_leg_skipped": server_leg_skipped,
        "chunk_size": chunk_size,
        "n_chunks": max(1, n_chunks),
        "peak_inflight_elements": min(n_items, chunk_size * pool.inflight),
        "parallelism": pool.parallelism if pool.is_parallel else 0,
        "uncompressed_server_set_bytes": nb * n_server_items,
    }
    entry: dict = {"tag": tag, "server_tag": leg_tag}

    def _recv_t_blob() -> bytes:
        """The server-set leg, lifted to the double-blinded domain as it
        arrives (imap: receive / lift / server-respond all overlap)."""
        n_srv = _scalar(ack.payload["n_server_chunks"])
        if server_leg_skipped:
            return rc["t_blob"]

        def _srv_chunks():
            for k in range(n_srv):
                m = _recv_kind(ep, "psi_server_set_chunk", worker,
                               timeout)
                if int(m.seq) != k:
                    raise _desync("psi_server_set_chunk", int(m.seq), k)
                yield (m.payload["data"].tobytes(), client._blind_exp,
                       p, nb)

        blob = b"".join(pool.imap(pow_chunk, _srv_chunks()))
        client.ops += len(blob) // nb
        return blob

    def _recv_doubles() -> bytes:
        if delta_used:
            m = _recv_kind(ep, "psi_delta_ack", worker, timeout)
            if int(m.seq) != 0:
                raise _desync("psi_delta_ack", int(m.seq), 0)
            d_added = m.payload["data"].tobytes()
            if _scalar(m.payload["n_total"]) != n_items:
                raise _desync("psi_delta_ack n_total",
                              _scalar(m.payload["n_total"]), n_items)
            rows = np.frombuffer(rc["d_blob"], np.uint8).reshape(-1, nb)
            kept = (rows[delta["retained"]].tobytes()
                    if delta["retained"] else b"")
            return kept + d_added
        d_parts: List[bytes] = []
        for k in range(n_chunks):
            m = _recv_kind(ep, "psi_double_chunk", worker, timeout)
            if int(m.seq) != k:
                raise _desync("psi_double_chunk", int(m.seq), k)
            d_parts.append(m.payload["data"].tobytes())
        return b"".join(d_parts)

    if client.mode == "noinv":
        t_blob = _recv_t_blob()
        if resp_skipped:
            d_blob, inter = rc["d_blob"], list(rc["inter"])
        else:
            d_blob = _recv_doubles()
            inter = client.match_double_blinded(d_blob, t_blob)
        entry.update(t_blob=t_blob, d_blob=d_blob, inter=list(inter))
        stats["server_set_bytes"] = len(t_blob)
        stats["server_response_bytes"] = len(d_blob) + len(t_blob)
        expected_doubles = (0 if (resp_skipped or delta_used)
                            else n_chunks)
    elif client.mode == "hidden":
        if server_leg_skipped:
            t_blob = rc.get("t_blob", b"")
        else:
            t_blob = _recv_t_blob()
            cb = chunk_size * nb
            for k, o in enumerate(range(0, len(t_blob), cb)):
                ep.send("psi_lift_chunk",
                        {"data": _u8(t_blob[o:o + cb]),
                         "base": np.int64(o // nb)}, seq=k)
        if delta_used:
            m = _recv_kind(ep, "psi_delta_ack", worker, timeout)
            if int(m.seq) != 0:
                raise _desync("psi_delta_ack", int(m.seq), 0)
        km = _recv_kind(ep, "psi_keep_mask", worker, timeout)
        if int(km.seq) != 0:
            raise _desync("psi_keep_mask", int(km.seq), 0)
        keep = _i64list(km.payload["keep"])
        rows = _i64list(km.payload["rows"])
        if len(keep) != len(rows):
            raise RuntimeError("PSI protocol desync: keep/rows length "
                               "mismatch in psi_keep_mask")
        inter = keep
        entry.update(keep=list(keep), rows=list(rows), t_blob=t_blob)
        stats["hidden_rows"] = rows
        stats["hidden_kept"] = len(keep)
        stats["server_set_bytes"] = len(t_blob)
        stats["server_response_bytes"] = len(t_blob) + 16 * len(keep)
        expected_doubles = 0
    else:
        if server_leg_skipped:
            bloom = rc["bloom"]
        else:
            n_shards = _scalar(ack.payload["n_shards"])
            m_bits = _scalar(ack.payload["shard_n_bits"])
            k_hashes = _scalar(ack.payload["shard_n_hashes"])
            shards = []
            for k in range(n_shards):
                m = _recv_kind(ep, "psi_bloom_shard", worker, timeout)
                if int(m.seq) != k:
                    raise _desync("psi_bloom_shard", int(m.seq), k)
                shards.append(BloomFilter.from_bytes(
                    m.payload["data"].tobytes(), m_bits, k_hashes))
            bloom = ShardedBloom(shards) if shards else None

        if resp_skipped:
            inter = list(rc["inter"])
        else:
            bases: List[int] = []

            def _dbl_chunks():
                for k in range(n_chunks):
                    m = _recv_kind(ep, "psi_double_chunk", worker,
                                   timeout)
                    if int(m.seq) != k:
                        raise _desync("psi_double_chunk", int(m.seq), k)
                    bases.append(_scalar(m.payload["base"]))
                    yield (m.payload["data"].tobytes(),
                           client.unblind_exp, p, nb)

            client.ops += 0 if n_chunks == 0 else n_items
            inter = []
            for unb in pool.imap(pow_chunk, _dbl_chunks()):
                inter.extend(client.match_bloom_chunk(unb, bloom,
                                                      bases.pop(0)))
        entry.update(bloom=bloom, inter=list(inter))
        stats["bloom_bytes"] = bloom.nbytes() if bloom else 0
        stats["bloom_shards"] = bloom.n_shards if bloom else 0
        stats["server_response_bytes"] = (len(blinded)
                                          + stats["bloom_bytes"])
        expected_doubles = 0 if resp_skipped else n_chunks

    done = _recv_kind(ep, "psi_done", worker, timeout)
    if _scalar(done.payload["n_chunks"]) != expected_doubles:
        raise _desync("psi_done n_chunks",
                      _scalar(done.payload["n_chunks"]), expected_doubles)
    stats["server_modexp_ops"] = _scalar(done.payload["modexp_ops"])
    stats["client_modexp_ops"] = client.ops - ops0
    stats["modexp_ops"] = (stats["client_modexp_ops"]
                           + stats["server_modexp_ops"])
    # round verified end-to-end: only now may the per-owner cache change
    client.round_cache[peer] = entry
    return inter, stats


def serve_psi(name: str, server: PSIServer, endpoint
              ) -> Tuple[PSIServerEndpoint, threading.Thread]:
    """Spawn a PSI server actor on its own daemon thread (the owner-side
    analogue of the split loop's worker threads).  Returns
    ``(worker, thread)``; send ``psi_stop`` on the peer endpoint and
    join to shut down."""
    worker = PSIServerEndpoint(name, server, endpoint)
    th = threading.Thread(target=worker.run, daemon=True,
                          name=f"psi-{name}")
    th.start()
    return worker, th
