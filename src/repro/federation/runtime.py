"""Party worker harness: one OS process per data owner.

``process_transport`` provides the boundary; this module provides the
*parties* on its far side.  Each worker is a spawned process (spawn, not
fork: the parent holds live XLA/threading state once jax is loaded, and
spawn re-imports only the target module's dependency chain) that builds
its party actor from a picklable spec and runs the exact same actor loop
the thread backend runs:

  * :func:`owner_worker_main` — rebuilds the owner's
    :class:`~repro.federation.parties.OwnerComputeEndpoint` inside the
    worker: the registry adapter is reconstructed from the (dataclass)
    model config, head programs re-jit in the worker's own XLA runtime,
    and the owner's current head params arrive as numpy leaves.  Only
    cut activations/gradients ever cross back.
  * :func:`psi_worker_main` — a jax-free
    :class:`~repro.federation.psi_transport.PSIServerEndpoint` actor
    (the PSI stack imports no jax, so these workers stay numpy-light).
  * :class:`WorkerHandle` — the parent-side view: the duplex
    :class:`~repro.federation.process_transport.ProcessEndpoint`, the
    ``Process``, and the crash-surfacing ``error`` property the
    session's receive polls check (poison-pill frame, or a nonzero exit
    code for deaths too sudden to send one).

Worker lifecycle (docs/WIRE_PROTOCOL.md §5): spawn -> warmup handshake
(driven by the session over the pipe, compiling every program before the
timed region) -> steady-state protocol -> ``stop`` / ``psi_stop`` ->
drain + exit 0.  A worker that throws ships one final
``__worker_error__`` frame with its traceback and exits 1.

Chaos hooks: ``REPRO_CHAOS_PARTY`` carries a ``federation.faults``
:class:`~repro.federation.faults.FaultPlan` (legacy single tokens like
``"<party>:crash_fwd"``, comma-separated multi-party specs, or a
``json:`` plan) injected inside the named workers.  Spawned children
inherit the parent's environment, so tests set it with
``monkeypatch.setenv`` — the only way to reach inside a spawned process
that a parent-side monkeypatch cannot touch.
"""
from __future__ import annotations

import traceback
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.federation import faults
from repro.federation.faults import CHAOS_ENV  # noqa: F401 — re-export
from repro.federation.process_transport import ProcessEndpoint

__all__ = ["OwnerWorkerSpec", "PSIWorkerSpec", "WorkerHandle",
           "owner_worker_main", "psi_worker_main",
           "spawn_owner_worker", "spawn_psi_worker", "CHAOS_ENV"]

SCIENTIST = "scientist"


def _chaos_action(name: str) -> Optional[str]:
    """Back-compat view of the env fault plan: the legacy action token
    (``crash_fwd`` / ``wedge_fwd`` / ``crash_psi`` / ``wedge_psi``) for
    ``name``, or ``None``.  Accepts comma-separated multi-party specs —
    the plan *is* the serialization now; this is just its one-token
    projection."""
    for f in faults.plan_from_env().for_party(name):
        key = (f.action, f.kind)
        if key in faults._LEGACY_INV:
            return faults._LEGACY_INV[key]
    return None


def _mp_context():
    import multiprocessing as mp
    return mp.get_context("spawn")


# ---------------------------------------------------------------------------
# Worker specs (picklable: dataclass configs + numpy arrays + scalars)
# ---------------------------------------------------------------------------


@dataclass
class OwnerWorkerSpec:
    """Everything a spawned owner worker needs to reconstruct its party.

    ``config`` is the registry model config (``MLPSplitConfig`` /
    ``ArchConfig`` — frozen dataclasses, cheap pickles); ``param_leaves``
    are the owner's current head-segment params flattened to numpy in
    canonical tree-leaf order (the worker rebuilds the tree against the
    structure of a reference slice from ``adapter.init``, so no treedef
    crosses the boundary)."""

    name: str
    ids: List[str]
    features: np.ndarray
    owner_index: int
    config: object
    init_seed: int
    param_leaves: List[np.ndarray] = field(default_factory=list)
    codec: Optional[str] = None
    microbatches: int = 1
    ack_steps: bool = False
    owner_lr: Optional[float] = None
    latency_s: float = 0.0
    bandwidth_bps: Optional[float] = None
    #: optimizer-state leaves for a respawn resuming mid-run (None: the
    #: worker initializes fresh state from its params, the PR 6 path)
    opt_state_leaves: Optional[List[np.ndarray]] = None
    #: the step counter to resume at (respawned workers must stage the
    #: replayed step's forwards, not step 0's)
    start_step: int = 0
    #: worker generation: 0 for first launch; respawns bump it, so
    #: generation-0 faults (the legacy default) don't re-fire
    generation: int = 0
    #: secure forward aggregation: "masked_sum" builds a
    #: ``core.masking.MaskedAggregator`` in the worker (root seed from
    #: the env channel ``REPRO_MASK_SEED``, default the init seed — the
    #: scientist-side spec never carries the root); None = plain cuts
    aggregation: Optional[str] = None
    #: total owner count — the mask cancellation set (>= 2 for masked)
    n_owners: int = 0
    #: owner-side Titcombe wire defence (deterministic, seeded on
    #: init_seed so replay after recovery re-derives identical noise)
    cut_noise_std: float = 0.0


@dataclass
class PSIWorkerSpec:
    """A PSI server actor's world: the owner's ID set + group geometry.
    Import chain is jax-free end to end.

    ``beta`` and the content-tag cache snapshots rehydrate the owner's
    persistent PSI state into the (otherwise stateless) spawned worker:
    in a real deployment the owner's process is long-lived, so a fresh
    worker per round must reproduce byte-identical response legs (same
    secret, same deterministic shuffle) and honor caches from earlier
    rounds — otherwise repeat resolves re-ship full legs."""

    name: str
    ids: List[str]
    group: str
    fp_rate: float = 1e-9
    latency_s: float = 0.0
    bandwidth_bps: Optional[float] = None
    generation: int = 0
    beta: Optional[int] = None
    blind_cache: Optional[dict] = None
    resp_cache: Optional[dict] = None
    lift_cache: Optional[dict] = None
    # precomputed response-side state (owner-side precompute, performed
    # on the owner's persistent PSIServer at spawn): packed blinded own
    # set, its shuffle->row map, and the per-item element cache
    own_packed: Optional[bytes] = None
    own_rows: Optional[List[int]] = None
    own_elems: Optional[dict] = None


# ---------------------------------------------------------------------------
# Worker mains (top-level functions: spawn pickles them by reference)
# ---------------------------------------------------------------------------


def _run_worker(spec, conn, body) -> None:
    """Shared worker scaffold: endpoint up, body, poison pill + exit 1
    on any failure, clean close + exit 0 otherwise.  (The exit code only
    makes sense process-side; the in-process thread harness just ends
    the thread after the pill ships.)"""
    import threading

    ep = ProcessEndpoint(spec.name, SCIENTIST, conn,
                         latency_s=spec.latency_s,
                         bandwidth_bps=spec.bandwidth_bps)
    # wire faults (drop/corrupt/delay) on everything this worker sends
    faults.arm_endpoint(ep, spec.name,
                        generation=getattr(spec, "generation", 0))
    try:
        body(spec, ep)
    except BaseException as e:              # noqa: BLE001 — shipped to
        ep.send_error(e, traceback.format_exc())   # the parent's poll
        ep.close()
        if threading.current_thread() is threading.main_thread():
            raise SystemExit(1)
        return
    ep.close()


def _owner_body(spec: OwnerWorkerSpec, ep: ProcessEndpoint) -> None:
    import jax

    from repro.federation.parties import DataOwner, OwnerComputeEndpoint
    from repro.federation.registry import build_adapter
    from repro.federation.transport import get_codec

    adapter = build_adapter(spec.config)
    p = spec.owner_index
    # reference slice for the param-tree structure only: init is
    # deterministic per (config, seed), so the structure — and, for a
    # fresh session, the values — match the parent's exactly
    template = adapter.owner_param_slice(
        adapter.init(jax.random.PRNGKey(spec.init_seed)), p)
    params = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template),
        [jax.numpy.asarray(leaf) for leaf in spec.param_leaves])
    owner = DataOwner(spec.name, spec.ids, spec.features)
    owner_opt, owner_update = adapter.owner_update_rule(spec.owner_lr)
    head_fwd, head_bwd = adapter.owner_programs(p)
    opt_state = None
    if spec.opt_state_leaves is not None:
        # a respawn resumes the snapshotted optimizer state verbatim
        opt_state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(owner_opt.init(params)),
            [jax.numpy.asarray(leaf) for leaf in spec.opt_state_leaves])
    masker = None
    if spec.aggregation == "masked_sum":
        from repro.core import masking
        masker = masking.MaskedAggregator(
            masking.mask_root_from_env(spec.init_seed), p, spec.n_owners,
            adapter.quant_program(), generation=spec.generation)
    worker = OwnerComputeEndpoint(
        owner, ep, head_fwd, head_bwd, optimizer=owner_opt,
        params=params, codec=get_codec(spec.codec),
        ack_steps=spec.ack_steps, microbatches=spec.microbatches,
        gather=adapter.gather_program(), update_program=owner_update,
        tail_program=adapter.owner_tail_rule(spec.owner_lr, p),
        opt_state=opt_state, start_step=spec.start_step,
        masker=masker, cut_noise_std=spec.cut_noise_std,
        noise_seed=spec.init_seed)
    _arm_chaos(worker, spec.name, generation=spec.generation)
    worker.run()
    if worker.error is not None:
        raise worker.error


def owner_worker_main(spec: OwnerWorkerSpec, conn) -> None:
    """Spawn target for an owner compute worker (also runnable on a
    thread against a pipe end — the in-process harness tests use that to
    exercise this exact code path under the tracer)."""
    _run_worker(spec, conn, _owner_body)


def _psi_body(spec: PSIWorkerSpec, ep: ProcessEndpoint) -> None:
    from repro.core.psi import PSIServer
    from repro.federation.psi_transport import PSIServerEndpoint

    server = PSIServer(spec.ids, spec.fp_rate, spec.group, beta=spec.beta)
    if spec.own_packed is not None:
        server._own_packed = spec.own_packed
        server._own_rows = list(spec.own_rows or [])
        server._own_elems = dict(spec.own_elems or {})
    actor = PSIServerEndpoint(spec.name, server, ep,
                              blind_cache=dict(spec.blind_cache or {}),
                              resp_cache=dict(spec.resp_cache or {}),
                              lift_cache=dict(spec.lift_cache or {}))
    _arm_chaos(actor, spec.name, generation=spec.generation)
    actor.run()
    if actor.error is not None:
        raise actor.error


def psi_worker_main(spec: PSIWorkerSpec, conn) -> None:
    """Spawn target for a PSI server actor (jax-free)."""
    _run_worker(spec, conn, _psi_body)


def _arm_chaos(actor, name: str, *, generation: int = 0) -> None:
    """Wrap ``actor.handle`` with the env fault plan's crash/wedge
    faults for ``name`` (kind targeting lives in the plan — an owner
    actor armed with a ``psi_blind_chunk`` fault simply never sees the
    kind, matching the old suffix dispatch)."""
    faults.arm_actor(actor, name, generation=generation)


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------


class WorkerHandle:
    """The scientist's view of one spawned party worker.

    Duck-types the interfaces the session's crash-surfacing polls
    already use: ``error`` (the thread actors' parked-exception slot),
    ``name``, and ``owner`` (the parent-side party object).  ``error``
    reads the poison pill off the endpoint when one arrived, else maps
    an unexpected nonzero/dead exit code to a ``RuntimeError``."""

    def __init__(self, name: str, proc, endpoint: ProcessEndpoint,
                 owner=None):
        self.name = name
        self.proc = proc
        self.endpoint = endpoint
        self.owner = owner

    @property
    def error(self) -> Optional[BaseException]:
        if self.endpoint.peer_error is not None:
            return self.endpoint.peer_error
        code = self.proc.exitcode
        if code not in (None, 0):
            return RuntimeError(
                f"party worker {self.name!r} exited with code {code}")
        return None

    def shutdown(self, timeout: float = 10.0) -> None:
        """Drain + join; escalate to terminate if the worker is stuck.
        Idempotent — safe in ``finally`` blocks."""
        self.proc.join(timeout=timeout)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=5.0)
        self.endpoint.close()

    def __repr__(self):
        state = ("alive" if self.proc.is_alive()
                 else f"exit={self.proc.exitcode}")
        return f"WorkerHandle({self.name!r}, {state})"


def _spawn(name: str, main, spec, *, owner=None, tap=None,
           dedup: bool = False) -> WorkerHandle:
    ctx = _mp_context()
    parent_conn, child_conn = ctx.Pipe(duplex=True)
    proc = ctx.Process(target=main, args=(spec, child_conn), daemon=True,
                       name=f"party-{name}")
    proc.start()
    child_conn.close()          # the child owns its end now
    ep = ProcessEndpoint(SCIENTIST, name, parent_conn,
                         latency_s=spec.latency_s,
                         bandwidth_bps=spec.bandwidth_bps, tap=tap,
                         dedup=dedup)
    return WorkerHandle(name, proc, ep, owner=owner)


def spawn_owner_worker(spec: OwnerWorkerSpec, *, owner=None, tap=None,
                       dedup: bool = False) -> WorkerHandle:
    """Spawn one owner compute worker; returns the parent-side handle
    (its ``endpoint`` is the scientist's end of the party boundary).
    ``dedup`` turns on seq-based duplicate drop on the parent's receive
    path — the supervised fit path uses it so a restarted worker's
    replayed frames are idempotent."""
    return _spawn(spec.name, owner_worker_main, spec, owner=owner,
                  tap=tap, dedup=dedup)


def spawn_psi_worker(owner, *, group: str, fp_rate: float = 1e-9,
                     latency_s: float = 0.0,
                     bandwidth_bps: Optional[float] = None,
                     tap=None, generation: int = 0,
                     pool=None) -> WorkerHandle:
    """Spawn one PSI server actor for ``owner`` (a
    :class:`~repro.federation.parties.DataOwner`).  ``generation``
    increments on retry, so generation-0 faults don't re-fire.

    The spec rehydrates the owner's persistent PSI state (β, blinded
    own set, content-tag caches) into the fresh worker — a stand-in for
    the long-lived owner process of a real deployment, and what keeps
    repeat/churned rounds O(Δ) on the process backend.  The own-set
    blinding runs on the owner's persistent server at spawn (``pool``
    parallelizes it), so respawns and retries never repeat it."""
    key = (group, fp_rate)
    srv = owner.psi_server(group, fp_rate)   # synced to the population
    srv.own_blinded_packed(pool)             # O(Δ new items) after churn
    spec = PSIWorkerSpec(name=owner.name, ids=list(srv.items),
                         group=group, fp_rate=fp_rate,
                         latency_s=latency_s, bandwidth_bps=bandwidth_bps,
                         generation=generation,
                         beta=srv._beta,
                         blind_cache=dict(
                             owner._psi_blind_caches.setdefault(key, {})),
                         resp_cache=dict(
                             owner._psi_resp_caches.setdefault(key, {})),
                         lift_cache=dict(
                             owner._psi_lift_caches.setdefault(key, {})),
                         own_packed=srv._own_packed,
                         own_rows=srv._own_rows,
                         own_elems=srv._own_elems)
    return _spawn(spec.name, psi_worker_main, spec, owner=owner, tap=tap)
