"""Party abstractions — the paper's cast of characters as objects.

PyVertical's contribution is an *API*: a data scientist trains on features
vertically partitioned across data owners **without ever touching raw
features**, and owners never see labels.  These classes make that
visibility contract structural:

  * :class:`DataOwner` holds ``(ids, features)``.  It has **no** label
    attribute of any kind, and its ``features`` property raises
    :class:`PrivacyError` — raw features are reachable only through the
    owner-side accessor ``_features`` used by ``federation/batching.py``
    and the session's owner-side assembly (the simulation analogue of code
    running on the owner's device).
  * :class:`DataScientist` holds ``(ids, labels)`` and nothing else: no
    feature array ever lands on the object.
  * Cross-party flows go through :class:`~repro.federation.session.
    VerticalSession`, which records every owner->scientist message in its
    ``transcript`` — tests assert the only payloads are PSI responses and
    cut-layer activations (claim C4).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.resolution import VerticalDataset
from repro.core.vertical import make_ids, partition_sequence


class PrivacyError(RuntimeError):
    """Raised when code crosses the party-visibility boundary."""


class DataOwner:
    """A data owner: a vertical slice of every shared subject's features.

    The owner participates in training by running its head segment and
    shipping only cut-layer activations; raw rows never leave.  ``ids``
    are public to the session for PSI (the protocol itself only reveals
    the intersection to the scientist)."""

    def __init__(self, name: str, ids: Sequence[str], features: np.ndarray):
        self.name = name
        self._vd = VerticalDataset(list(ids), np.asarray(features))

    # -- public (scientist-visible) surface --------------------------------
    @property
    def ids(self) -> List[str]:
        return self._vd.ids

    @property
    def n_rows(self) -> int:
        return len(self._vd.ids)

    @property
    def feature_shape(self) -> Tuple[int, ...]:
        """Per-row feature shape — metadata, not data."""
        return tuple(self._vd.data.shape[1:])

    @property
    def features(self):
        raise PrivacyError(
            f"raw features of {self.name!r} are private to the owner; "
            "only cut-layer activations cross the party boundary")

    def __repr__(self):
        return (f"DataOwner({self.name!r}, rows={self.n_rows}, "
                f"feature_shape={self.feature_shape})")

    # -- owner-side surface (runs 'on the owner's device') -----------------
    @property
    def _features(self) -> np.ndarray:
        return self._vd.data

    def _align(self, keep_ids: Sequence[str]) -> None:
        """Discard non-shared rows and sort by ID (paper §3.1)."""
        self._vd = self._vd.filter_and_sort(keep_ids)


class DataScientist:
    """The data scientist: subject ids + labels (``None`` for label-free
    workflows such as serving).  Holds no features, ever."""

    def __init__(self, ids: Sequence[str], labels: Optional[np.ndarray]):
        self._vd = VerticalDataset(
            list(ids),
            np.asarray(labels) if labels is not None
            else np.zeros(len(list(ids)), np.int32))
        self.has_labels = labels is not None

    @property
    def ids(self) -> List[str]:
        return self._vd.ids

    @property
    def labels(self) -> Optional[np.ndarray]:
        return self._vd.data if self.has_labels else None

    def __repr__(self):
        return (f"DataScientist(rows={len(self._vd.ids)}, "
                f"labels={self.has_labels})")

    def _align(self, keep_ids: Sequence[str]) -> None:
        self._vd = self._vd.filter_and_sort(keep_ids)


# ---------------------------------------------------------------------------
# Party constructors for the two standard vertical layouts
# ---------------------------------------------------------------------------


def feature_parties(scientist_ds: VerticalDataset,
                    owner_ds: Dict[str, VerticalDataset]
                    ) -> Tuple[DataScientist, List[DataOwner]]:
    """Wrap ``make_vertical_mnist_parties``-style datasets (scientist
    labels + per-owner feature slices) as party objects."""
    sci = DataScientist(scientist_ds.ids, scientist_ds.data)
    owners = [DataOwner(name, ds.ids, ds.data)
              for name, ds in owner_ds.items()]
    return sci, owners


def sequence_parties(tokens: np.ndarray, n_owners: int,
                     ids: Optional[Sequence[str]] = None,
                     with_labels: bool = True
                     ) -> Tuple[DataScientist, List[DataOwner]]:
    """Vertically partition token streams across sequence-slice owners.

    ``tokens``: (N, S+1) when ``with_labels`` (inputs ``[:, :-1]``, the
    scientist keeps next-token labels ``[:, 1:]``), else (N, S) raw
    contexts (serving: the scientist holds no labels).  Owner p receives
    the contiguous sequence slice [p*S/P, (p+1)*S/P) of every document."""
    tokens = np.asarray(tokens)
    if with_labels:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    else:
        inputs, labels = tokens, None
    ids = list(ids) if ids is not None else make_ids(len(tokens), "doc")
    slices = partition_sequence(inputs, n_owners)
    owners = [DataOwner(f"owner{p}", ids, slices[p])
              for p in range(n_owners)]
    return DataScientist(ids, labels), owners
