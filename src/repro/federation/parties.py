"""Party abstractions — the paper's cast of characters as objects.

PyVertical's contribution is an *API*: a data scientist trains on features
vertically partitioned across data owners **without ever touching raw
features**, and owners never see labels.  These classes make that
visibility contract structural:

  * :class:`DataOwner` holds ``(ids, features)``.  It has **no** label
    attribute of any kind, and its ``features`` property raises
    :class:`PrivacyError` — raw features are reachable only through the
    owner-side accessor ``_features`` used by ``federation/batching.py``
    and the session's owner-side assembly (the simulation analogue of code
    running on the owner's device).
  * :class:`DataScientist` holds ``(ids, labels)`` and nothing else: no
    feature array ever lands on the object.
  * Cross-party flows go through :class:`~repro.federation.session.
    VerticalSession`, which records every owner->scientist message in its
    ``transcript`` — tests assert the only payloads are PSI responses and
    cut-layer activations (claim C4).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.psi import DEFAULT_MODE, PSIClient, PSIServer
from repro.core.resolution import VerticalDataset
from repro.core.vertical import make_ids, partition_sequence
from repro.optim import apply_updates


class PrivacyError(RuntimeError):
    """Raised when code crosses the party-visibility boundary."""


class DataOwner:
    """A data owner: a vertical slice of every shared subject's features.

    The owner participates in training by running its head segment and
    shipping only cut-layer activations; raw rows never leave.  ``ids``
    are public to the session for PSI (the protocol itself only reveals
    the intersection to the scientist)."""

    def __init__(self, name: str, ids: Sequence[str], features: np.ndarray):
        self.name = name
        self._vd = VerticalDataset(list(ids), np.asarray(features))
        # the owner's FULL population: ``_vd`` becomes the aligned
        # training view after a resolve, but PSI always runs (and
        # re-runs) against the population — a repeat resolve must not
        # intersect against its own previous output
        self._full = self._vd
        self._psi_servers: Dict[tuple, PSIServer] = {}
        # content-tag caches (client uploads / double-blind responses /
        # hidden-mode lifts) — owned here so the byte and modexp savings
        # survive per-round actor re-creation AND population churn
        self._psi_blind_caches: Dict[tuple, dict] = {}
        self._psi_resp_caches: Dict[tuple, dict] = {}
        self._psi_lift_caches: Dict[tuple, dict] = {}

    # -- public (scientist-visible) surface --------------------------------
    @property
    def ids(self) -> List[str]:
        return self._vd.ids

    @property
    def n_rows(self) -> int:
        return len(self._vd.ids)

    @property
    def feature_shape(self) -> Tuple[int, ...]:
        """Per-row feature shape — metadata, not data."""
        return tuple(self._vd.data.shape[1:])

    @property
    def features(self):
        raise PrivacyError(
            f"raw features of {self.name!r} are private to the owner; "
            "only cut-layer activations cross the party boundary")

    def __repr__(self):
        return (f"DataOwner({self.name!r}, rows={self.n_rows}, "
                f"feature_shape={self.feature_shape})")

    def psi_server(self, group: str, fp_rate: float = 1e-9) -> PSIServer:
        """The owner's PSI endpoint, cached per (group, fp_rate): β and
        the per-element blinded own set are *persistent* state — a
        re-resolve after ±Δ row churn recomputes only the Δ new
        elements' exponentiations (``PSIServer.update_items``), not the
        whole set.  The accessor self-syncs against the owner's current
        rows, so callers never see a stale population."""
        key = (group, fp_rate)
        pop = self._full.ids
        srv = self._psi_servers.get(key)
        if srv is None:
            srv = self._psi_servers[key] = PSIServer(pop, fp_rate, group)
        elif srv.items != pop:
            srv.update_items(pop)
        return srv

    def psi_endpoint(self, endpoint, group: str, fp_rate: float = 1e-9,
                     pool=None):
        """The owner's wire-native PSI actor: wraps the cached
        :meth:`psi_server` state in a
        :class:`~repro.federation.psi_transport.PSIServerEndpoint`
        reacting to protocol messages on ``endpoint``.  The actor object
        is per-channel, but both memoization layers persist on the owner
        (β-side response state on the PSIServer, the client-upload byte
        cache in ``_psi_blind_caches``), so repeat rounds skip the
        blinded re-upload even across actor re-creation.  Invalidated
        when the owner's rows change (``_align``).  ``pool`` feeds the
        actor's own-set chunk kernels (executors are thread-safe, so the
        session shares one resolve pool across all parties)."""
        from repro.federation.psi_transport import PSIServerEndpoint
        key = (group, fp_rate)
        return PSIServerEndpoint(
            self.name, self.psi_server(group, fp_rate), endpoint,
            blind_cache=self._psi_blind_caches.setdefault(key, {}),
            resp_cache=self._psi_resp_caches.setdefault(key, {}),
            lift_cache=self._psi_lift_caches.setdefault(key, {}),
            chunk_kernel_pool=pool)

    def update_rows(self, ids: Sequence[str], features: np.ndarray
                    ) -> None:
        """Streaming-population update: replace the owner's rows in
        place.  PSI state is *kept* — the cached server re-syncs
        incrementally on the next resolve (O(Δ) new exponentiations for
        ±Δ churn), and the content-tag caches stay valid because they
        are keyed by content, never by session."""
        self._full = VerticalDataset(list(ids), np.asarray(features))
        self._vd = self._full

    # -- owner-side surface (runs 'on the owner's device') -----------------
    @property
    def _features(self) -> np.ndarray:
        return self._vd.data

    def _align(self, keep_ids: Sequence[str]) -> None:
        """Derive the aligned training view from the FULL population:
        discard non-shared rows and sort by ID (paper §3.1).  PSI state
        persists: the server accessor self-syncs to the population
        incrementally, and content-tag caches cannot go stale."""
        self._vd = self._full.filter_and_sort(keep_ids)

    def _align_hidden(self, rows: Sequence[int]) -> None:
        """Membership-hiding alignment: keep exactly ``rows`` (row
        indices into the full population, decoys included) in that
        order, and replace raw IDs with positional pseudonyms — the
        aligned order is the only cross-party coordinate system, so no
        party needs to know which raw IDs matched."""
        rows = list(rows)
        self._vd = VerticalDataset(
            [f"anon{k:06d}" for k in range(len(rows))],
            self._full.data[np.asarray(rows, np.int64)]
            if rows else self._full.data[:0])


class DataScientist:
    """The data scientist: subject ids + labels (``None`` for label-free
    workflows such as serving).  Holds no features, ever."""

    def __init__(self, ids: Sequence[str], labels: Optional[np.ndarray]):
        self._vd = VerticalDataset(
            list(ids),
            np.asarray(labels) if labels is not None
            else np.zeros(len(list(ids)), np.int32))
        self.has_labels = labels is not None
        # full population vs aligned view — see DataOwner._full
        self._full = self._vd
        self._psi_clients: Dict[tuple, PSIClient] = {}

    @property
    def ids(self) -> List[str]:
        return self._vd.ids

    @property
    def labels(self) -> Optional[np.ndarray]:
        return self._vd.data if self.has_labels else None

    def __repr__(self):
        return (f"DataScientist(rows={len(self._vd.ids)}, "
                f"labels={self.has_labels})")

    def psi_client(self, group: str, mode: str = DEFAULT_MODE,
                   pool=None) -> PSIClient:
        """The scientist's PSI endpoint, cached per (group, mode): its
        blinded upload is memoized on the client and reused against
        every owner round.  The accessor self-syncs against the
        scientist's current rows via ``PSIClient.update_items`` — after
        ±Δ churn the memoized upload is *spliced*, costing O(Δ) modexp
        and arming the wire delta fast path (``pool`` feeds the spliced
        elements' chunk kernels)."""
        key = (group, mode)
        pop = self._full.ids
        cli = self._psi_clients.get(key)
        if cli is None:
            cli = self._psi_clients[key] = PSIClient(pop, group, mode=mode)
        elif cli.items != pop:
            cli.update_items(pop, pool=pool)
        return cli

    def update_rows(self, ids: Sequence[str],
                    labels: Optional[np.ndarray]) -> None:
        """Streaming-population update: replace the scientist's rows in
        place.  Cached PSI clients re-sync incrementally on the next
        resolve (O(Δ) modexp + a delta upload for ±Δ churn)."""
        self._full = VerticalDataset(
            list(ids),
            np.asarray(labels) if labels is not None
            else np.zeros(len(list(ids)), np.int32))
        self._vd = self._full
        self.has_labels = labels is not None

    def _align(self, keep_ids: Sequence[str]) -> None:
        self._vd = self._full.filter_and_sort(keep_ids)

    def _align_hidden(self, positions: Sequence[int],
                      client_items: Sequence[str]) -> None:
        """Membership-hiding alignment: ``positions`` index the PSI
        client's item order (members + decoys, indistinguishable on the
        wire); map each back to the scientist's full-population row and
        adopt positional pseudonym IDs matching the owners'."""
        row_of = {it: i for i, it in enumerate(self._full.ids)}
        rows = [row_of[client_items[p]] for p in positions]
        self._vd = VerticalDataset(
            [f"anon{k:06d}" for k in range(len(rows))],
            self._full.data[np.asarray(rows, np.int64)]
            if rows else self._full.data[:0])


# ---------------------------------------------------------------------------
# Owner-side compute endpoint (true split execution)
# ---------------------------------------------------------------------------


class OwnerComputeEndpoint:
    """The compute that, in a real deployment, runs on the owner's device.

    Holds the owner's private feature slice (staged on device once — the
    per-step dispatch loop never blocks on a host transfer), its
    head-segment parameters, and its own optimizer state; everything else
    arrives as protocol messages on its
    :class:`~repro.federation.transport.Endpoint`:

      ``head_fwd``       (scientist -> owner): batch row indices, seq t.
                         The owner gathers ITS OWN rows on device, splits
                         them into ``microbatches`` chunks, and — once
                         every update through step t-1 is applied — runs
                         the jitted head forward per chunk, shipping each
                         codec-encoded cut chunk the moment it exists
                         (paper Fig. 2, arrow 5): up to M cut exchanges
                         in flight per channel.
      ``cut_gradients``  (scientist -> owner): the cut gradient for chunk
                         m of step t, seq ``t*M + m`` (arrow 7).  The
                         owner runs its explicit-VJP head backward for
                         that chunk immediately (hidden under the wire
                         for all but the last chunk), accumulates, and on
                         the step's final chunk applies its optimizer
                         update (arrow 8) — grads from every microbatch
                         are accumulated at step-start params before the
                         single update, so the math is the plain
                         full-batch step, GPipe-scheduled.
      ``warmup``         pre-training handshake: runs every jitted
                         program (gather, fwd/bwd per chunk shape, a
                         zero-gradient update, both codec directions) so
                         no XLA compile lands inside the timed training
                         region.  A zero gradient leaves params and
                         optimizer state bitwise unchanged.
      ``barrier``        flush marker; the owner acks once every prior
                         message is processed.
      ``pull_params``    the trusted-runtime param fetch: the owner
                         ships its current head-segment params as
                         numbered numpy leaves (``params_dump``).  The
                         thread backend reads ``self.params`` directly
                         (shared memory); across a process boundary this
                         message is the only way the session's
                         reassembly can see owner state.
      ``stop``           end of training.

    FIFO channel order is the protocol's only synchronization: every
    gradient chunk of step t precedes the forward execution for step
    t+1 (the t+1 ``head_fwd`` may *arrive* early — it is staged, not
    run, until the step-t update lands), so pipelined schedules stay
    mathematically exact.  ``run`` is the thread target; with compute
    released from the GIL (jitted programs), owner threads genuinely
    overlap the scientist's trunk.
    """

    def __init__(self, owner: DataOwner, endpoint, head_fwd, head_bwd, *,
                 optimizer, params, codec, ack_steps: bool = False,
                 microbatches: int = 1, gather=None, update_program=None,
                 tail_program=None, opt_state=None, start_step: int = 0,
                 masker=None, cut_noise_std: float = 0.0,
                 noise_seed: int = 0):
        import jax
        import jax.numpy as jnp

        self.owner = owner
        self.endpoint = endpoint
        self.head_fwd, self.head_bwd = head_fwd, head_bwd
        # secure forward aggregation: when set, every cut that ships is
        # quantized + ring-masked (core/masking.py) instead of
        # codec-encoded — an eavesdropper sees uniform ring elements
        self.masker = masker
        # owner-side Titcombe defence: deterministic Gaussian noise on
        # steady-state cuts BEFORE they ship (the joint path's
        # cut_noise_std analogue, but on the wire)
        self.cut_noise_std = float(cut_noise_std)
        self.noise_seed = int(noise_seed)
        self.opt = optimizer
        self.params = params
        # a respawned worker resumes snapshotted optimizer state and the
        # step counter it rolled back to; fresh endpoints init both
        self.opt_state = (optimizer.init(params) if opt_state is None
                          else opt_state)
        self.codec = codec
        self.ack_steps = ack_steps
        self.micro = int(microbatches)
        self.steps_done = int(start_step)
        self.error: Optional[BaseException] = None
        self._inflight: Dict[int, object] = {}   # seq -> owner-side inputs
        self._plan: Dict[int, list] = {}         # step -> staged fwd chunks
        self._grad_acc = None
        self._grads_seen = 0
        # step -> (np params, np opt_state): host copies (donated device
        # buffers get reused by later updates), kept for the supervised
        # fit's rollback protocol
        self._snaps: Dict[int, tuple] = {}

        if update_program is None:
            # one jitted program per segment op — update+apply compiled
            # together, the same fusion granularity as the joint train
            # step (required for bit-for-bit gradient equivalence);
            # params/state buffers are donated
            def _update(p, s, g, i):
                updates, s = optimizer.update(g, s, p, i)
                return apply_updates(p, updates), s

            update_program = jax.jit(_update, donate_argnums=(0, 1))
        self._update = update_program
        # fused bwd+update+fwd tail (one dispatch on the critical path);
        # None falls back to the separate programs
        self._tail = tail_program
        self._gather = gather or jax.jit(lambda feats, idx: feats[idx])
        self._feats = jnp.asarray(owner._features)   # device-staged, once

    # helpers --------------------------------------------------------------
    def _stage(self, idx) -> list:
        """Gather the step's rows on device and pre-slice the microbatch
        chunks (all off the latency-critical path)."""
        import jax.numpy as jnp
        x = self._gather(self._feats, jnp.asarray(np.asarray(idx)))
        if self.micro == 1:
            return [x]
        bm = x.shape[0] // self.micro
        return [x[m * bm:(m + 1) * bm] for m in range(self.micro)]

    def _ship_cut(self, out, seq: int, kind: str = "cut_activations"
                  ) -> None:
        # segment programs may return (cut, aux): the scalar owner-local
        # aux loss rides along for metric parity
        cut, aux = out if isinstance(out, tuple) else (out, None)
        if self.masker is not None:
            # masked-sum wire format: {"mq": uint32 ring element}.
            # Bypasses the codec — uniform ring bytes are incompressible
            # and already 4 bytes/element, the f32 it replaces.
            tag = (self.masker.step_tag(seq) if kind == "cut_activations"
                   else self.masker.warmup_tag(seq))
            payload = self.masker.encode(cut, tag)
        else:
            if self.cut_noise_std > 0.0 and kind == "cut_activations":
                from repro.core.privacy import deterministic_cut_noise
                cut = deterministic_cut_noise(
                    cut, self.cut_noise_std, self.noise_seed, f"s{seq}")
            payload = self.codec.encode(cut)
        if aux is not None:
            payload["aux"] = np.float32(np.asarray(aux).sum())
        self.endpoint.send(kind, payload, seq=seq)

    def _run_fwd(self, step: int, first_out=None) -> None:
        """Run + ship the microbatch forwards of ``step`` (params are
        already at step-start state by FIFO order).  ``first_out``:
        chunk 0's forward output when the fused tail program already
        produced it."""
        chunks = self._plan[step]
        start = 0
        if first_out is not None:
            self._inflight[step * self.micro] = chunks[0]
            self._ship_cut(first_out, step * self.micro)
            start = 1
        for m in range(start, len(chunks)):
            seq = step * self.micro + m
            self._inflight[seq] = chunks[m]
            self._ship_cut(self.head_fwd(self.params, chunks[m]), seq)
        del self._plan[step]

    def _warmup(self, msg) -> None:
        """Compile every program this endpoint will run, leaving params
        and optimizer state bitwise untouched (zero-gradient update)."""
        import jax
        import jax.numpy as jnp

        chunks = self._stage(msg.payload["idx"])
        for m, x in enumerate(chunks):
            self._ship_cut(self.head_fwd(self.params, x), m,
                           kind="warmup_cuts")
        acc = None
        gzero = None
        for m in range(len(chunks)):
            g = jnp.asarray(self.codec.decode(
                self.endpoint.recv_kind("warmup_grads").payload))
            gzero = g * 0.0
            grads = self.head_bwd(self.params, chunks[m], gzero)
            acc = grads if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, grads)
        self.params, self.opt_state = self._update(
            self.params, self.opt_state, acc, 0)
        if self._tail is not None:
            # compile the fused tail too — zero grads leave params and
            # state bitwise unchanged, matching its real call shape
            # (acc=None for single-chunk steps, a grads tree otherwise)
            tail_acc = None if self.micro == 1 else \
                jax.tree.map(lambda a: a * 0.0, acc)
            self.params, self.opt_state, _ = self._tail(
                self.params, self.opt_state, tail_acc, chunks[-1],
                gzero, 0, chunks[0])
        self.endpoint.send("warmup_done", {}, seq=msg.seq)

    # one message ----------------------------------------------------------
    def handle(self, msg) -> bool:
        """Process one protocol message; returns False on ``stop``."""
        if msg.kind == "stop":
            return False
        if msg.kind == "barrier":
            self.endpoint.send("barrier_ack", {}, seq=msg.seq)
            return True
        if msg.kind == "pull_params":
            import jax
            leaves = jax.tree_util.tree_leaves(self.params)
            self.endpoint.send(
                "params_dump",
                {str(i): np.asarray(leaf)
                 for i, leaf in enumerate(leaves)}, seq=msg.seq)
            return True
        if msg.kind == "warmup":
            self._warmup(msg)
            return True
        if msg.kind == "head_fwd":
            step = int(msg.seq)
            self._plan[step] = self._stage(msg.payload["idx"])
            if step == self.steps_done:
                # all updates through step-1 applied — run now; otherwise
                # the staged plan runs when the step-(t-1) update lands
                self._run_fwd(step)
            return True
        if msg.kind == "cut_gradients":
            import jax
            import jax.numpy as jnp
            seq = int(msg.seq)
            g = jnp.asarray(self.codec.decode(msg.payload))
            x = self._inflight.pop(seq)
            # grads accumulate at step-start params; ONE update per step
            # on its last chunk (GPipe semantics — the exact full-batch
            # step; with micro == 1 this degenerates to the one-shot
            # update)
            last = self._grads_seen + 1 == self.micro
            nxt = self.steps_done + 1
            if last and self._tail is not None and nxt in self._plan:
                # fused fast path: final-chunk bwd + accumulate + update
                # + next step's first forward, one compiled dispatch
                self.params, self.opt_state, out = self._tail(
                    self.params, self.opt_state, self._grad_acc, x, g,
                    self.steps_done, self._plan[nxt][0])
                self._grad_acc, self._grads_seen = None, 0
                self.steps_done = nxt
                self._run_fwd(nxt, out)
            else:
                grads = self.head_bwd(self.params, x, g)
                self._grad_acc = grads if self._grad_acc is None else \
                    jax.tree.map(lambda a, b: a + b, self._grad_acc,
                                 grads)
                self._grads_seen += 1
                if last:
                    self.params, self.opt_state = self._update(
                        self.params, self.opt_state, self._grad_acc,
                        self.steps_done)
                    self._grad_acc, self._grads_seen = None, 0
                    self.steps_done += 1
                    if self.steps_done in self._plan:
                        self._run_fwd(self.steps_done)
            if self.ack_steps:
                self.endpoint.send("step_done", {}, seq=seq)
            return True
        if msg.kind == "heartbeat":
            # liveness probe (federation/supervisor.py): answering
            # inline between protocol messages is exactly the signal —
            # a wedged actor stops answering
            self.endpoint.send("heartbeat_ack", {}, seq=msg.seq)
            return True
        if msg.kind == "snapshot":
            # step marker s: params/opt_state are at step-s-start state
            # by FIFO order.  Keep a host copy (device buffers are
            # donated by later updates) and ack it back with the leaves,
            # so the scientist can respawn this owner from step s.
            import jax
            s = int(msg.seq)
            snap = (jax.tree.map(lambda a: np.array(a), self.params),
                    jax.tree.map(lambda a: np.array(a), self.opt_state))
            self._snaps[s] = snap
            # keep the 4 newest markers (NOT a step-distance window:
            # with sparse resync the pipeline's FIFO lag still needs
            # the previous marker around for recovery)
            for old in sorted(self._snaps)[:-4]:
                del self._snaps[old]
            payload = {f"p{i}": leaf for i, leaf in
                       enumerate(jax.tree_util.tree_leaves(snap[0]))}
            payload.update(
                {f"o{i}": leaf for i, leaf in
                 enumerate(jax.tree_util.tree_leaves(snap[1]))})
            self.endpoint.send("snapshot_ack", payload, seq=s)
            return True
        if msg.kind == "rollback":
            # another party failed: restore step-s-start state, discard
            # every staged/in-flight chunk, and let the scientist replay
            # from s.  One update per step still holds — the replayed
            # step's update is the only one applied for it.
            import jax
            import jax.numpy as jnp
            s = int(msg.seq)
            if s not in self._snaps:
                raise RuntimeError(
                    f"owner {self.owner.name}: no snapshot for step {s}")
            p_np, o_np = self._snaps[s]
            self.params = jax.tree.map(jnp.asarray, p_np)
            self.opt_state = jax.tree.map(jnp.asarray, o_np)
            self._plan.clear()
            self._inflight.clear()
            self._grad_acc, self._grads_seen = None, 0
            self.steps_done = s
            self._snaps = {s: (p_np, o_np)}
            if hasattr(self.endpoint, "reset_dedup"):
                self.endpoint.reset_dedup()
            self.endpoint.send("rollback_ack", {}, seq=s)
            return True
        raise RuntimeError(
            f"owner {self.owner.name}: unknown message kind {msg.kind!r}")

    # thread target --------------------------------------------------------
    def run(self):
        try:
            while self.handle(self.endpoint.recv()):
                pass
        except BaseException as e:            # noqa: BLE001 — surfaced by
            self.error = e                    # the session's recv timeout


# ---------------------------------------------------------------------------
# Party constructors for the two standard vertical layouts
# ---------------------------------------------------------------------------


def feature_parties(scientist_ds: VerticalDataset,
                    owner_ds: Dict[str, VerticalDataset]
                    ) -> Tuple[DataScientist, List[DataOwner]]:
    """Wrap ``make_vertical_mnist_parties``-style datasets (scientist
    labels + per-owner feature slices) as party objects."""
    sci = DataScientist(scientist_ds.ids, scientist_ds.data)
    owners = [DataOwner(name, ds.ids, ds.data)
              for name, ds in owner_ds.items()]
    return sci, owners


def sequence_parties(tokens: np.ndarray, n_owners: int,
                     ids: Optional[Sequence[str]] = None,
                     with_labels: bool = True
                     ) -> Tuple[DataScientist, List[DataOwner]]:
    """Vertically partition token streams across sequence-slice owners.

    ``tokens``: (N, S+1) when ``with_labels`` (inputs ``[:, :-1]``, the
    scientist keeps next-token labels ``[:, 1:]``), else (N, S) raw
    contexts (serving: the scientist holds no labels).  Owner p receives
    the contiguous sequence slice [p*S/P, (p+1)*S/P) of every document."""
    tokens = np.asarray(tokens)
    if with_labels:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    else:
        inputs, labels = tokens, None
    ids = list(ids) if ids is not None else make_ids(len(tokens), "doc")
    slices = partition_sequence(inputs, n_owners)
    owners = [DataOwner(f"owner{p}", ids, slices[p])
              for p in range(n_owners)]
    return DataScientist(ids, labels), owners
