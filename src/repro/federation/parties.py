"""Party abstractions — the paper's cast of characters as objects.

PyVertical's contribution is an *API*: a data scientist trains on features
vertically partitioned across data owners **without ever touching raw
features**, and owners never see labels.  These classes make that
visibility contract structural:

  * :class:`DataOwner` holds ``(ids, features)``.  It has **no** label
    attribute of any kind, and its ``features`` property raises
    :class:`PrivacyError` — raw features are reachable only through the
    owner-side accessor ``_features`` used by ``federation/batching.py``
    and the session's owner-side assembly (the simulation analogue of code
    running on the owner's device).
  * :class:`DataScientist` holds ``(ids, labels)`` and nothing else: no
    feature array ever lands on the object.
  * Cross-party flows go through :class:`~repro.federation.session.
    VerticalSession`, which records every owner->scientist message in its
    ``transcript`` — tests assert the only payloads are PSI responses and
    cut-layer activations (claim C4).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.resolution import VerticalDataset
from repro.core.vertical import make_ids, partition_sequence
from repro.optim import apply_updates


class PrivacyError(RuntimeError):
    """Raised when code crosses the party-visibility boundary."""


class DataOwner:
    """A data owner: a vertical slice of every shared subject's features.

    The owner participates in training by running its head segment and
    shipping only cut-layer activations; raw rows never leave.  ``ids``
    are public to the session for PSI (the protocol itself only reveals
    the intersection to the scientist)."""

    def __init__(self, name: str, ids: Sequence[str], features: np.ndarray):
        self.name = name
        self._vd = VerticalDataset(list(ids), np.asarray(features))

    # -- public (scientist-visible) surface --------------------------------
    @property
    def ids(self) -> List[str]:
        return self._vd.ids

    @property
    def n_rows(self) -> int:
        return len(self._vd.ids)

    @property
    def feature_shape(self) -> Tuple[int, ...]:
        """Per-row feature shape — metadata, not data."""
        return tuple(self._vd.data.shape[1:])

    @property
    def features(self):
        raise PrivacyError(
            f"raw features of {self.name!r} are private to the owner; "
            "only cut-layer activations cross the party boundary")

    def __repr__(self):
        return (f"DataOwner({self.name!r}, rows={self.n_rows}, "
                f"feature_shape={self.feature_shape})")

    # -- owner-side surface (runs 'on the owner's device') -----------------
    @property
    def _features(self) -> np.ndarray:
        return self._vd.data

    def _align(self, keep_ids: Sequence[str]) -> None:
        """Discard non-shared rows and sort by ID (paper §3.1)."""
        self._vd = self._vd.filter_and_sort(keep_ids)


class DataScientist:
    """The data scientist: subject ids + labels (``None`` for label-free
    workflows such as serving).  Holds no features, ever."""

    def __init__(self, ids: Sequence[str], labels: Optional[np.ndarray]):
        self._vd = VerticalDataset(
            list(ids),
            np.asarray(labels) if labels is not None
            else np.zeros(len(list(ids)), np.int32))
        self.has_labels = labels is not None

    @property
    def ids(self) -> List[str]:
        return self._vd.ids

    @property
    def labels(self) -> Optional[np.ndarray]:
        return self._vd.data if self.has_labels else None

    def __repr__(self):
        return (f"DataScientist(rows={len(self._vd.ids)}, "
                f"labels={self.has_labels})")

    def _align(self, keep_ids: Sequence[str]) -> None:
        self._vd = self._vd.filter_and_sort(keep_ids)


# ---------------------------------------------------------------------------
# Owner-side compute endpoint (true split execution)
# ---------------------------------------------------------------------------


class OwnerComputeEndpoint:
    """The compute that, in a real deployment, runs on the owner's device.

    Holds the owner's private feature slice, its head-segment parameters,
    and its own optimizer state; everything else arrives as protocol
    messages on its :class:`~repro.federation.transport.Endpoint`:

      ``head_fwd``       (scientist -> owner): batch row indices, seq t.
                         The owner gathers ITS OWN rows, runs the jitted
                         head forward, and ships codec-encoded cut
                         activations back — the only data that ever
                         leaves (paper Fig. 2, arrow 5).
      ``cut_gradients``  (scientist -> owner): the cut gradient for seq t
                         (arrow 7).  The owner runs its explicit-VJP head
                         backward against the inputs it cached for t and
                         applies its own optimizer update (arrow 8).
      ``barrier``        flush marker; the owner acks once every prior
                         message is processed.
      ``stop``           end of training.

    FIFO channel order is the protocol's only synchronization: the
    gradient for step t always precedes the forward request for step
    t+1, so pipelined schedules stay mathematically exact.  ``run`` is
    the thread target; with compute released from the GIL (jitted
    programs), owner threads genuinely overlap the scientist's trunk.
    """

    def __init__(self, owner: DataOwner, endpoint, head_fwd, head_bwd, *,
                 optimizer, params, codec, ack_steps: bool = False):
        import jax

        self.owner = owner
        self.endpoint = endpoint
        self.head_fwd, self.head_bwd = head_fwd, head_bwd
        self.opt = optimizer
        self.params = params
        self.opt_state = optimizer.init(params)
        self.codec = codec
        self.ack_steps = ack_steps
        self.steps_done = 0
        self.error: Optional[BaseException] = None
        self._inflight: Dict[int, object] = {}   # seq -> owner-side inputs

        # one jitted program per segment op — update+apply compiled
        # together, the same fusion granularity as the joint train step
        # (required for bit-for-bit gradient equivalence)
        def _update(p, s, g, i):
            updates, s = optimizer.update(g, s, p, i)
            return apply_updates(p, updates), s

        self._update = jax.jit(_update)

    # one message ----------------------------------------------------------
    def handle(self, msg) -> bool:
        """Process one protocol message; returns False on ``stop``."""
        if msg.kind == "stop":
            return False
        if msg.kind == "barrier":
            self.endpoint.send("barrier_ack", {}, seq=msg.seq)
            return True
        if msg.kind == "head_fwd":
            import jax.numpy as jnp
            seq = int(msg.seq)
            x = jnp.asarray(self.owner._features[msg.payload["idx"]])
            self._inflight[seq] = x
            out = self.head_fwd(self.params, x)
            # segment programs may return (cut, aux): the scalar
            # owner-local aux loss rides along for metric parity
            cut, aux = out if isinstance(out, tuple) else (out, None)
            payload = self.codec.encode(np.asarray(cut))
            if aux is not None:
                payload["aux"] = np.float32(np.asarray(aux).sum())
            self.endpoint.send("cut_activations", payload, seq=seq)
            return True
        if msg.kind == "cut_gradients":
            import jax.numpy as jnp
            seq = int(msg.seq)
            g = jnp.asarray(self.codec.decode(msg.payload))
            x = self._inflight.pop(seq)
            grads = self.head_bwd(self.params, x, g)
            self.params, self.opt_state = self._update(
                self.params, self.opt_state, grads, self.steps_done)
            self.steps_done += 1
            if self.ack_steps:
                self.endpoint.send("step_done", {}, seq=seq)
            return True
        raise RuntimeError(
            f"owner {self.owner.name}: unknown message kind {msg.kind!r}")

    # thread target --------------------------------------------------------
    def run(self):
        try:
            while self.handle(self.endpoint.recv()):
                pass
        except BaseException as e:            # noqa: BLE001 — surfaced by
            self.error = e                    # the session's recv timeout


# ---------------------------------------------------------------------------
# Party constructors for the two standard vertical layouts
# ---------------------------------------------------------------------------


def feature_parties(scientist_ds: VerticalDataset,
                    owner_ds: Dict[str, VerticalDataset]
                    ) -> Tuple[DataScientist, List[DataOwner]]:
    """Wrap ``make_vertical_mnist_parties``-style datasets (scientist
    labels + per-owner feature slices) as party objects."""
    sci = DataScientist(scientist_ds.ids, scientist_ds.data)
    owners = [DataOwner(name, ds.ids, ds.data)
              for name, ds in owner_ds.items()]
    return sci, owners


def sequence_parties(tokens: np.ndarray, n_owners: int,
                     ids: Optional[Sequence[str]] = None,
                     with_labels: bool = True
                     ) -> Tuple[DataScientist, List[DataOwner]]:
    """Vertically partition token streams across sequence-slice owners.

    ``tokens``: (N, S+1) when ``with_labels`` (inputs ``[:, :-1]``, the
    scientist keeps next-token labels ``[:, 1:]``), else (N, S) raw
    contexts (serving: the scientist holds no labels).  Owner p receives
    the contiguous sequence slice [p*S/P, (p+1)*S/P) of every document."""
    tokens = np.asarray(tokens)
    if with_labels:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    else:
        inputs, labels = tokens, None
    ids = list(ids) if ids is not None else make_ids(len(tokens), "doc")
    slices = partition_sequence(inputs, n_owners)
    owners = [DataOwner(f"owner{p}", ids, slices[p])
              for p in range(n_owners)]
    return DataScientist(ids, labels), owners
