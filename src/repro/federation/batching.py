"""Every batch-partitioning layout of the SplitNN system, in one place.

Before this module existed, three mutually-incompatible copies of the
vertical-partition-to-batch logic lived in ``examples/quickstart.py``
(feature slices stacked to ``x_slices``), ``launch/train.py`` /
``examples/train_vertical_llm.py`` (token reshapes to ``owner_tokens``)
and ``launch/engine.py`` (padded serving contexts).  They are now three
*layouts* of one module, each the batch-level counterpart of a
``core/vertical.py`` partitioner and property-tested to round-trip
against it:

  feature layout    ``x_slices``     (P, B, f_p)   <-> partition_features
  sequence layout   ``owner_tokens`` (P, B, S_p)   <-> partition_sequence
  serving layout    left-padded contexts -> sequence layout

All functions are pure numpy/jnp shape plumbing; nothing here looks at
labels (this is owner-side code under the party-visibility contract —
see ``federation/parties.py``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

Array = np.ndarray
Slices = Union[Array, List[Array]]


# ---------------------------------------------------------------------------
# feature layout (the paper's MNIST experiment: MLPSplitNN ``x_slices``)
# ---------------------------------------------------------------------------


def stack_feature_slices(slices: Sequence[Array]) -> Slices:
    """Per-owner feature slices [(B, f_i), ...] -> stacked (P, B, f) when the
    owners are symmetric, else the list unchanged (imbalanced vertical
    datasets, paper §5.1)."""
    widths = {s.shape[-1] for s in slices}
    if len(widths) == 1:
        return np.stack([np.asarray(s) for s in slices])
    return [np.asarray(s) for s in slices]


def unstack_feature_slices(stacked: Slices) -> List[Array]:
    """Inverse of :func:`stack_feature_slices`."""
    if isinstance(stacked, list):
        return stacked
    return [stacked[p] for p in range(stacked.shape[0])]


def feature_batch(owner_slices: Sequence[Array], labels: Optional[Array],
                  idx: Optional[Array] = None) -> Dict[str, jnp.ndarray]:
    """Assemble an ``MLPSplitNN`` training batch from per-owner feature
    matrices [(N, f_i), ...] + scientist labels (N,), optionally gathering
    rows ``idx`` (ID-aligned across all parties after resolution)."""
    sel = (lambda a: a if idx is None else a[idx])
    xs = stack_feature_slices([sel(np.asarray(s)) for s in owner_slices])
    batch = {"x_slices": ([jnp.asarray(x) for x in xs]
                          if isinstance(xs, list) else jnp.asarray(xs))}
    if labels is not None:
        batch["labels"] = jnp.asarray(sel(np.asarray(labels)))
    return batch


# ---------------------------------------------------------------------------
# sequence layout (split LMs: ``owner_tokens``)
# ---------------------------------------------------------------------------


def sequence_owner_slices(tokens: Array, n_owners: int) -> Array:
    """(B, S) combined sequences -> (P, B, S_p) contiguous owner slices.

    Identical partition to ``core.vertical.partition_sequence`` (owner p
    holds [p*S/P, (p+1)*S/P)), stacked on a leading owner dim so the head
    pass can vmap over owners."""
    B, S = tokens.shape
    if S % n_owners:
        raise ValueError(f"seq {S} not divisible by {n_owners} owners")
    return np.asarray(tokens).reshape(
        B, n_owners, S // n_owners).transpose(1, 0, 2)


def merge_sequence_slices(owner_tokens: Array) -> Array:
    """Inverse of :func:`sequence_owner_slices`: (P, B, S_p) -> (B, S)."""
    P, B, S_p = owner_tokens.shape
    return np.asarray(owner_tokens).transpose(1, 0, 2).reshape(B, P * S_p)


def sequence_batch(owner_slices: Sequence[Array], labels: Optional[Array],
                   idx: Optional[Array] = None) -> Dict[str, jnp.ndarray]:
    """Assemble a ``SplitModel`` training batch from per-owner token slices
    [(N, S_p), ...] + scientist next-token labels (N, S)."""
    sel = (lambda a: a if idx is None else a[idx])
    ot = np.stack([sel(np.asarray(s)) for s in owner_slices])
    batch = {"owner_tokens": jnp.asarray(ot)}
    if labels is not None:
        batch["labels"] = jnp.asarray(sel(np.asarray(labels)))
    return batch


# ---------------------------------------------------------------------------
# serving layout (padded request waves -> sequence layout)
# ---------------------------------------------------------------------------


def pad_contexts(contexts: Sequence[Array], n_slots: int, length: int,
                 pad: int = 0, pad_side: str = "left") -> Array:
    """Ragged request contexts -> a full (n_slots, length) int32 wave.

    ``pad_side="left"`` right-aligns each context (recency next to the
    decode position — what the serving engine wants); unused slots stay
    all-pad."""
    if len(contexts) > n_slots:
        raise ValueError(f"{len(contexts)} contexts > {n_slots} slots")
    out = np.full((n_slots, length), pad, np.int32)
    for i, c in enumerate(contexts):
        c = np.asarray(c, np.int32)
        if len(c) > length:
            raise ValueError(f"context {len(c)} > wave length {length}")
        if pad_side == "left":
            out[i, length - len(c):] = c
        elif pad_side == "right":
            out[i, :len(c)] = c
        else:
            raise ValueError(pad_side)
    return out


def serving_owner_slices(batch_tokens: Array, n_owners: int) -> jnp.ndarray:
    """Padded (B, S) wave -> (P, B, S_p) device-ready owner slices."""
    return jnp.asarray(sequence_owner_slices(batch_tokens, n_owners))


def pad_context_row(tokens: Array, length: int, pad: int = 0,
                    pad_side: str = "left") -> Array:
    """One request's padded (length,) row — the slot-level unit of the
    serving layout (continuous batching admits one slot at a time)."""
    return pad_contexts([tokens], 1, length, pad=pad, pad_side=pad_side)[0]


def context_tag(row: Array) -> str:
    """sha256 content tag of a padded context row — the PSI blind-upload
    dedup trick (entity resolution's content addressing) applied to
    serving: two requests with byte-identical padded contexts are the
    same entity-context, whoever submits them.  Keys the repeat-entity
    cut cache (``launch/engine.py``)."""
    import hashlib
    a = np.ascontiguousarray(np.asarray(row, np.int32))
    return hashlib.sha256(a.tobytes()).hexdigest()
