"""VerticalSession — the single entrypoint for every PyVertical workflow.

The paper's pipeline (Fig. 2) as a facade over the repo's machinery
(this example runs verbatim under ``make docs-check``):

```python
from repro.configs.pyvertical_mnist import CONFIG
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties

sci, owners = feature_parties(*make_vertical_mnist_parties(
    400, seed=0, keep_frac=0.9))
session = VerticalSession(sci, owners)
stats = session.resolve(group="modp512")  # DH-PSI + ID alignment
assert stats["global_intersection"] == len(session.scientist.ids)
session.build(CONFIG)                     # MLPSplitNN | SplitModel
history = session.fit(epochs=3, batch_size=64, eval_frac=0.2,
                      verbose=False)
assert history["train"][-1]["loss"] < history["train"][0]["loss"]
# (LM archs additionally serve: engine = session.serve(...))
```

``resolve`` scales to million-ID sets: ``session.resolve(group=...,
parallelism=4, chunk_size=4096)`` streams the PSI rounds in bounded
chunks through a modexp worker pool and reuses the scientist's blinded
upload across every owner (see ``repro/core/psi.py``).

Party-visibility contract (enforced, see ``tests/test_federation.py``):
owners never see labels, the scientist never receives raw feature arrays.
Every cross-party message the session mediates is appended to
``session.transcript``; during training the only owner->scientist payloads
are PSI responses and cut-layer activations (claim C4), and the only
scientist->owner payloads are blinded PSI sets, the resolved-ID broadcast,
and cut-layer gradients.

Training modes:

  * ``fit(mode="joint")`` — one jitted autodiff program per step.
  * ``fit(mode="joint", microbatches=M)`` — the *microbatched joint
    oracle*: the same GPipe math the pipelined split schedule runs
    (per-chunk grads at step-start params, accumulated in chunk order,
    one update), executed in-process through the same compiled segment
    programs.  Chunked reductions are not bitwise-identical to the
    one-shot program (XLA reduction order differs with row count), so
    this loop — not the fused program — is the bit-for-bit reference
    for microbatched split runs.
  * ``fit(mode="split", microbatches=M)`` — true split execution over
    the transport with M cut exchanges in flight per channel.
"""
from __future__ import annotations

import os
import queue as _queue
import threading
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking, privacy
from repro.core.modexp import ModexpPool
from repro.core.psi import DEFAULT_CHUNK, DEFAULT_MODE, psi_round
from repro.core.splitnn import (cut_layer_traffic, make_split_train_step,
                                train_state_init)
from repro.federation import batching, faults, transport
from repro.federation.parties import (DataOwner, DataScientist,
                                      OwnerComputeEndpoint, PrivacyError)
from repro.federation.registry import build_adapter
from repro.federation.supervisor import OwnerFailure, Supervisor
from repro.federation.transport import FrameCorrupt


def _scalars(m):
    return {k: float(v) for k, v in m.items()}


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


#: leaked-actor accounting: party threads that outlived their join
#: deadline (process-wide — a wedged actor sleeping through its stop is
#: the common producer; tests reset this between cases)
leak_stats = {"leaked_threads": 0}


def _join_or_warn(th, timeout: float, context: str) -> bool:
    """``th.join(timeout)`` that *surfaces* the leak: a party thread
    still alive after its deadline (a wedged actor mid-sleep, a stuck
    receive) gets a loud ``RuntimeWarning`` and a ``leak_stats`` bump
    instead of silently outliving the session."""
    th.join(timeout=timeout)
    if th.is_alive():
        leak_stats["leaked_threads"] += 1
        warnings.warn(
            f"{context}: thread {th.name!r} still alive after "
            f"{timeout:.1f}s join — leaked (wedged actor?)",
            RuntimeWarning, stacklevel=2)
        return False
    return True


class VerticalSession:
    """Orchestrates one scientist + N owners through resolve / build /
    fit / evaluate / serve.  The session itself is the trusted simulation
    runtime; party objects keep their raw data private."""

    def __init__(self, scientist: DataScientist,
                 owners: Union[Sequence[DataOwner], Dict[str, DataOwner]],
                 *, seed: int = 0):
        self.scientist = scientist
        self.owners: List[DataOwner] = (list(owners.values())
                                        if isinstance(owners, dict)
                                        else list(owners))
        if len({o.name for o in self.owners}) != len(self.owners):
            raise ValueError("owner names must be unique")
        if not self.owners:
            raise ValueError("need at least one data owner")
        self.seed = seed
        self.transcript: List[dict] = []
        self.resolve_stats: Optional[dict] = None
        self.transport_stats: Optional[dict] = None
        #: one entry per supervised-fit recovery / PSI round retry
        self.recovery_events: List[dict] = []
        self.adapter = None
        self.config = None
        self._init_seed = seed
        self.params = None
        self.history: Optional[dict] = None
        self._resolved = False
        self._eval_idx = np.arange(0)
        self._train_idx: Optional[np.ndarray] = None
        self._eval_fn = None

    # ------------------------------------------------------------- plumbing

    def _log(self, frm: str, to: str, kind: str, **payload):
        self.transcript.append({"from": frm, "to": to, "kind": kind,
                                **payload})

    def _owner_arrays(self) -> List[np.ndarray]:
        """Owner-side accessor: aligned per-owner feature matrices.  These
        arrays feed the jitted joint step (the simulation of owner-local
        head computation); they are never attached to the scientist."""
        return [o._features for o in self.owners]

    def _require(self, *, resolved=False, built=False, labels=False):
        if resolved and not self._resolved:
            raise RuntimeError("call session.resolve() before training — "
                               "parties are not ID-aligned yet")
        if built and self.adapter is None:
            raise RuntimeError("call session.build(config) first")
        if labels and not self.scientist.has_labels:
            raise PrivacyError("the scientist holds no labels; this "
                               "session supports inference only")

    # ------------------------------------------------------------ 1. resolve

    def resolve(self, *, group: str = "modp2048",
                fp_rate: float = 1e-9, mode: str = DEFAULT_MODE,
                parallelism: int = 0,
                chunk_size: int = DEFAULT_CHUNK,
                backend: str = "direct", latency_s: float = 0.0,
                bandwidth_bps: Optional[float] = None,
                timeout: float = 120.0, retries: int = 0,
                retry_backoff_s: float = 0.05) -> dict:
        """The paper's §3.1 protocol: the scientist runs DH-PSI pairwise
        with each owner (scientist = client, so only the scientist learns
        each intersection), intersects globally, broadcasts the shared IDs,
        and every party filter-and-sorts.  Returns the stats dict.

        ``mode`` selects the protocol variant: ``"noinv"`` (default) and
        ``"bloom"`` reveal each pairwise intersection to the scientist;
        ``"hidden"`` is the membership-hiding variant — matching runs on
        the *owner* side, the scientist receives only a padded keep-mask
        (members + deterministic decoys, indistinguishable in every
        frame), and all parties align on positional pseudonym IDs, so
        training proceeds on aligned row order without the scientist
        ever learning which raw IDs matched.  A repeat resolve after ±Δ
        ID churn (``scientist.update_rows`` / ``owner.update_rows``)
        costs O(Δ) modexp and O(Δ) wire bytes: the memoized blinded
        upload is spliced client-side and shipped as one
        ``psi_delta_chunk``, and unchanged response legs are skipped
        entirely via content tags.

        The scientist blinds its set ONCE and reuses the blinded upload
        for every owner round (logged as a ``psi_blind_reuse`` transcript
        entry from the second round on); each owner's response-side state
        (sharded Bloom or blinded own set, by ``mode``) is likewise
        per-session.  ``parallelism`` forks that many modexp workers
        shared across all owner rounds (0 = the bit-identical serial
        engine); ``chunk_size`` bounds the streamed chunks so million-ID
        sets never materialize one giant blinded batch.

        ``backend`` selects the execution engine:

          * ``"direct"`` (default) — the in-process reference engine
            (``core.psi.psi_round``): party objects exchange chunks by
            direct call, byte counts are protocol-data tallies.
          * ``"queue"`` — *wire-native* resolution: each owner runs a
            ``PSIServerEndpoint`` actor on its own thread behind a
            serialized ``federation.transport`` channel, every protocol
            leg crosses as a framed ``Message`` (pipelined, chunk k+1
            overlapping chunk k's server modexp), and the transcript +
            stats carry **measured** per-party wire bytes.  ``latency_s``
            / ``bandwidth_bps`` inject per-message transit time (wire
            backends only); ``timeout`` bounds each receive so a wedged
            owner fails the resolve instead of hanging it.
          * ``"process"`` — the same wire-native protocol with each
            owner's actor in its own *spawned worker process*
            (``federation/runtime.py``): every leg crosses a real OS
            pipe, the PSI stack's jax-free import chain keeps the
            workers numpy-light, and a crashed worker surfaces through
            its poison-pill frame or exit code.

        The intersection is bit-identical across backends, chunk sizes,
        and parallelism (property-tested).

        ``retries`` re-runs a *failed* owner round (crashed or wedged
        PSI worker) up to that many extra times with exponential backoff
        (``retry_backoff_s`` base), respawning the owner's actor at
        generation ``attempt`` so generation-0 injected faults don't
        re-fire.  The scientist's sha256-memoized blinded upload
        survives the retry, so any chunk the owner already cached ships
        zero repeat bytes (queue backend: the owner-side cache also
        survives actor re-creation)."""
        if backend not in ("direct", "queue", "process"):
            raise ValueError(f"unknown resolve backend {backend!r}")
        if backend == "direct" and (latency_s or bandwidth_bps):
            raise ValueError("latency_s/bandwidth_bps model the wire — "
                             "they require a wire backend "
                             "('queue' or 'process')")
        stats: dict = {"rounds": [], "global_intersection": 0,
                       "mode": mode, "parallelism": parallelism,
                       "chunk_size": chunk_size, "backend": backend}
        if backend != "direct":
            stats["latency_s"] = latency_s
            stats["per_party_wire"] = {}
        hidden = mode == "hidden"
        global_pos: Optional[set] = None        # hidden: keep positions
        row_maps: Dict[str, dict] = {}          # hidden: pos -> owner row
        with ModexpPool(parallelism) as pool:
            # the accessor self-syncs a cached client against the
            # scientist's current population (O(Δ) splice after churn —
            # this is what arms the wire's psi_delta_chunk fast path)
            client = self.scientist.psi_client(group, mode, pool=pool)
            global_ids = set(client.items)
            for owner in self.owners:
                for attempt in range(max(0, retries) + 1):
                    try:
                        if backend != "direct":
                            inter, rstats = self._resolve_owner_wire(
                                client, owner, backend=backend,
                                group=group, fp_rate=fp_rate, pool=pool,
                                chunk_size=chunk_size,
                                latency_s=latency_s,
                                bandwidth_bps=bandwidth_bps,
                                timeout=timeout, stats=stats,
                                generation=attempt)
                        else:
                            inter, rstats = self._resolve_owner_direct(
                                client, owner, group=group,
                                fp_rate=fp_rate, pool=pool,
                                chunk_size=chunk_size)
                        break
                    except RuntimeError as e:
                        # a crashed/wedged PSI round costs one retry:
                        # the client's blinded upload is memoized, so
                        # the rerun re-ships only what the owner never
                        # cached (0 bytes when the round died late)
                        if attempt >= retries:
                            raise
                        self._log("scientist", owner.name,
                                  "psi_round_retry", attempt=attempt + 1,
                                  error=str(e))
                        self.recovery_events.append(
                            {"party": owner.name, "action": "psi_retry",
                             "attempt": attempt + 1, "error": str(e)})
                        time.sleep(retry_backoff_s * (2 ** attempt))
                # the ENGINE's parallelism (0 when the host can't fork),
                # not the requested value — stats must not claim a pool
                # that silently degraded to serial
                stats["parallelism"] = rstats["parallelism"]
                if rstats["blind_cached"] or rstats.get("upload_skipped"):
                    # the memoized-blind reuse is protocol-relevant (it is
                    # why owner rounds 2..N are cheap) — record it
                    self._log("scientist", owner.name, "psi_blind_reuse",
                              reused_upload_bytes=
                              rstats["client_upload_bytes"],
                              recompute_skipped=rstats["blind_cached"],
                              upload_skipped=bool(
                                  rstats.get("upload_skipped", False)))
                if rstats.get("delta_used") or rstats.get("resp_skipped") \
                        or rstats.get("server_leg_skipped"):
                    # the churn fast paths (O(Δ) delta splice / cached
                    # response leg) are likewise protocol-relevant
                    self._log("scientist", owner.name, "psi_delta_reuse",
                              delta_used=bool(rstats.get("delta_used")),
                              resp_skipped=bool(
                                  rstats.get("resp_skipped")),
                              server_leg_skipped=bool(
                                  rstats.get("server_leg_skipped")))
                if hidden:
                    row_maps[owner.name] = dict(
                        zip(inter, rstats["hidden_rows"]))
                    pos = set(inter)
                    global_pos = (pos if global_pos is None
                                  else global_pos & pos)
                else:
                    global_ids &= set(inter)
                stats["rounds"].append({
                    "owner": owner.name, "intersection_size": len(inter),
                    "client_upload_bytes": rstats["client_upload_bytes"],
                    "server_response_bytes":
                        rstats["server_response_bytes"],
                    "n_chunks": rstats["n_chunks"],
                    "blind_cached": rstats["blind_cached"],
                    **({"bloom_bytes": rstats["bloom_bytes"],
                        "bloom_shards": rstats["bloom_shards"]}
                       if mode == "bloom" else
                       {"server_set_bytes": rstats["server_set_bytes"]}),
                    **({k: rstats[k] for k in
                        ("delta_used", "resp_skipped",
                         "server_leg_skipped", "client_modexp_ops",
                         "server_modexp_ops", "hidden_kept")
                        if k in rstats}),
                    **({"upload_skipped": rstats["upload_skipped"],
                        "upload_wire_bytes": rstats["upload_wire_bytes"],
                        "download_wire_bytes":
                            rstats["download_wire_bytes"]}
                       if backend != "direct" else {})})
        if hidden:
            final = sorted(global_pos or set())
            stats["global_intersection"] = len(final)
            # positional pseudonym alignment: every party keeps the
            # same aligned order; the scientist maps keep positions
            # back to its rows via the client's item order and never
            # learns which raw IDs actually matched (decoys are
            # indistinguishable in every frame it saw)
            items = list(client.items)
            for owner in self.owners:
                owner._align_hidden(
                    [row_maps[owner.name][p] for p in final])
                self._log("scientist", owner.name, "resolved_ids",
                          count=len(final))
            self.scientist._align_hidden(final, items)
        else:
            stats["global_intersection"] = len(global_ids)
            self.scientist._align(global_ids)
            for owner in self.owners:
                owner._align(global_ids)
                self._log("scientist", owner.name, "resolved_ids",
                          count=len(global_ids))
        for owner in self.owners:
            # invariant SplitNN training relies on: identical ID order
            assert owner.ids == self.scientist.ids, \
                f"misaligned owner {owner.name}"
        # every owner round succeeded: fold the delta into the new base
        # (the next churn diffs against the state all peers now cache)
        client.rebase_delta()
        self._resolved = True
        self.resolve_stats = stats
        return stats

    def _resolve_owner_direct(self, client, owner, *, group, fp_rate,
                              pool, chunk_size):
        """One in-process PSI round (the PR 4 reference engine), with
        per-kind transcript tallies from the engine's message callback."""
        server = owner.psi_server(group, fp_rate)
        wire: Dict[str, List[int]] = {}

        def tally(kind, n_bytes):
            c = wire.setdefault(kind, [0, 0])
            c[0] += 1
            c[1] += n_bytes

        inter, rstats = psi_round(client, server, pool=pool,
                                  chunk_size=chunk_size, on_message=tally)
        # one transcript entry per wire-message kind, aggregated
        # (per-chunk entries would swamp the transcript at 1e6)
        for kind, (n_msgs, n_bytes) in wire.items():
            frm, to = (("scientist", owner.name)
                       if kind in ("psi_blind_chunk", "psi_delta_chunk",
                                   "psi_lift_chunk")
                       else (owner.name, "scientist"))
            self._log(frm, to, kind, bytes=n_bytes, chunks=n_msgs)
        return inter, rstats

    def _mirror_owner_psi_caches(self, owner, client, group, fp_rate):
        """Copy a finished process-backend round's content-addressed PSI
        artifacts onto the owner, standing in for the persistent caches a
        long-lived owner process would keep (the spawned worker's died
        with it).  Entries are keyed by content tag, so a mirrored value
        can never go stale — at worst it is evicted unused.  The hidden
        response leg (``D``) is the one artifact the client never sees,
        so hidden delta on the process backend degrades to a full upload
        rather than being mirrored here."""
        from repro.core.psi import blind_tag as _btag
        key = (group, fp_rate)
        blob = client._blinded_packed
        if blob is not None:
            owner._psi_blind_caches.setdefault(key, {})[_btag(blob)] = blob
        rc = client.round_cache.get(owner.name)
        if not rc:
            return
        if "d_blob" in rc:
            owner._psi_resp_caches.setdefault(key, {})[rc["tag"]] = \
                rc["d_blob"]
        if client.mode == "hidden" and rc.get("t_blob"):
            owner._psi_lift_caches.setdefault(key, {})[rc["server_tag"]] = \
                rc["t_blob"]

    def _resolve_owner_wire(self, client, owner, *, backend, group,
                            fp_rate, pool, chunk_size, latency_s,
                            bandwidth_bps, timeout, stats,
                            generation=0):
        """One wire-native PSI round: the owner's actor on its own thread
        (``backend="queue"``) or in its own spawned process
        (``backend="process"``, ``federation/runtime.py``) behind a
        serialized channel, every leg a measured Message.  The transcript
        gets one aggregated entry per kind per direction with *measured*
        payload and wire bytes, and ``stats['per_party_wire']`` the
        owner's channel totals."""
        from repro.federation.psi_transport import wire_psi_round

        if backend == "process":
            from repro.federation import runtime
            # spawn-time own-set blinding happens on the owner's
            # persistent server (parent side); fold those ops into the
            # round's server count so backends stay comparable
            srv_parent = owner.psi_server(group, fp_rate)
            spawn_ops0 = srv_parent.ops
            handle = runtime.spawn_psi_worker(
                owner, group=group, fp_rate=fp_rate,
                latency_s=latency_s, bandwidth_bps=bandwidth_bps,
                generation=generation, pool=pool)
            try:
                ep_sci = handle.endpoint
                inter, rstats = wire_psi_round(
                    client, ep_sci, worker=handle, pool=pool,
                    chunk_size=chunk_size, timeout=timeout,
                    peer=owner.name)
            finally:
                try:
                    handle.endpoint.send("psi_stop", {})
                except RuntimeError:        # worker already gone
                    pass
                handle.shutdown()
            for k in ("server_modexp_ops", "modexp_ops"):
                rstats[k] = rstats.get(k, 0) + srv_parent.ops - spawn_ops0
            # the spawned worker's caches died with it; mirror the round's
            # content-addressed artifacts onto the (long-lived) owner so
            # the next spawn rehydrates them and repeat rounds stay O(Δ).
            # Legitimate: the session is the trusted simulation runtime,
            # and every entry is keyed by its own content tag.
            self._mirror_owner_psi_caches(owner, client, group, fp_rate)
        else:
            ep_sci, ep_own = transport.channel_pair(
                "scientist", owner.name, backend="queue",
                latency_s=latency_s, bandwidth_bps=bandwidth_bps)
            worker = owner.psi_endpoint(ep_own, group, fp_rate, pool=pool)
            # same chaos surface as the spawned workers: the env plan's
            # crash/wedge + wire faults land on the in-process actor too
            faults.arm_actor(worker, owner.name, generation=generation)
            faults.arm_endpoint(ep_own, owner.name, generation=generation)
            th = threading.Thread(target=worker.run, daemon=True,
                                  name=f"psi-{owner.name}")
            th.start()
            try:
                inter, rstats = wire_psi_round(
                    client, ep_sci, worker=worker, pool=pool,
                    chunk_size=chunk_size, timeout=timeout,
                    peer=owner.name)
            finally:
                ep_sci.send("psi_stop", {})
                _join_or_warn(th, 10.0, f"resolve({owner.name})")

        sent, rcvd = ep_sci.sent_stats, ep_sci.recv_stats
        for kind, st in sorted(sent["by_kind"].items()):
            if kind == "psi_stop":
                continue
            self._log("scientist", owner.name, kind, measured=True,
                      bytes=st["payload_bytes"],
                      wire_bytes=st["wire_bytes"], chunks=st["count"])
        for kind, st in sorted(rcvd["by_kind"].items()):
            self._log(owner.name, "scientist", kind, measured=True,
                      bytes=st["payload_bytes"],
                      wire_bytes=st["wire_bytes"], chunks=st["count"])
        stats["per_party_wire"][owner.name] = {
            "sent_wire_bytes": sent["wire_bytes"],
            "recv_wire_bytes": rcvd["wire_bytes"],
            "messages": sent["messages"] + rcvd["messages"],
        }
        # the blind upload specifically (zero when the owner had it
        # cached) — hello/stop framing lives in per_party_wire totals
        rstats["upload_wire_bytes"] = sent["by_kind"].get(
            "psi_blind_chunk", {"wire_bytes": 0})["wire_bytes"]
        rstats["download_wire_bytes"] = rcvd["wire_bytes"]
        return inter, rstats

    # -------------------------------------------------------------- 2. build

    def build(self, config, *, seed: Optional[int] = None
              ) -> "VerticalSession":
        """Instantiate the split model for ``config`` via the registry
        (``MLPSplitConfig`` -> MLPSplitNN, ``ArchConfig`` -> SplitModel)
        and initialize per-party parameters."""
        self.adapter = build_adapter(config)
        # the config + init seed are what a spawned owner worker needs to
        # rebuild its adapter/programs (federation/runtime.py)
        self.config = config
        self._init_seed = self.seed if seed is None else seed
        key = jax.random.PRNGKey(self._init_seed)
        self.params = self.adapter.init(key)
        self._eval_fn = jax.jit(
            lambda p, b: self.adapter.loss_fn(p, b)[1])
        return self

    # ---------------------------------------------------------------- 3. fit

    def fit(self, *, epochs: Optional[int] = None,
            steps: Optional[int] = None, batch_size: int = 128,
            eval_frac: float = 0.0, owner_lr: Optional[float] = None,
            scientist_lr: Optional[float] = None,
            log_every: Optional[int] = None, ckpt_dir: Optional[str] = None,
            ckpt_every: int = 0, shuffle_seed: Optional[int] = None,
            verbose: bool = True, mode: str = "joint",
            schedule: str = "pipelined", microbatches: int = 1,
            compression: Optional[str] = None, backend: str = "queue",
            latency_s: float = 0.0,
            bandwidth_bps: Optional[float] = None,
            timeout: float = 120.0, supervise: bool = False,
            max_restarts: int = 2, resync_every: int = 1,
            heartbeat_s: float = 0.5,
            aggregation: Optional[str] = None) -> dict:
        """The SplitNN training loop.

        Exactly one of ``epochs`` (feature workloads) / ``steps`` (LM
        workloads) must be given.  ``eval_frac`` holds out the last
        fraction of aligned rows; per-epoch (or final) eval metrics land
        in ``history["eval"]``.  ``ckpt_dir``+``ckpt_every`` write
        per-party checkpoints through ``repro.checkpoint.save_split``.
        Returns ``{"train": [...], "eval": [...], "final": {...}}``.

        ``mode="joint"`` (default) runs the single jitted autodiff
        program — the gradient-equivalence oracle.  With
        ``microbatches=M > 1`` the joint loop runs the *microbatched*
        oracle instead: per-chunk grads at step-start params, accumulated
        in chunk order through the same compiled segment programs the
        split schedule uses (GPipe semantics).  ``mode="split"`` runs
        *true split execution*: each owner's head segment executes on its
        own thread behind a ``federation.transport`` channel, and the
        only cross-party tensors are cut activations / cut gradients —
        measured wire bytes, not estimates (``self.transport_stats``).
        Split-mode knobs: ``schedule`` ("pipelined" overlaps owner
        compute and wire latency with the scientist's work — with
        ``microbatches=M`` every batch is split into M GPipe chunks and
        up to M cut exchanges ride the channel concurrently;
        "sequential" is the fully synchronous baseline),
        ``compression`` (None | "fp16" | "int8" cut-payload codec),
        ``backend`` ("queue" = serialized simulated network, "direct" =
        in-process reference passing, "process" = each owner in its own
        spawned worker process over a real OS pipe —
        ``federation/runtime.py``), ``latency_s``/``bandwidth_bps``
        (injected per-message transit time), ``timeout`` (seconds each
        steady-state cross-party receive may wait before a wedged or
        dead owner surfaces as a clean error on the scientist side;
        warmup receives use at least 120 s to absorb worker startup +
        compile).

        ``supervise=True`` (split mode, wire backends) turns on the
        crash-recovery protocol: every ``resync_every`` steps the
        scientist ships a ``snapshot`` marker — each owner keeps a host
        copy of its step-start params/optimizer state and acks the
        leaves back — and a ``federation.supervisor.Supervisor`` runs
        heartbeat liveness probes alongside the step loop.  When an
        owner crashes, wedges past ``timeout``, or a frame fails its
        CRC, the session rolls every survivor back to the newest marker
        the failed party acked, respawns the dead owner from its
        snapshotted leaves (bounded exponential backoff, at most
        ``max_restarts`` per party), replays the in-flight steps from
        the cached batch-index log, and continues — the final params
        are bit-identical to the fault-free run (property-tested; the
        zero-grad recovery warmup is a bitwise no-op for SGD-family
        owner optimizers, the paper's case).  Each recovery appends to
        ``session.recovery_events``.

        ``aggregation="masked_sum"`` turns on secure forward
        aggregation (Cai et al., ``core/masking.py``): each owner ships
        its cut quantized + ring-masked with pairwise-cancelling masks
        (root seed over the ``REPRO_MASK_SEED`` env channel), so the
        scientist reconstructs only the owner SUM — no per-owner
        activation ever crosses the wire.  Requires an adapter with
        ``combine="sum"`` and >= 2 owners.  ``mode="joint"`` with
        masked_sum runs the *masked joint oracle* — the identical
        quantize -> ring-sum -> dequantize combine without masks —
        which split masked execution reproduces bit-for-bit (masks
        cancel exactly in the integer ring; property-tested)."""
        self._require(resolved=True, built=True, labels=True)
        if (epochs is None) == (steps is None):
            raise ValueError("pass exactly one of epochs= or steps=")
        if mode not in ("joint", "split"):
            raise ValueError(f"mode must be 'joint' or 'split': {mode!r}")
        microbatches = int(microbatches)
        if microbatches < 1:
            raise ValueError(f"microbatches must be >= 1: {microbatches}")
        if microbatches > 1:
            if batch_size % microbatches:
                raise ValueError(
                    f"microbatches={microbatches} must divide "
                    f"batch_size={batch_size}")
            if not getattr(self.adapter, "supports_microbatch", False):
                raise ValueError(
                    f"{type(self.adapter).__name__} does not support "
                    "microbatched training")
        if aggregation not in (None, "masked_sum"):
            raise ValueError(f"unknown aggregation {aggregation!r} "
                             "(None | 'masked_sum')")
        if aggregation == "masked_sum":
            if not getattr(self.adapter, "supports_masked", False):
                raise ValueError(
                    f"{type(self.adapter).__name__} does not support "
                    "masked_sum aggregation (needs combine='sum')")
            if len(self.owners) < 2:
                raise ValueError(
                    "masked_sum needs >= 2 owners: a single owner's "
                    "masked payload would expose its activations")
        if supervise:
            if mode != "split":
                raise ValueError("supervise=True requires mode='split' "
                                 "(recovery is a wire protocol)")
            if backend == "direct":
                raise ValueError("supervise=True requires a wire "
                                 "backend ('queue' or 'process')")
            if int(resync_every) < 1:
                raise ValueError(
                    f"resync_every must be >= 1: {resync_every}")
        if mode == "split":
            return self._fit_split(
                epochs=epochs, steps=steps, batch_size=batch_size,
                eval_frac=eval_frac, owner_lr=owner_lr,
                scientist_lr=scientist_lr, log_every=log_every,
                ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                shuffle_seed=shuffle_seed, verbose=verbose,
                schedule=schedule, microbatches=microbatches,
                compression=compression, backend=backend,
                latency_s=latency_s, bandwidth_bps=bandwidth_bps,
                timeout=timeout, supervise=supervise,
                max_restarts=max_restarts,
                resync_every=int(resync_every),
                heartbeat_s=heartbeat_s, aggregation=aggregation)
        if microbatches > 1 or aggregation is not None:
            # the masked joint oracle runs through the microbatched
            # loop even at M=1: its quantize->ring-sum->dequantize
            # combine is what split masked execution reproduces
            return self._fit_joint_microbatched(
                epochs=epochs, steps=steps, batch_size=batch_size,
                eval_frac=eval_frac, owner_lr=owner_lr,
                scientist_lr=scientist_lr, log_every=log_every,
                ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                shuffle_seed=shuffle_seed, verbose=verbose,
                microbatches=microbatches, aggregation=aggregation)

        n = len(self.scientist.ids)
        n_train = n - int(n * eval_frac)
        if n_train < batch_size:
            raise ValueError(f"{n_train} train rows < batch {batch_size}")
        self._train_idx = np.arange(n_train)
        self._eval_idx = np.arange(n_train, n)

        adapter = self.adapter
        opt = adapter.default_optimizer(owner_lr, scientist_lr)
        state = train_state_init(self.params, opt)
        # donate=True: the joint step consumes its param/state buffers in
        # place — the allocation-free hot loop the core API was built for
        step_fn = make_split_train_step(adapter.loss_fn, opt, donate=True)

        # the per-step protocol traffic, recorded once (static shapes)
        for owner in self.owners:
            shape = adapter.cut_shape(batch_size, owner.feature_shape)
            self._log(owner.name, "scientist", "cut_activations",
                      shape=shape, width=shape[-1], per_step=True)
            self._log("scientist", owner.name, "cut_gradients",
                      shape=shape, per_step=True)

        owner_arrays = self._owner_arrays()
        labels = self.scientist.labels
        rng = np.random.default_rng(self.seed if shuffle_seed is None
                                    else shuffle_seed)
        history: dict = {"train": [], "eval": []}
        t0 = time.time()
        metrics = {}

        stream = self._index_stream(rng, n_train, batch_size, epochs, steps)
        if epochs is not None:
            steps_per_epoch = (n_train - batch_size) // batch_size + 1
            global_step = 0
            for ep in range(epochs):
                for _ in range(steps_per_epoch):
                    batch = adapter.make_batch(
                        owner_arrays, labels, next(stream))
                    self.params, state, metrics = step_fn(
                        self.params, state, batch, global_step)
                    global_step += 1
                rec = {"epoch": ep, **_scalars(metrics)}
                history["train"].append(rec)
                if len(self._eval_idx):
                    history["eval"].append(
                        {"epoch": ep, **self.evaluate()})
                if verbose and (ep % (log_every or 1) == 0
                                or ep == epochs - 1):
                    ev = history["eval"][-1] if history["eval"] else {}
                    extra = "".join(f" val_{k}={v:.4f}"
                                    for k, v in ev.items() if k != "epoch")
                    print(f"epoch {ep:3d} " + " ".join(
                        f"{k}={v:.4f}" for k, v in rec.items()
                        if k != "epoch") + extra +
                        f" ({time.time() - t0:.1f}s)")
                if ckpt_dir and ckpt_every and (ep + 1) % ckpt_every == 0:
                    self.checkpoint(ckpt_dir, ep + 1)
        else:
            for i in range(steps):
                batch = adapter.make_batch(owner_arrays, labels,
                                           next(stream))
                self.params, state, metrics = step_fn(
                    self.params, state, batch, i)
                rec = {"step": i, **_scalars(metrics)}
                history["train"].append(rec)
                if verbose and log_every and (i % log_every == 0
                                              or i == steps - 1):
                    print(f"step {i:5d} " + " ".join(
                        f"{k}={v:.4f}" for k, v in rec.items()
                        if k != "step") + f" ({time.time() - t0:.1f}s)")
                if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                    self.checkpoint(ckpt_dir, i + 1)
            if len(self._eval_idx):
                history["eval"].append({"step": steps, **self.evaluate()})

        final = dict(history["train"][-1]) if history["train"] else {}
        if history["eval"]:
            final.update({f"val_{k}": v
                          for k, v in history["eval"][-1].items()
                          if k not in ("epoch", "step")})
        history["final"] = final
        self.history = history
        return history

    def _index_stream(self, rng, n_train, batch_size, epochs, steps):
        """The batch-index stream — ONE generator shared by the joint
        and split training loops, so both consume the shuffle rng
        identically (split-mode gradient equivalence is bit-for-bit
        against the joint path and depends on this).  epochs-mode:
        a fresh permutation per epoch, full batches only; steps-mode:
        reshuffle whenever the remaining tail can't fill a batch."""
        if epochs is not None:
            for _ in range(epochs):
                order = rng.permutation(self._train_idx)
                for s in range(0, n_train - batch_size + 1, batch_size):
                    yield order[s:s + batch_size]
        else:
            order = rng.permutation(self._train_idx)
            cursor = 0
            for _ in range(steps):
                if cursor + batch_size > n_train:
                    order = rng.permutation(self._train_idx)
                    cursor = 0
                yield order[cursor:cursor + batch_size]
                cursor += batch_size

    def _train_bookkeeping(self, t, metrics, history, t0, *, epochs,
                           steps, steps_per_epoch, log_every, verbose,
                           ckpt_dir, ckpt_every, sync):
        """Per-step history/eval/print/checkpoint — shared by the
        microbatched joint oracle and the split loop.  ``sync`` makes
        ``self.params`` current (a transport barrier + reassembly for
        the split loop, a local reassembly for the oracle) before any
        eval or checkpoint touches them."""
        if epochs is not None:
            if (t + 1) % steps_per_epoch:
                return
            ep_i = (t + 1) // steps_per_epoch - 1
            rec = {"epoch": ep_i, **_scalars(metrics)}
            history["train"].append(rec)
            if len(self._eval_idx):
                sync()
                history["eval"].append(
                    {"epoch": ep_i, **self.evaluate()})
            if verbose and (ep_i % (log_every or 1) == 0
                            or ep_i == epochs - 1):
                ev = history["eval"][-1] if history["eval"] else {}
                extra = "".join(f" val_{k}={v:.4f}"
                                for k, v in ev.items() if k != "epoch")
                print(f"epoch {ep_i:3d} " + " ".join(
                    f"{k}={v:.4f}" for k, v in rec.items()
                    if k != "epoch") + extra +
                    f" ({time.time() - t0:.1f}s)")
            if ckpt_dir and ckpt_every and (ep_i + 1) % ckpt_every == 0:
                sync()
                self.checkpoint(ckpt_dir, ep_i + 1)
        else:
            rec = {"step": t, **_scalars(metrics)}
            history["train"].append(rec)
            if verbose and log_every and (t % log_every == 0
                                          or t == steps - 1):
                print(f"step {t:5d} " + " ".join(
                    f"{k}={v:.4f}" for k, v in rec.items()
                    if k != "step") + f" ({time.time() - t0:.1f}s)")
            if ckpt_dir and ckpt_every and (t + 1) % ckpt_every == 0:
                sync()
                self.checkpoint(ckpt_dir, t + 1)

    # ------------------------------------- 3a. microbatched joint oracle

    def _fit_joint_microbatched(self, *, epochs, steps, batch_size,
                                eval_frac, owner_lr, scientist_lr,
                                log_every, ckpt_dir, ckpt_every,
                                shuffle_seed, verbose, microbatches,
                                aggregation=None) -> dict:
        """The GPipe reference loop: per-microbatch segment programs,
        grads accumulated in chunk order at step-start params, one
        optimizer update per party per step.  Runs the SAME compiled
        programs (adapter-cached) as ``fit(mode="split",
        microbatches=M)`` in the same order — the bit-for-bit oracle for
        microbatched split execution.

        With ``aggregation="masked_sum"`` this loop is the *masked
        joint oracle*: cuts are quantized through the adapter's quant
        program, host-ring-summed (``masking.fold_quantized`` — exact
        integer addition, bitwise the wire fold once masks cancel), and
        the masked trunk programs consume the int32 sum; every owner's
        head backward receives the same broadcast ``dL/dz``."""
        adapter = self.adapter
        M = microbatches
        bm = batch_size // M
        n = len(self.scientist.ids)
        n_train = n - int(n * eval_frac)
        if n_train < batch_size:
            raise ValueError(f"{n_train} train rows < batch {batch_size}")
        self._train_idx = np.arange(n_train)
        self._eval_idx = np.arange(n_train, n)

        P = len(self.owners)
        head_progs = [adapter.owner_programs(p) for p in range(P)]
        gather = adapter.gather_program()
        feats = [jnp.asarray(o._features) for o in self.owners]
        owner_opt, owner_update = adapter.owner_update_rule(owner_lr)
        slices = [adapter.owner_param_slice(self.params, p)
                  for p in range(P)]
        ostates = [owner_opt.init(s) for s in slices]
        trunk_opt, trunk_update = adapter.trunk_update_rule(scientist_lr)
        masked = aggregation == "masked_sum"
        if masked:
            quant = adapter.quant_program()
            cutgrad, weightgrad = \
                adapter.masked_trunk_microbatch_programs()
        else:
            cutgrad, weightgrad = adapter.trunk_microbatch_programs()
        tp = self.params["trunk"]
        ts = trunk_opt.init(tp)
        denom = jnp.asarray(float(batch_size), jnp.float32)
        inv_micro = jnp.asarray(1.0 / M, jnp.float32)

        labels = self.scientist.labels
        rng = np.random.default_rng(self.seed if shuffle_seed is None
                                    else shuffle_seed)
        stream = self._index_stream(rng, n_train, batch_size, epochs, steps)
        if epochs is not None:
            steps_per_epoch = (n_train - batch_size) // batch_size + 1
            total_steps = epochs * steps_per_epoch
        else:
            steps_per_epoch = None
            total_steps = steps

        def reassemble():
            self.params = {"heads": adapter.stack_head_params(slices),
                           "trunk": tp}

        history: dict = {"train": [], "eval": []}
        t0 = time.time()
        metrics: dict = {}

        for t in range(total_steps):
            idx = next(stream)
            lab_full = labels[idx]
            idx_dev = jnp.asarray(np.asarray(idx, np.int32))
            xs = [gather(f, idx_dev) for f in feats]
            chunks = [[x[m * bm:(m + 1) * bm] for m in range(M)]
                      for x in xs]
            parts_list = []
            owner_aux = 0.0
            hg_acc: List[Optional[object]] = [None] * P
            cut_cache = []
            for m in range(M):
                cuts = []
                for p in range(P):
                    out = head_progs[p][0](slices[p], chunks[p][m])
                    cut, aux = (out if isinstance(out, tuple)
                                else (out, None))
                    cuts.append(cut)
                    if aux is not None:
                        # identical f32 round-trip as the wire's aux
                        owner_aux += float(
                            np.float32(np.asarray(aux).sum()))
                lab_m = jnp.asarray(lab_full[m * bm:(m + 1) * bm])
                if masked:
                    # the oracle combine: quantize each owner's cut,
                    # host-ring-sum (no masks — they'd cancel anyway),
                    # feed the masked trunk program the int32 sum.  The
                    # broadcast z-grad is every owner's cut gradient.
                    zsum = jnp.asarray(masking.fold_quantized(
                        [np.asarray(quant(c)) for c in cuts]))
                    zg, parts = cutgrad(tp, zsum, lab_m, denom,
                                        inv_micro)
                    cg = [zg] * P
                    cached = zsum
                else:
                    cached = cuts = tuple(cuts)
                    cg, parts = cutgrad(tp, cuts, lab_m, denom,
                                        inv_micro)
                parts_list.append(parts)
                for p in range(P):
                    hg = head_progs[p][1](slices[p], chunks[p][m], cg[p])
                    hg_acc[p] = hg if hg_acc[p] is None else \
                        _tree_add(hg_acc[p], hg)
                cut_cache.append((cached, lab_m))
            for p in range(P):
                slices[p], ostates[p] = owner_update(
                    slices[p], ostates[p], hg_acc[p], t)
            tg_acc = None
            for cuts, lab_m in cut_cache:
                tg = weightgrad(tp, cuts, lab_m, denom, inv_micro)
                tg_acc = tg if tg_acc is None else _tree_add(tg_acc, tg)
            tp, ts = trunk_update(tp, ts, tg_acc, t)
            parts_acc = parts_list[0]
            for parts in parts_list[1:]:
                parts_acc = {k: parts_acc[k] + parts[k] for k in parts}
            metrics = dict(parts_acc)
            if owner_aux and "aux" in metrics:
                metrics = {**metrics, "aux": metrics["aux"] + owner_aux}

            self._train_bookkeeping(
                t, metrics, history, t0, epochs=epochs, steps=steps,
                steps_per_epoch=steps_per_epoch, log_every=log_every,
                verbose=verbose, ckpt_dir=ckpt_dir,
                ckpt_every=ckpt_every, sync=reassemble)

        reassemble()
        if steps is not None and len(self._eval_idx):
            history["eval"].append({"step": steps, **self.evaluate()})

        final = dict(history["train"][-1]) if history["train"] else {}
        if history["eval"]:
            final.update({f"val_{k}": v
                          for k, v in history["eval"][-1].items()
                          if k not in ("epoch", "step")})
        history["final"] = final
        self.history = history
        return history

    # ------------------------------------------------- 3b. split execution

    def _recv_from_owner(self, ep, worker, kind, timeout: float = 120.0):
        """Receive ``kind`` from one owner, surfacing a dead worker
        immediately (short poll) instead of after the full timeout.
        Process-backed workers can also fail *through* the receive — a
        poison-pill frame or a severed pipe raises out of ``recv_kind``
        — and get wrapped in the same owner-attributed error.  Failures
        raise :class:`~repro.federation.supervisor.OwnerFailure` (a
        ``RuntimeError`` carrying ``.party``), so the supervised fit
        knows whom to restart; message strings are unchanged."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return ep.recv_kind(kind, timeout=1.0)
            except _queue.Empty:
                if worker.error is not None:
                    raise OwnerFailure(
                        f"owner worker {worker.owner.name!r} failed",
                        party=worker.owner.name) from worker.error
                if time.monotonic() > deadline:
                    raise OwnerFailure(
                        f"timed out waiting for {kind!r} from "
                        f"{worker.owner.name!r}",
                        party=worker.owner.name)
            except Exception:
                if getattr(worker, "error", None) is not None:
                    raise OwnerFailure(
                        f"owner worker {worker.owner.name!r} failed",
                        party=worker.owner.name) from worker.error
                raise

    def _sync_split_params(self, workers, eps, trunk_params,
                           timeout: float = 120.0):
        """Flush every owner's message queue (barrier), then reassemble
        the session-resident param tree from the owners' live segments —
        the trusted-runtime accessor, mirroring ``_owner_arrays``.
        Thread-backed owners expose their params directly; process-backed
        owners answer a ``pull_params`` request with their numbered
        numpy leaves, rebuilt here against the session's tree
        structure."""
        for ep in eps:
            ep.send("barrier", {}, seq=-1)
        for ep, w in zip(eps, workers):
            self._recv_from_owner(ep, w, "barrier_ack", timeout=timeout)
        head_slices = []
        for p, (ep, w) in enumerate(zip(eps, workers)):
            if hasattr(w, "params"):            # in-process actor
                head_slices.append(w.params)
                continue
            ep.send("pull_params", {}, seq=-1)
            m = self._recv_from_owner(ep, w, "params_dump",
                                      timeout=timeout)
            structure = jax.tree_util.tree_structure(
                self.adapter.owner_param_slice(self.params, p))
            head_slices.append(jax.tree_util.tree_unflatten(
                structure, [jnp.asarray(m.payload[str(i)])
                            for i in range(len(m.payload))]))
        self.params = {
            "heads": self.adapter.stack_head_params(head_slices),
            "trunk": trunk_params}

    def _fit_split(self, *, epochs, steps, batch_size, eval_frac, owner_lr,
                   scientist_lr, log_every, ckpt_dir, ckpt_every,
                   shuffle_seed, verbose, schedule, microbatches,
                   compression, backend, latency_s, bandwidth_bps,
                   timeout=120.0, supervise=False, max_restarts=2,
                   resync_every=1, heartbeat_s=0.5,
                   aggregation=None) -> dict:
        """True split execution over the transport layer (paper Fig. 2).

        Per step t the wire carries exactly four message kinds:
        ``head_fwd`` (batch row indices; arrow 4 "compute forward"),
        ``cut_activations`` (arrow 5), ``cut_gradients`` (arrow 7), and
        — in the sequential schedule only — ``step_done`` acks.  The
        pipelined schedule ships the step-t+1 forward request *before*
        step t's gradients and the gradients before the trunk update, so
        the owners' backward+forward for t/t+1 overlap the scientist's
        optimizer step; with ``microbatches=M`` the batch is split into
        M GPipe chunks, each chunk's cut gradient leaves the moment its
        cut activations arrive, and the trunk's weight gradients +
        update run *inside the wire's round-trip window* — only one
        chunk of owner-edge and trunk-cutgrad compute remains on the
        latency-critical path.  FIFO order keeps the math identical
        (owners accumulate every chunk gradient at step-start params and
        update exactly once per step).  An explicit warmup round
        compiles every program on both sides before the timed region.

        With the lossless codec, both schedules reproduce the joint
        program bit-for-bit whenever the adapter's head optimizer is
        elementwise-separable across owners (the paper's MLP/SGD case —
        property-tested); microbatched runs reproduce the microbatched
        joint oracle (``fit(mode="joint", microbatches=M)``) the same
        way.  The LM adapter clips grads per-owner instead of across all
        heads, so it tracks the joint path within tolerance rather than
        exactly."""
        adapter = self.adapter
        if not getattr(adapter, "supports_split", False):
            raise ValueError(f"{type(adapter).__name__} does not support "
                             "split execution")
        if backend not in ("queue", "direct", "process"):
            raise ValueError(f"unknown fit backend {backend!r}")
        if schedule not in ("pipelined", "sequential"):
            raise ValueError(f"unknown schedule {schedule!r}")
        sequential = schedule == "sequential"
        M = microbatches
        if sequential and M > 1:
            raise ValueError("microbatches > 1 requires the pipelined "
                             "schedule (sequential is the synchronous "
                             "baseline)")
        bm = batch_size // M
        codec = transport.get_codec(compression)

        n = len(self.scientist.ids)
        n_train = n - int(n * eval_frac)
        if n_train < batch_size:
            raise ValueError(f"{n_train} train rows < batch {batch_size}")
        self._train_idx = np.arange(n_train)
        self._eval_idx = np.arange(n_train, n)

        trunk_opt, trunk_update = adapter.trunk_update_rule(scientist_lr)
        trunk_params = self.params["trunk"]
        trunk_state = trunk_opt.init(trunk_params)
        # Pipelined: the decomposed trunk programs serve every M (M == 1
        # is a single whole-batch chunk) — cut grads on the
        # latency-critical path, weight grads + update in the wire's
        # shadow.  The decomposition is bitwise-identical to the fused
        # trunk step (property-tested), so the M == 1 joint-oracle
        # equivalence is unchanged.  Sequential: the fused one-pass
        # program — recompute-based decomposition would double trunk
        # work with no wire window to hide it in, overstating the
        # baseline this schedule exists to provide.
        masked = aggregation == "masked_sum"
        if sequential:
            trunk_step = (adapter.masked_trunk_program() if masked
                          else adapter.trunk_program())
            cutgrad = weightgrad = None
        else:
            cutgrad, weightgrad = (
                adapter.masked_trunk_microbatch_programs() if masked
                else adapter.trunk_microbatch_programs())
            trunk_step = None
        denom = jnp.asarray(float(batch_size), jnp.float32)
        inv_micro = jnp.asarray(1.0 / M, jnp.float32)

        # secure aggregation key agreement: the mask root travels the
        # env channel so spawned owner workers (which inherit the
        # parent's environment) and in-process actors derive the same
        # pairwise streams.  Respect a caller-set value (the deployment
        # secret); otherwise publish the session default for the run
        # and restore on exit.
        mask_env_set = False
        if masked and not os.environ.get(masking.MASK_ENV, ""):
            os.environ[masking.MASK_ENV] = str(self._init_seed)
            mask_env_set = True
        mask_root = masking.mask_root_from_env(self._init_seed)

        # gradient-side label-leakage defences (SplitConfig): applied
        # to every cut-gradient chunk before it ships — deterministic
        # per (seed, seq, owner), so supervised replay after a recovery
        # re-derives bitwise-identical defended gradients
        sp_cfg = self.config.split
        defend_on = (sp_cfg.grad_noise_std > 0.0
                     or sp_cfg.grad_norm_mode != "none")

        def defend(g, seq, p):
            if not defend_on:
                return g
            return privacy.obfuscate_cut_gradient(
                np.asarray(g), noise_std=sp_cfg.grad_noise_std,
                norm_mode=sp_cfg.grad_norm_mode, seed=self._init_seed,
                tag=f"g{seq}o{p}")

        owner_opt, owner_update = adapter.owner_update_rule(owner_lr)
        workers, eps, threads = [], [], []

        def spawn_proc(p, *, param_leaves, opt_state_leaves=None,
                       start_step=0, generation=0):
            # one spawned worker process per owner (federation/
            # runtime.py): the spec carries the model config + the
            # owner's param leaves (and, on respawn, its snapshotted
            # optimizer state + resume step), and the worker rebuilds
            # the exact OwnerComputeEndpoint the thread path constructs
            from repro.federation import runtime
            owner = self.owners[p]
            spec = runtime.OwnerWorkerSpec(
                name=owner.name, ids=list(owner.ids),
                features=np.asarray(owner._features),
                owner_index=p, config=self.config,
                init_seed=self._init_seed,
                param_leaves=param_leaves,
                codec=compression, microbatches=M,
                ack_steps=sequential, owner_lr=owner_lr,
                latency_s=latency_s, bandwidth_bps=bandwidth_bps,
                opt_state_leaves=opt_state_leaves,
                start_step=start_step, generation=generation,
                aggregation=aggregation, n_owners=len(self.owners),
                cut_noise_std=sp_cfg.cut_noise_std)
            return runtime.spawn_owner_worker(spec, owner=owner)

        def spawn_thread(p, *, params, opt_state=None, start_step=0,
                         generation=0):
            owner = self.owners[p]
            ep_sci, ep_own = transport.channel_pair(
                "scientist", owner.name, backend=backend,
                latency_s=latency_s, bandwidth_bps=bandwidth_bps)
            head_fwd, head_bwd = adapter.owner_programs(p)
            masker = None
            if masked:
                masker = masking.MaskedAggregator(
                    mask_root, p, len(self.owners),
                    adapter.quant_program(), generation=generation)
            w = OwnerComputeEndpoint(
                owner, ep_own, head_fwd, head_bwd,
                optimizer=owner_opt, params=params,
                codec=codec, ack_steps=sequential, microbatches=M,
                gather=adapter.gather_program(),
                update_program=owner_update,
                tail_program=adapter.owner_tail_rule(owner_lr, p),
                opt_state=opt_state, start_step=start_step,
                masker=masker, cut_noise_std=sp_cfg.cut_noise_std,
                noise_seed=self._init_seed)
            # in-process actors get the same chaos surface as spawned
            # workers: the env plan's crash/wedge wrap + wire faults
            faults.arm_actor(w, owner.name, generation=generation)
            if backend == "queue":
                faults.arm_endpoint(ep_own, owner.name,
                                    generation=generation)
            th = threading.Thread(target=w.run, daemon=True,
                                  name=f"owner-{owner.name}")
            th.start()
            return w, ep_sci, th

        for p in range(len(self.owners)):
            if backend == "process":
                handle = spawn_proc(
                    p, param_leaves=[
                        np.asarray(leaf) for leaf in
                        jax.tree_util.tree_leaves(
                            adapter.owner_param_slice(self.params, p))])
                workers.append(handle)
                eps.append(handle.endpoint)
            else:
                w, ep_sci, th = spawn_thread(
                    p, params=adapter.owner_param_slice(self.params, p))
                workers.append(w)
                eps.append(ep_sci)
                threads.append(th)

        sup = None
        if supervise:
            # heartbeat liveness probes ride the protocol channels on
            # their own thread (send paths are thread-safe; recv_kind's
            # locked stash routes each kind to its consumer).  The step
            # loop never *acts* on a suspicion alone — recovery triggers
            # on in-band failures (OwnerFailure / FrameCorrupt), which
            # are strictly fresher — but the supervisor owns the
            # restart budget and backoff.
            sup = Supervisor(max_restarts=max_restarts,
                             heartbeat_s=heartbeat_s)
            for p, owner in enumerate(self.owners):
                sup.attach(owner.name, eps[p], workers[p])
            sup.start()

        labels = self.scientist.labels
        rng = np.random.default_rng(self.seed if shuffle_seed is None
                                    else shuffle_seed)
        if epochs is not None:
            steps_per_epoch = (n_train - batch_size) // batch_size + 1
            total_steps = epochs * steps_per_epoch
        else:
            steps_per_epoch = None
            total_steps = steps
        # THE batch-index stream — shared with the joint loop.  The
        # replay log caches every batch pulled from the generator so a
        # supervised recovery can re-send step s's exact indices without
        # re-consuming the shuffle rng (bit-identity depends on it).
        gen = self._index_stream(rng, n_train, batch_size, epochs, steps)
        idx_log: list = []

        def get_idx(i):
            while len(idx_log) <= i:
                idx_log.append(next(gen))
            return idx_log[i]

        inflight: deque = deque()

        def send_fwd(idx, seq):
            for ep in eps:
                ep.send("head_fwd", {"idx": np.asarray(idx, np.int32)},
                        seq=seq)
            inflight.append(idx)

        def recv_chunk(seq):
            """One microbatch chunk from every owner -> per-owner cut
            tuple + the owners' summed aux scalar.  The cuts go into the
            jitted trunk programs as-is (stacking happens in-program).
            Masked runs fold the owners' uint32 ring payloads instead:
            the return is the reconstructed int32 SUM — the scientist
            never materializes a per-owner activation."""
            cuts, payloads, aux = [], [], 0.0
            for ep, w in zip(eps, workers):
                m = self._recv_from_owner(ep, w, "cut_activations",
                                          timeout=timeout)
                if m.seq != seq:
                    raise RuntimeError(f"protocol desync: cut seq {m.seq} "
                                       f"!= expected {seq}")
                if masked:
                    payloads.append(m.payload)
                else:
                    cuts.append(codec.decode(m.payload))
                if "aux" in m.payload:
                    aux += float(np.asarray(m.payload["aux"]).sum())
            if masked:
                return jnp.asarray(masking.reconstruct(payloads)), aux
            return tuple(cuts), aux

        # Party threads trade sub-millisecond messages; CPython's default
        # 5 ms GIL switch interval would let one party's pure-Python
        # stretch stall another's dispatch for a whole quantum.
        import sys as _sys
        old_switch = _sys.getswitchinterval()
        _sys.setswitchinterval(5e-4)

        # warmup receives tolerate worker startup + compile (a spawned
        # process imports jax and jits every program before its first
        # cut) — the user's ``timeout`` governs steady-state receives
        warmup_timeout = max(timeout, 120.0)

        # ---------------- warmup: compile both sides before the clock
        try:
            widx = np.zeros(batch_size, np.int32)
            wlab = np.asarray(labels[widx])
            wzero = None        # kept: respawned workers re-warm with it
            for ep in eps:
                ep.send("warmup", {"idx": widx}, seq=-1)
            for m in range(M):
                cuts, payloads = [], []
                for ep, w in zip(eps, workers):
                    mm = self._recv_from_owner(ep, w, "warmup_cuts",
                                               timeout=warmup_timeout)
                    if masked:
                        payloads.append(mm.payload)
                    else:
                        cuts.append(codec.decode(mm.payload))
                lab_m = jnp.asarray(wlab[m * bm:(m + 1) * bm])
                if masked:
                    # all owners are generation 0 here, so their warmup
                    # masks cancel and the fold is the true zsum —
                    # compiles the masked trunk programs at real shapes
                    zsum = jnp.asarray(masking.reconstruct(payloads))
                    if sequential:
                        _, _, zg = trunk_step(trunk_params, zsum, lab_m)
                    else:
                        zg, _ = cutgrad(trunk_params, zsum, lab_m,
                                        denom, inv_micro)
                        weightgrad(trunk_params, zsum, lab_m, denom,
                                   inv_micro)
                    zero = np.zeros_like(np.asarray(zg))
                elif sequential:
                    _, _, cg = trunk_step(trunk_params, jnp.stack(cuts),
                                          lab_m)
                    zero = np.zeros_like(np.asarray(cg[0]))
                else:
                    cg, _ = cutgrad(trunk_params, tuple(cuts), lab_m,
                                    denom, inv_micro)
                    weightgrad(trunk_params, tuple(cuts), lab_m, denom,
                               inv_micro)
                    zero = np.zeros_like(np.asarray(cg[0]))
                wzero = zero
                for ep in eps:
                    ep.send("warmup_grads", codec.encode(zero), seq=m)
            trunk_params, trunk_state = trunk_update(
                trunk_params, trunk_state,
                jax.tree.map(jnp.zeros_like, trunk_params), 0)
            for ep, w in zip(eps, workers):
                self._recv_from_owner(ep, w, "warmup_done",
                                      timeout=warmup_timeout)

            # ---------------- the timed training region
            history: dict = {"train": [], "eval": []}
            t0 = time.time()
            t_warm = None     # end of step 0 (steady-state guard band)
            overhead_s = 0.0  # eval/sync/ckpt time, excluded from step cost
            metrics: dict = {}

            def sync():
                self._sync_split_params(workers, eps, trunk_params,
                                        timeout=timeout)

            # -------- supervision state (markers, snapshots, replay)
            trunk_snaps: dict = {}   # marker step -> (np params, np state)
            hist_marks: dict = {}    # marker step -> history lengths
            snap_acks: dict = {p: {} for p in range(len(eps))}
            marker = {"last": None, "pending": False}
            KEEP = 4                 # markers retained (> pipeline lag)

            def collect_acks(s):
                for p, (ep, w) in enumerate(zip(eps, workers)):
                    m = self._recv_from_owner(ep, w, "snapshot_ack",
                                              timeout=timeout)
                    if int(m.seq) != s:
                        raise OwnerFailure(
                            f"snapshot ack desync from "
                            f"{self.owners[p].name!r}: seq {m.seq} != "
                            f"{s}", party=self.owners[p].name)
                    snap_acks[p][s] = {k: np.array(v)
                                       for k, v in m.payload.items()}
                    for old in sorted(snap_acks[p])[:-KEEP]:
                        del snap_acks[p][old]

            def mark(s):
                # collect the previous marker's acks lazily (they have
                # been on the wire since that iteration), then ship
                # marker s: each owner snapshots its step-s-start
                # params/opt state by FIFO order; the trunk's step-s
                # snapshot is taken right here
                if marker["pending"]:
                    collect_acks(marker["last"])
                for ep in eps:
                    ep.send("snapshot", {}, seq=s)
                trunk_snaps[s] = (
                    jax.tree.map(lambda a: np.array(a), trunk_params),
                    jax.tree.map(lambda a: np.array(a), trunk_state))
                hist_marks[s] = (len(history["train"]),
                                 len(history["eval"]))
                for old in sorted(trunk_snaps)[:-KEEP]:
                    del trunk_snaps[old]
                    hist_marks.pop(old, None)
                marker["last"], marker["pending"] = s, True

            def respawn(p, s):
                # rebuild owner p from the marker-s leaves it acked:
                # params + optimizer state + step counter, armed at its
                # next generation so generation-0 faults stay fired
                gen_n = sup.restarts(self.owners[p].name)
                ack = snap_acks[p][s]
                p_leaves = [ack[f"p{i}"] for i in
                            range(sum(k.startswith("p") for k in ack))]
                o_leaves = [ack[f"o{i}"] for i in
                            range(sum(k.startswith("o") for k in ack))]
                if backend == "process":
                    handle = spawn_proc(
                        p, param_leaves=p_leaves,
                        opt_state_leaves=o_leaves, start_step=s,
                        generation=gen_n)
                    workers[p], eps[p] = handle, handle.endpoint
                else:
                    structure = jax.tree_util.tree_structure(
                        adapter.owner_param_slice(self.params, p))
                    params_r = jax.tree_util.tree_unflatten(
                        structure, [jnp.asarray(x) for x in p_leaves])
                    opt_r = jax.tree_util.tree_unflatten(
                        jax.tree_util.tree_structure(
                            owner_opt.init(params_r)),
                        [jnp.asarray(x) for x in o_leaves])
                    w, ep_sci, th = spawn_thread(
                        p, params=params_r, opt_state=opt_r,
                        start_step=s, generation=gen_n)
                    workers[p], eps[p] = w, ep_sci
                    threads.append(th)
                sup.attach(self.owners[p].name, eps[p], workers[p])

            def rewarm(p):
                # compile the respawned worker's programs before it
                # rejoins the timed region; the zero-grad update is a
                # bitwise no-op (SGD-family owner optimizers)
                ep, w = eps[p], workers[p]
                ep.send("warmup", {"idx": widx}, seq=-1)
                for m in range(M):
                    self._recv_from_owner(ep, w, "warmup_cuts",
                                          timeout=warmup_timeout)
                    ep.send("warmup_grads", codec.encode(wzero), seq=m)
                self._recv_from_owner(ep, w, "warmup_done",
                                      timeout=warmup_timeout)

            def recover(exc):
                """Roll every party back to the newest consistent
                marker s*, respawn the dead owner from its acked
                snapshot, and return s* as the step to replay from."""
                nonlocal trunk_params, trunk_state
                crashed = isinstance(exc, OwnerFailure)
                party = exc.party if crashed else exc.sender
                sup.failed.setdefault(party, exc)
                sup.plan_restart(party)     # budget + bounded backoff
                if crashed:
                    p_dead = next(i for i, o in enumerate(self.owners)
                                  if o.name == party)
                    # harvest snapshot acks still in flight from the
                    # dead party (sent before it died), then cut loose
                    try:
                        while True:
                            m = eps[p_dead].recv_kind("snapshot_ack",
                                                      timeout=0.5)
                            snap_acks[p_dead][int(m.seq)] = {
                                k: np.array(v)
                                for k, v in m.payload.items()}
                    except Exception:   # noqa: BLE001 — channel is dead
                        pass
                    shutdown = getattr(workers[p_dead], "shutdown", None)
                    if shutdown is not None:
                        shutdown()
                    acked = sorted(s for s in snap_acks[p_dead]
                                   if s in trunk_snaps)
                    if not acked:
                        raise OwnerFailure(
                            f"party {party!r} failed with no "
                            "recoverable snapshot", party=party) from exc
                    s_star = acked[-1]
                else:
                    # wire fault (FrameCorrupt): the party is alive —
                    # everyone rolls back to the newest marker, which
                    # every owner has processed by FIFO order
                    p_dead = None
                    s_star = marker["last"]
                for i, ep in enumerate(eps):
                    if i != p_dead:
                        ep.send("rollback", {}, seq=s_star)
                for i, (ep, w) in enumerate(zip(eps, workers)):
                    if i == p_dead:
                        continue
                    while int(self._recv_from_owner(
                            ep, w, "rollback_ack",
                            timeout=timeout).seq) != s_star:
                        pass
                    # everything the owner sent before its ack is stale
                    ep.flush_pending()
                    if hasattr(ep, "reset_dedup"):
                        ep.reset_dedup()
                if crashed:
                    respawn(p_dead, s_star)
                    rewarm(p_dead)
                tp_np, ts_np = trunk_snaps[s_star]
                trunk_params = jax.tree.map(jnp.asarray, tp_np)
                trunk_state = jax.tree.map(jnp.asarray, ts_np)
                n_tr, n_ev = hist_marks[s_star]
                del history["train"][n_tr:]
                del history["eval"][n_ev:]
                trunk_snaps.clear()
                hist_marks.clear()
                for p in snap_acks:
                    snap_acks[p].clear()
                marker["last"], marker["pending"] = None, False
                # synchronous re-mark: every owner (respawned included)
                # snapshots its restored step-s*-start state, so a
                # second failure before the next marker stays covered
                mark(s_star)
                collect_acks(s_star)
                marker["pending"] = False
                self.recovery_events.append({
                    "party": party, "step": int(s_star),
                    "action": "respawn" if crashed else "rollback",
                    "error": str(exc)})
                return s_star

            t = 0
            fwd_next = 0        # next head_fwd seq to ship
            while t < total_steps:
              try:
                if supervise and t % resync_every == 0 \
                        and marker["last"] != t:
                    mark(t)
                if fwd_next == t:
                    # step t's forward request (start or replay resume)
                    send_fwd(get_idx(t), t)
                    fwd_next = t + 1
                if (not sequential and t + 1 < total_steps
                        and fwd_next == t + 1):
                    # the t+1 forward request leaves FIRST: it overlaps
                    # the wire and the owners stage (not run) it until
                    # their step-t update lands — FIFO keeps it exact
                    send_fwd(get_idx(t + 1), t + 1)
                    fwd_next = t + 2
                idx_t = inflight.popleft()
                # label staging runs while the cut chunks are on the wire
                lab_t = np.asarray(labels[idx_t])
                lab_chunks = [jnp.asarray(lab_t[m * bm:(m + 1) * bm])
                              for m in range(M)]
                if sequential:
                    # synchronous baseline: one whole-batch exchange
                    # through the fused one-pass trunk program; update
                    # strictly before the grads leave, wait for every
                    # owner's step, then request t+1
                    cuts, owner_aux = recv_chunk(t)
                    if masked:
                        # recv_chunk already folded the ring sum; the
                        # broadcast z-grad goes back to every owner
                        parts, tg, zg = trunk_step(
                            trunk_params, cuts, lab_chunks[0])
                        cg = [zg] * len(eps)
                    else:
                        parts, tg, cg = trunk_step(
                            trunk_params, jnp.stack(cuts), lab_chunks[0])
                    trunk_params, trunk_state = trunk_update(
                        trunk_params, trunk_state, tg, t)
                    for p, ep in enumerate(eps):
                        ep.send("cut_gradients",
                                codec.encode(defend(cg[p], t, p)),
                                seq=t)
                    for ep, w in zip(eps, workers):
                        self._recv_from_owner(ep, w, "step_done",
                                              timeout=timeout)
                    if t + 1 < total_steps and fwd_next == t + 1:
                        send_fwd(get_idx(t + 1), t + 1)
                        fwd_next = t + 2
                    parts_list = [parts]
                else:
                    # pipelined GPipe: each chunk's cut grads ship the
                    # moment its cuts arrive; everything batch-wide —
                    # trunk weight grads, the optimizer update, metric
                    # folds — runs in the wire's shadow afterwards
                    owner_aux = 0.0
                    parts_list = []
                    cut_cache = []
                    for m in range(M):
                        seq = t * M + m
                        cuts, aux_m = recv_chunk(seq)
                        owner_aux += aux_m
                        cg, parts = cutgrad(trunk_params, cuts,
                                            lab_chunks[m], denom,
                                            inv_micro)
                        if masked:
                            # cutgrad returned the broadcast z-grad
                            cg = [cg] * len(eps)
                        for p, ep in enumerate(eps):
                            ep.send("cut_gradients",
                                    codec.encode(defend(cg[p], seq, p)),
                                    seq=seq)
                        parts_list.append(parts)
                        cut_cache.append((cuts, lab_chunks[m]))
                    tg_acc = None
                    for cuts, lab_m in cut_cache:
                        tg = weightgrad(trunk_params, cuts, lab_m,
                                        denom, inv_micro)
                        tg_acc = tg if tg_acc is None else \
                            _tree_add(tg_acc, tg)
                    trunk_params, trunk_state = trunk_update(
                        trunk_params, trunk_state, tg_acc, t)
                parts_acc = parts_list[0]
                for parts in parts_list[1:]:
                    parts_acc = {k: parts_acc[k] + parts[k]
                                 for k in parts}
                metrics = dict(parts_acc)
                if owner_aux and "aux" in metrics:
                    # joint-path parity: heads aux + trunk aux
                    metrics = {**metrics,
                               "aux": metrics["aux"] + owner_aux}
                if t == 0:
                    t_warm = time.time()

                # ----------- bookkeeping (excluded from step timings)
                tb = time.time()
                self._train_bookkeeping(
                    t, metrics, history, t0, epochs=epochs, steps=steps,
                    steps_per_epoch=steps_per_epoch, log_every=log_every,
                    verbose=verbose, ckpt_dir=ckpt_dir,
                    ckpt_every=ckpt_every, sync=sync)
                overhead_s += time.time() - tb
                t += 1
              except (OwnerFailure, FrameCorrupt) as e:
                if not supervise:
                    raise
                t = recover(e)
                inflight.clear()
                fwd_next = t

            wall_s = time.time() - t0
            self._sync_split_params(workers, eps, trunk_params,
                                    timeout=timeout)
            if steps is not None and len(self._eval_idx):
                history["eval"].append({"step": steps, **self.evaluate()})
        finally:
            _sys.setswitchinterval(old_switch)
            if mask_env_set:
                os.environ.pop(masking.MASK_ENV, None)
            if sup is not None:
                sup.stop()
            for ep in eps:
                try:
                    ep.send("stop", {})
                except RuntimeError:        # worker already gone
                    pass
            for th in threads:
                _join_or_warn(th, 10.0, "fit(split)")
            for w in workers:
                shutdown = getattr(w, "shutdown", None)
                if shutdown is not None:    # process-backed handle
                    shutdown()

        # ------------------------------------- measured traffic accounting
        per_owner: Dict[str, dict] = {}
        tot_payload = tot_wire = 0
        for owner, ep in zip(self.owners, eps):
            sent, rcvd = ep.sent_stats, ep.recv_stats
            cut_k = rcvd["by_kind"].get("cut_activations",
                                        {"payload_bytes": 0,
                                         "wire_bytes": 0})
            grad_k = sent["by_kind"].get("cut_gradients",
                                         {"payload_bytes": 0,
                                          "wire_bytes": 0})
            per_owner[owner.name] = {
                "cut_payload_bytes": cut_k["payload_bytes"],
                "cut_wire_bytes": cut_k["wire_bytes"],
                "grad_payload_bytes": grad_k["payload_bytes"],
                "grad_wire_bytes": grad_k["wire_bytes"],
                "messages": sent["messages"] + rcvd["messages"],
            }
            tot_payload += cut_k["payload_bytes"] + grad_k["payload_bytes"]
            tot_wire += cut_k["wire_bytes"] + grad_k["wire_bytes"]
            self._log(owner.name, "scientist", "cut_activations",
                      bytes=cut_k["payload_bytes"], measured=True,
                      per_step_bytes=cut_k["payload_bytes"]
                      // max(total_steps, 1),
                      width=self.adapter.cut_shape(
                          batch_size, owner.feature_shape)[-1])
            self._log("scientist", owner.name, "cut_gradients",
                      bytes=grad_k["payload_bytes"], measured=True,
                      per_step_bytes=grad_k["payload_bytes"]
                      // max(total_steps, 1))
        self.transport_stats = {
            "mode": "split", "schedule": schedule,
            "microbatches": M,
            "aggregation": aggregation or "none",
            "compression": compression or "none", "backend": backend,
            "latency_s": latency_s, "bandwidth_bps": bandwidth_bps,
            "steps": total_steps, "wall_s": wall_s,
            # per-step cost excludes eval/sync/ckpt bookkeeping (every
            # compile is pulled out of the timed region by the warmup
            # handshake) ...
            "step_ms": (1e3 * (wall_s - overhead_s)
                        / max(total_steps, 1)),
            # ... and, steady-state, the step-0 pipeline fill too
            "steady_step_ms": (1e3 * (t0 + wall_s - t_warm - overhead_s)
                               / (total_steps - 1)
                               if t_warm is not None and total_steps > 1
                               else 1e3 * (wall_s - overhead_s)
                               / max(total_steps, 1)),
            "per_owner": per_owner,
            "cut_payload_bytes_per_step": sum(
                o["cut_payload_bytes"] for o in per_owner.values())
            // max(total_steps, 1),
            "total_payload_bytes": tot_payload,
            "total_wire_bytes": tot_wire,
            "total_payload_bytes_per_step": tot_payload
            // max(total_steps, 1),
            "recoveries": len(self.recovery_events),
            "supervisor": dict(sup.stats) if sup is not None else None,
        }

        final = dict(history["train"][-1]) if history["train"] else {}
        if history["eval"]:
            final.update({f"val_{k}": v
                          for k, v in history["eval"][-1].items()
                          if k not in ("epoch", "step")})
        history["final"] = final
        history["transport"] = self.transport_stats
        self.history = history
        return history

    # ------------------------------------------------------------ 4. eval

    def evaluate(self, *, split: str = "eval",
                 batch_size: int = 512) -> Dict[str, float]:
        """Metrics on the held-out (or train) rows, batched and
        length-weighted."""
        self._require(resolved=True, built=True, labels=True)
        idx = self._eval_idx if split == "eval" else self._train_idx
        if idx is None or not len(idx):
            raise ValueError(f"no rows in split {split!r} — "
                             "fit with eval_frac > 0 first")
        owner_arrays = self._owner_arrays()
        labels = self.scientist.labels
        totals: Dict[str, float] = {}
        n_done = 0
        for s in range(0, len(idx), batch_size):
            sub = idx[s:s + batch_size]
            m = self._eval_fn(self.params, self.adapter.make_batch(
                owner_arrays, labels, sub))
            for k, v in m.items():
                totals[k] = totals.get(k, 0.0) + float(v) * len(sub)
            n_done += len(sub)
        return {k: v / n_done for k, v in totals.items()}

    # ------------------------------------------------------------ 5. serve

    def serve(self, **engine_kw):
        """Wrap the resident split model in a ``ServingEngine`` (LM archs).
        Kwargs are forwarded: ``batch_slots, ctx_len, max_new, eos_token,
        ring_cache, pad_token``, plus the transport boundary knobs
        ``transport`` ("direct" | "queue" | "process" routes every cut
        activation through a measured ``federation.transport`` channel),
        ``latency_s``, ``bandwidth_bps``, and ``compression``
        (None | "fp16" | "int8" cut codec), and the serving knobs
        ``scheduler`` ("wave" drains in fixed waves; "continuous"
        refills freed slots per tick), ``max_queue`` (bounded admission
        — ``submit`` raises ``QueueFull`` beyond it), and ``cut_cache``
        (True or a ``CutCache`` — repeat contexts skip head recompute
        and cut upload entirely)."""
        self._require(built=True)
        if not getattr(self.adapter, "supports_serving", False):
            raise ValueError(
                f"{type(self.adapter).__name__} does not support serving")
        return self.adapter.make_engine(self.params, **engine_kw)

    def serve_dataset(self, *, max_new: int = 16, batch_slots: int = 4,
                      n_requests: Optional[int] = None, **engine_kw):
        """Serve the session's own aligned contexts: owners' sequence
        slices are merged (owner-side) into each request's context, queued,
        and decoded in waves.  Returns ({rid: Result}, engine)."""
        self._require(resolved=True, built=True)
        contexts = batching.merge_sequence_slices(
            np.stack(self._owner_arrays()))
        if n_requests is not None:
            contexts = contexts[:n_requests]
        engine = self.serve(batch_slots=batch_slots,
                            ctx_len=contexts.shape[1], max_new=max_new,
                            **engine_kw)
        for row in contexts:
            engine.submit(row)
        return engine.run(), engine

    # ---------------------------------------------------------- accounting

    def checkpoint(self, ckpt_dir: str, step: int = 0) -> str:
        """Per-party checkpoints: heads/owner{i}.npz + trunk.npz."""
        self._require(built=True)
        from repro import checkpoint as ckpt
        return ckpt.save_split(ckpt_dir, self.params, step)

    def restore(self, step_dir: str) -> "VerticalSession":
        """Load per-party checkpoints saved by :meth:`checkpoint` (or
        ``fit(ckpt_every=...)``) back into the resident params, so a
        fresh session resumes training/serving from that step."""
        self._require(built=True)
        from repro import checkpoint as ckpt
        self.params = ckpt.restore_split(step_dir)
        return self

    def cut_traffic(self, batch_size: int,
                    bytes_per_el: int = 4) -> Dict[str, int]:
        """Bytes crossing each owner<->scientist boundary per step (C4)."""
        self._require(built=True)
        shape = self.adapter.cut_shape(
            batch_size, self.owners[0].feature_shape)
        tokens = shape[1] if len(shape) == 3 else 1
        return cut_layer_traffic(len(self.owners), batch_size, tokens,
                                 shape[-1], bytes_per_el)
